"""Compile the full vision benchmark suite and print the Table-III-style
comparison (ours vs the baseline reference-stack compiler).

    PYTHONPATH=src python examples/compile_vision.py [--fast]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_tables import bench_table3  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()

models = None
if args.fast:
    models = [("mobilenet_v1", 1.0), ("mobilenet_v2", 1.0),
              ("efficientnet_lite0", 1.0)]
bench_table3(models=models)
