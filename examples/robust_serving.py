"""Fault-tolerant serving drill: deadlines, overload, chaos, recovery.

    PYTHONPATH=src python examples/robust_serving.py

1. starts a pooled `Session` (2 workers, per-worker plan arenas) and
   serves a burst of deadline-tagged traffic;
2. overloads the bounded queue and shows typed `Overloaded` shedding
   with retry-after hints;
3. injects chaos — both workers stall mid-batch (hung-kernel
   signature) — and shows heartbeat detection, in-flight re-dispatch
   and worker recycling with zero ticket loss;
4. poisons the model's plan until the circuit breaker trips, shows
   requests degrading to the interpretive oracle (correct outputs,
   slower), then the half-open re-lower probe recovering the plan path;
5. prints the robustness surface: p50/p99 histograms, shed/deadline/
   degraded counters, breaker state, per-worker health;
6. re-opens the session with `workers=("process", 2)` — real worker
   *processes* mmap-loading the model artifact — SIGKILLs one
   mid-batch, and shows pipe-EOF detection, re-dispatch to the
   survivor and an off-request-path respawn, still with zero loss.
"""
import time

import numpy as np

import repro.api as api
import repro.runtime.chaos as chaos


def main() -> None:
    # ---- 1. pooled session, deadline-tagged burst -----------------------
    sess = api.Session(max_batch=8, workers=2, max_queue=64,
                       linger_ms=1.0, heartbeat_timeout_s=0.2,
                       breaker_threshold=2, breaker_cooldown_s=0.3)
    m = sess.add("mobilenet_v2", precision="int8", res_scale=0.25,
                 calib_samples=2, warmup=True)
    rng = np.random.default_rng(0)
    x = rng.normal(size=m.graph.inputs[0].shape).astype(np.float32)

    tickets = [sess.submit("mobilenet_v2", x, deadline_ms=500)
               for _ in range(24)]
    outs = [t.result(timeout=30) for t in tickets]
    print(f"1. burst served: {len(outs)} requests, all within deadline\n")

    # ---- 2. overload: typed shedding ------------------------------------
    accepted, shed_hint = [], None
    try:
        for _ in range(200):
            accepted.append(sess.submit("mobilenet_v2", x))
    except api.Overloaded as e:
        shed_hint = e.retry_after_ms
    print(f"2. overload: {len(accepted)} accepted, then shed with "
          f"retry-after ~{shed_hint:.0f} ms")
    for t in accepted:
        t.result(timeout=60)
    print("   ... every accepted ticket still terminated\n")

    # ---- 3. chaos: both workers hang mid-batch --------------------------
    with chaos.inject() as c:
        c.stall_worker(0, seconds=1.0)
        c.stall_worker(1, seconds=1.0)
        ts = [sess.submit("mobilenet_v2", x) for _ in range(16)]
        outs = [t.result(timeout=30) for t in ts]
    pool = sess.stats()["pool"]
    print(f"3. hung workers: {pool['recycled_workers']} recycled, "
          f"{pool['redispatched_batches']} in-flight batches "
          f"re-dispatched, {len(outs)}/{len(ts)} tickets served — "
          f"zero loss\n")

    # ---- 4. breaker: poisoned plan -> oracle serving -> recovery --------
    ref = m(x, engine="interp")
    with chaos.inject() as c:
        for _ in range(2):                 # K=2 consecutive batch failures
            c.poison_plan("mobilenet_v2", times=2)  # first try AND retry
            t = sess.submit("mobilenet_v2", x)
            try:
                t.result(timeout=30)
            except chaos.ChaosError:
                pass
        st = sess.stats()["models"]["mobilenet_v2"]
        print(f"4. breaker {st['breaker']['state']} after "
              f"{st['plan_failures']} plan failures "
              f"({st['retries']} retries attempted)")
        out = sess.submit("mobilenet_v2", x).result(timeout=60)
        err = max(float(np.max(np.abs(out[k] - ref[k]))) for k in ref)
        print(f"   degraded request served by the interpretive oracle "
              f"(max|err| vs oracle = {err:.2e})")
    time.sleep(0.4)                        # cooldown: probe may recover
    sess.submit("mobilenet_v2", x).result(timeout=60)
    st = sess.stats()["models"]["mobilenet_v2"]
    print(f"   after cooldown: breaker {st['breaker']['state']}, "
          f"{st['recoveries']} recovery\n")

    # ---- 5. the robustness surface --------------------------------------
    print("5. session report:")
    print(sess.report())
    sess.close()

    # ---- 6. process workers: SIGKILL survival ---------------------------
    # workers=("process", 2): each worker is a real OS process that
    # mmap-loads the model's .rpa artifact (weights shared copy-on-write)
    # and serves batches over a pipe protocol — a segfault or OOM kill
    # in one worker cannot take down the parent or its sibling
    psess = api.Session(max_batch=8, workers=("process", 2),
                        max_queue=64, linger_ms=1.0,
                        heartbeat_timeout_s=5.0)
    psess.add("mobilenet_v2", precision="int8", res_scale=0.25,
              calib_samples=2, warmup=True)
    [t.result(timeout=120)                 # first batch: children lower
     for t in [psess.submit("mobilenet_v2", x) for _ in range(16)]]
    pids = sorted({h.get("pid") for h in
                   psess._pool.worker_health().values()})
    print(f"6. process pool up: worker pids {pids}")
    with chaos.inject() as c:
        c.kill_worker(-1, mode="kill")     # SIGKILL the next claimant,
        ts = [psess.submit("mobilenet_v2", x)    # batch already in flight
              for _ in range(32)]
        outs = [t.result(timeout=120) for t in ts]
        kills = int(c.injected.get("kills", 0))
    for _ in range(100):                   # respawn is off the request
        if psess.stats()["pool"]["recycled_workers"]:   # path — let the
            break                          # supervisor land it
        time.sleep(0.05)
    st = psess.stats()
    ms = st["models"]["mobilenet_v2"]
    print(f"   {kills} worker process SIGKILLed mid-batch: "
          f"{ms['crash_redispatches']} crashed batches re-dispatched to "
          f"the survivor, {st['pool']['recycled_workers']} replacement "
          f"spawned off the request path, {len(outs)}/{len(ts)} tickets "
          f"served — zero loss")
    psess.close()


if __name__ == "__main__":
    main()
