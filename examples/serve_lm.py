"""Batched serving with KV caches across four architecture families.

    PYTHONPATH=src python examples/serve_lm.py

Prefill + greedy decode for a dense GQA model, the gemma3 local:global
pattern (ring-buffer local caches), a pure-SSM model (O(1) state), and
the whisper encoder-decoder (cross-attention KV) — the same serve_step
the decode dry-run cells lower at production scale.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve  # noqa: E402

for arch in ("qwen2-vl-2b", "gemma3-27b", "mamba2-370m", "whisper-tiny"):
    print(f"\n=== {arch} (reduced config) ===")
    serve(arch, batch=4, prompt_len=24, gen=12, smoke=True)
