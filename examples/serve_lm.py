"""LM decode on the NPU compile path: prefill + streamed greedy tokens.

    PYTHONPATH=src python examples/serve_lm.py [--families]

The decoder block stack is built as a compiler ``Graph``
(:mod:`repro.frontends.lm`), compiled once per (sequence, KV-bucket)
shape, and served by :class:`repro.api.DecodeSession`: the prompt runs
through the prefill graph, then every token replays the *same* cached
single-token plan — KV caches thread through the static graph as
inputs/outputs, so per-request state is just two arrays per layer.

``--families`` additionally runs the JAX-side serving sweep (dense GQA,
gemma3 local:global, SSM, whisper cross-attention) that this NPU path's
KV-cache contract mirrors.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import DecodeSession  # noqa: E402
from repro.obs import trace  # noqa: E402


def npu_decode(precision: str, prompt, new_tokens: int = 12) -> None:
    print(f"\n=== lm-tiny on the NPU path [{precision}] ===")
    sess = DecodeSession(precision=precision)
    with trace.session() as tr:
        t0 = time.monotonic()
        rid, tok = sess.prefill(prompt)
        t_prefill = time.monotonic() - t0
        toks = [tok]
        t0 = time.monotonic()
        toks += list(sess.stream(rid, new_tokens - 1))
        t_decode = time.monotonic() - t0
        sess.finish(rid)
    print(f"prompt {list(prompt)} -> {toks}")
    print(f"prefill {t_prefill * 1e3:.2f} ms (cold: includes the "
          f"one-time compile), decode {(new_tokens - 1) / t_decode:.1f} "
          f"tok/s")
    for shape, st in sess.stats().items():
        print(f"  model {shape}: compiled via {st['source']}, plan "
              f"builds={st['plan']['builds']} hits={st['plan']['hits']}")
    spans = [e for e in tr.events() if e[0].startswith("lm.")]
    print(f"  {len(spans)} lm.* trace spans (one per prefill/step, "
          f"all carrying the request's trace id)")


def families() -> None:
    from repro.launch.serve import serve
    for arch in ("qwen2-vl-2b", "gemma3-27b", "mamba2-370m",
                 "whisper-tiny"):
        print(f"\n=== {arch} (reduced config, JAX path) ===")
        serve(arch, batch=4, prompt_len=24, gen=12, smoke=True)


if __name__ == "__main__":
    prompt = [3, 17, 42, 5]
    npu_decode("float32", prompt)
    npu_decode("int8", prompt)
    if "--families" in sys.argv:
        families()
