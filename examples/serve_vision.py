"""Multi-model serving demo: a `repro.api.Session` precompiles two
vision models (one int8, one float32), serves a mixed-traffic request
stream, and prints per-model request stats plus program-cache tier hit
rates — including a second "process" (fresh Session + cleared in-memory
tier) that warm-starts from the on-disk artifact tier instead of
re-running the CP solver.

    PYTHONPATH=src python examples/serve_vision.py
"""
import os
import tempfile
import time

import numpy as np

import repro.api as api
from repro.core import program_cache_clear, program_cache_info

cache_dir = os.path.join(tempfile.gettempdir(), "neutron-programs")
print(f"two-tier program cache: in-process LRU + disk at {cache_dir}\n")

# ---- fleet startup: precompile both models ------------------------------
sess = api.Session(cache_dir=cache_dir)
t0 = time.monotonic()
sess.add("mobilenet_v2", precision="int8", res_scale=0.25,
         calib_samples=2, warmup=True)
sess.add("mobilenet_v1", precision="float32", res_scale=0.25, warmup=True)
print(f"precompiled 2 models in {time.monotonic() - t0:.1f}s")
for name in sess.models():
    print(sess.get(name).report(), "\n")

# ---- mixed-traffic request stream (micro-batched) ------------------------
# submit() queues requests; flush() coalesces same-model traffic into
# one batched compiled-replay-plan execution per model
sess.pin("mobilenet_v2")             # hot model: exempt from LRU evict
rng = np.random.default_rng(0)
traffic = rng.choice(["mobilenet_v2", "mobilenet_v1"], size=24,
                     p=[0.75, 0.25])
t0 = time.monotonic()
tickets = []
for name in traffic:
    h, w, c = sess.get(name).graph.inputs[0].shape
    x = rng.normal(size=(h, w, c)).astype(np.float32)
    tickets.append(sess.submit(name, x))
    if sess.queue_depth >= sess.max_batch:
        sess.flush()
sess.flush()
assert all(t.done for t in tickets)
print(f"served {len(traffic)} requests in {time.monotonic() - t0:.1f}s "
      f"(micro-batched plan replay)")
print(sess.report())

# ---- rolling redeploy: re-adding hits the in-process tier ----------------
sess.add("mobilenet_v2", precision="int8", res_scale=0.25, calib_samples=2)
sess.add("mobilenet_v1", precision="float32", res_scale=0.25)

# ---- second process: cold in-memory tier, warm disk tier -----------------
program_cache_clear(stats=False)     # simulate a fresh serving process
sess2 = api.Session(cache_dir=cache_dir)
t0 = time.monotonic()
m = sess2.add("mobilenet_v2", precision="int8", res_scale=0.25,
              calib_samples=2)
print(f"\ncold-process compile of mobilenet_v2/int8: "
      f"{time.monotonic() - t0:.2f}s via cache tier {m.cache_tier!r} "
      f"(no CP solve)")

info = program_cache_info()
mem = info["mem_hits"] / max(1, info["mem_hits"] + info["mem_misses"])
dsk = info["disk_hits"] / max(1, info["disk_hits"] + info["disk_misses"])
print(f"\nprogram-cache tiers: memory {info['mem_hits']} hits "
      f"({100 * mem:.0f}%), disk {info['disk_hits']} hits "
      f"({100 * dsk:.0f}%), {info['disk_entries']} artifacts on disk")
print(sess2.report())
