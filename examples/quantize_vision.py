"""int8 PTQ end to end: calibrate, quantize, compile, and replay a
vision model on the simulated Neutron NPU — then compare the scheduled
latency against the float32 compile of the same graph.

    PYTHONPATH=src python examples/quantize_vision.py [model]
"""
import sys

import numpy as np

from repro import quant
from repro.core import NEUTRON_2TOPS, CompilerOptions, compile_graph
from repro.core.executor import execute
from repro.core.ir import reference_execute
from repro.frontends.vision import build, build_quantized

model = sys.argv[1] if len(sys.argv) > 1 else "mobilenet_v2"

# float32 baseline
g_f, b_f = build(model, res_scale=0.5)
res_f = compile_graph(g_f, NEUTRON_2TOPS,
                      CompilerOptions(precision="float32"), cache=False)

# calibrate + quantize (min-max observers over 4 synthetic samples)
g, b, qm = build_quantized(model, res_scale=0.5, samples=4)
res_q = compile_graph(g, NEUTRON_2TOPS,
                      CompilerOptions(precision="int8"), cache=False)

f_ms, q_ms = res_f.program.latency_ms(), res_q.program.latency_ms()
print(f"{model}: float32 {f_ms:.3f} ms -> int8 {q_ms:.3f} ms "
      f"({f_ms / q_ms:.2f}x) at identical {NEUTRON_2TOPS.name}")
print(f"DDR traffic: {res_f.program.ddr_bytes()/1e6:.2f} MB -> "
      f"{res_q.program.ddr_bytes()/1e6:.2f} MB")

# replay the quantized program on the banked-TCM simulator
rng = np.random.default_rng(0)
inp = {g.inputs[0].name: rng.normal(
    size=g.inputs[0].shape).astype(np.float32)}
sem = quant.QuantSemantics(qm)
rep = execute(res_q.program, g, res_q.tiling, inp, qm.weights_f,
              semantics=sem)
print(f"quantized replay vs quantized oracle: ok={rep.ok} "
      f"(max err {rep.max_err:.2e})")

ref = reference_execute(g, inp, qm.weights_f)
for t in g.outputs:
    err = float(np.max(np.abs(rep.outputs[t.name] - ref[t.name])))
    print(f"  {t.name}: |int8 - float32 oracle| = {err:.4f} "
          f"(calibrated tol {sem.float_tolerance(t.name):.4f})")
