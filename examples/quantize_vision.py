"""int8 PTQ end to end through the public API: one `repro.api.compile`
call with ``precision="int8"`` runs calibration + quantization + the
precision-aware compile internally — then compare the scheduled latency
against the float32 compile of the same model and validate the replay.

    PYTHONPATH=src python examples/quantize_vision.py [model]
"""
import sys

import numpy as np

import repro.api as api

model = sys.argv[1] if len(sys.argv) > 1 else "mobilenet_v2"

# float32 baseline vs int8 (PTQ happens inside compile)
m_f = api.compile(model, res_scale=0.5, precision="float32", cache=False)
m_q = api.compile(model, res_scale=0.5, precision="int8",
                  calib_samples=4, cache=False)

f_ms, q_ms = m_f.program.latency_ms(), m_q.program.latency_ms()
print(f"{model}: float32 {f_ms:.3f} ms -> int8 {q_ms:.3f} ms "
      f"({f_ms / q_ms:.2f}x) at identical {m_f.cfg.name}")
print(f"DDR traffic: {m_f.program.ddr_bytes()/1e6:.2f} MB -> "
      f"{m_q.program.ddr_bytes()/1e6:.2f} MB")

# replay the quantized program on the banked-TCM simulator (checked
# against the quantized functional oracle)
rng = np.random.default_rng(0)
inp = rng.normal(size=m_q.graph.inputs[0].shape).astype(np.float32)
rep = m_q.verify(inp)
print(f"quantized replay vs quantized oracle: ok={rep.ok} "
      f"(max err {rep.max_err:.2e})")

# dequantized outputs sit inside the calibrated tolerance of the float
# oracle (the honest depth-aware bound, not an arbitrary epsilon)
from repro.core.ir import reference_execute  # noqa: E402

ref = reference_execute(m_q.graph, {m_q.graph.inputs[0].name: inp},
                        m_q.qm.weights_f)
for t in m_q.graph.outputs:
    err = float(np.max(np.abs(rep.outputs[t.name] - ref[t.name])))
    print(f"  {t.name}: |int8 - float32 oracle| = {err:.4f} "
          f"(calibrated tol {m_q.semantics.float_tolerance(t.name):.4f})")
