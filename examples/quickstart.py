"""Quickstart: compile a vision model for the Neutron NPU and run the
compiled tile program against the numpy oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (NEUTRON_2TOPS, CompilerOptions, compile_graph)
from repro.core.executor import execute
from repro.frontends.vision import build

# 1. build the model graph (MobileNetV2 at 1/4 resolution for speed)
graph, builder = build("mobilenet_v2", res_scale=0.25)
print(f"graph: {graph}")

# 2. compile with the full CP mid-end (formats + fusion + DAE schedule)
result = compile_graph(graph, NEUTRON_2TOPS, CompilerOptions())
stats = result.stats()
print(f"compiled in {stats['compile_s']:.2f}s -> "
      f"{stats['ticks']:.0f} ticks, modeled latency "
      f"{stats['latency_ms']:.3f} ms, "
      f"effective {stats['effective_tops']:.2f} TOPS "
      f"({100*stats['utilization']:.0f}% of peak), "
      f"DDR traffic {stats['ddr_mb']:.1f} MB")

# 3. run the compiled program functionally and check vs the oracle
h, w, c = graph.inputs[0].shape
image = np.random.default_rng(0).normal(size=(h, w, c)).astype(np.float32)
report = execute(result.program, graph, result.tiling,
                 {"input": image}, builder._weights)
print(f"functional check vs numpy oracle: max|err| = {report.max_err:.2e} "
      f"over {report.ticks} ticks  -> OK")

# 4. compare against the baseline (reference-stack) compiler
baseline = compile_graph(build("mobilenet_v2", res_scale=0.25)[0],
                         NEUTRON_2TOPS, CompilerOptions.baseline())
b = baseline.stats()
print(f"baseline compiler: {b['latency_ms']:.3f} ms -> "
      f"CP compiler speedup {b['latency_ms']/stats['latency_ms']:.2f}x")
