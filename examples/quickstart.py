"""Quickstart: compile a vision model for the Neutron NPU through the
public `repro.api` surface, run it on an image, check it against the
numpy oracle, and round-trip the deployable artifact.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

import repro.api as api
from repro.core import CompilerOptions

# 1. compile a model (MobileNetV2 at 1/4 resolution for speed) — one call
#    builds the graph and runs the full CP mid-end (formats + fusion +
#    DAE schedule + allocation)
model = api.compile("mobilenet_v2", res_scale=0.25)
print(model.report())

# 2. the CompiledModel is directly callable (single inputs or batches)
h, w, c = model.graph.inputs[0].shape
image = np.random.default_rng(0).normal(size=(h, w, c)).astype(np.float32)
logits = model(image)
batch = model(np.stack([image, image]))
print(f"\noutput {list(logits)[0]}: single {list(logits.values())[0].shape}"
      f", batched {list(batch.values())[0].shape}")

# 3. verify the compiled tile program against the numpy oracle
report = model.verify(image)
print(f"functional check vs numpy oracle: max|err| = {report.max_err:.2e} "
      f"over {report.ticks} ticks  -> OK")

# 4. ship it: save the versioned artifact, load it back (no recompile)
path = os.path.join(tempfile.gettempdir(), "mnv2.rpa")
model.save(path)
loaded = api.CompiledModel.load(path)
same = all(np.array_equal(loaded(image)[k], logits[k]) for k in logits)
print(f"artifact round trip {path}: outputs bit-exact = {same}")

# 5. compare against the baseline (reference-stack) compiler
baseline = api.compile("mobilenet_v2", res_scale=0.25,
                       options=CompilerOptions.baseline(), cache=False)
print(f"baseline compiler: {baseline.program.latency_ms():.3f} ms -> "
      f"CP compiler speedup "
      f"{baseline.program.latency_ms() / model.program.latency_ms():.2f}x")
