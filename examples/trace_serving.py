"""Observability tour: trace a pooled serving session, export the
Chrome trace + Prometheus metrics, and profile modeled-vs-measured.

    PYTHONPATH=src python examples/trace_serving.py

1. arms the span tracer and serves a burst of pooled requests
   (submit -> queue -> batch -> worker -> per-kernel plan steps);
2. exports ``trace_serving.json`` — open it in https://ui.perfetto.dev
   (or chrome://tracing) to see the request flow arrows hop from the
   submitting thread to the worker that served each request;
3. writes ``metrics_serving.prom`` — the session's Prometheus text
   exposition (latency/queue-wait summaries, shed/breaker/cache/worker
   counters);
4. prints ``CompiledModel.profile()`` — measured wall time per op
   against the cost model's predicted share, with the skew column
   flagging ops the model mis-prices on this backend.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import repro.api as api  # noqa: E402
from repro.obs import trace  # noqa: E402
from repro.obs.trace import validate_chrome_trace  # noqa: E402

MODEL, SCALE = "mobilenet_v2", 0.25

print("=== phase 1: traced pooled serving ===")
tracer = trace.enable()                      # arm before the traffic
with api.Session(max_batch=8, workers=2, linger_ms=1.0) as sess:
    m = sess.add(MODEL, precision="int8", res_scale=SCALE, warmup=True)
    rng = np.random.default_rng(0)
    feed = rng.normal(size=m.graph.inputs[0].shape).astype(np.float32)
    tickets = [sess.submit(MODEL, feed) for _ in range(32)]
    for t in tickets:
        t.result(timeout=60)
    print(sess.report())
    with open("metrics_serving.prom", "w") as f:
        f.write(sess.metrics())
trace.disable()

path = tracer.export("trace_serving.json")
problems = validate_chrome_trace(tracer.chrome_trace())
print(f"\n=== phase 2: exported {path} "
      f"({len(tracer)} events, {len(problems)} schema problems) ===")
print("open it in https://ui.perfetto.dev — each request's flow arrow "
      "hops from the submitting thread to its worker")
print("metrics exposition -> metrics_serving.prom")

print("\n=== phase 3: modeled vs measured (profile) ===")
prof = api.compile(MODEL, precision="int8", res_scale=SCALE).profile(
    batch=8, runs=3)
print(prof.render())
