"""Fault-tolerance drill: kill -> restore -> elastic re-mesh.

    PYTHONPATH=src python examples/fault_tolerance.py

1. trains a reduced model for 20 steps with checkpoints every 5;
2. simulates a node failure at step 20 (process state lost);
3. restores from the latest valid checkpoint and verifies the loss
   curve continues bit-identically (deterministic data pipeline);
4. simulates losing 3 of 8 hosts and plans the elastic re-mesh
   (shrunken data axis, preserved model axis).
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train_loop  # noqa: E402
from repro.runtime.fault import FaultMonitor, plan_remesh  # noqa: E402

ckpt = os.path.join(tempfile.gettempdir(), "repro_fault_demo")
shutil.rmtree(ckpt, ignore_errors=True)

print("=== phase 1: train 20 steps with checkpoints ===")
losses_a = train_loop("mamba2-370m", steps=20, smoke=True, ckpt_dir=ckpt,
                      ckpt_every=5, seq_len=128, global_batch=8,
                      log_every=5)

print("\n=== phase 2: 'node failure' -> restart from checkpoint ===")
# a fresh process restores from step 20 and continues to 30
losses_b = train_loop("mamba2-370m", steps=30, smoke=True, ckpt_dir=ckpt,
                      ckpt_every=5, seq_len=128, global_batch=8,
                      log_every=5)

print("\n=== phase 3: reference run without failure ===")
shutil.rmtree(ckpt, ignore_errors=True)
losses_c = train_loop("mamba2-370m", steps=30, smoke=True, ckpt_dir=None,
                      seq_len=128, global_batch=8, log_every=10)

resumed = losses_b[-5:]
reference = losses_c[-5:]
drift = max(abs(a - b) for a, b in zip(resumed, reference))
print(f"\nloss drift after restart vs uninterrupted run: {drift:.2e}")
assert drift < 1e-3, "restart is not deterministic!"

print("\n=== phase 4: elastic re-mesh after losing 3/8 hosts ===")
mon = FaultMonitor(n_hosts=8, timeout_s=0.01)
for h in (2, 5, 7):
    mon.mark_failed(h)
healthy = mon.healthy_hosts()
print(f"healthy hosts: {healthy}")
# 8 hosts x 32 chips = 256 chips; model axis 16 preserved
plan = plan_remesh(global_batch=256, old_data=16, model_axis=16,
                   n_healthy_chips=len(healthy) * 32)
print(f"re-mesh: {plan.old_shape} -> {plan.new_shape}; per-shard batch "
      f"{plan.batch_per_shard_old} -> {plan.batch_per_shard_new}")
print("fault-tolerance drill passed.")
