"""End-to-end LM training: ~100M-parameter model, a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the full production path — deterministic sharded data pipeline,
jit'd train_step (AdamW + cosine schedule + microbatch accumulation),
async checkpointing with restart — on a reduced mamba2 config sized to
~100M parameters.  Loss is printed every 20 steps and must decrease.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train_loop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="mamba2-370m")
    args = ap.parse_args()

    ckpt = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
    losses = train_loop(
        args.arch, steps=args.steps, smoke=True, ckpt_dir=ckpt,
        ckpt_every=50, seq_len=256, global_batch=16, n_micro=2,
        log_every=20)
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"\nmean loss first-10 {first:.4f} -> last-10 {last:.4f}")
    assert last < first, "loss did not decrease!"
    print("training works end-to-end (loss decreased).")


if __name__ == "__main__":
    main()
