"""LM decode benchmark: prefill latency + steady-state tokens/s.

The causal-operator subsystem serves transformer decode on the NPU
path: :class:`repro.api.DecodeSession` compiles the prefill and
single-token decode graphs once per (sequence, KV-bucket) shape and
then replays the *same* cached per-step plan every token.  This bench
measures, for float32 and int8:

  * **prefill latency** — prompt in, first token out (the compiled
    prefill graph at the prompt's sequence bucket);
  * **steady-state decode** — tokens/s over a greedy generation loop,
    after a short warmup, including any KV-bucket growth the loop
    crosses;
  * **parity, in-bench** — ``CompiledModel.verify`` on a live decode
    step feed (real request caches, not synthetic zeros): the compiled
    plan must reproduce the interpretive executor bit-exactly for
    float32 and within one output quantization step for int8;
  * **zero re-lowering** — after warmup every compiled model's plan
    cache must be frozen: ``builds == 1`` per model while ``hits``
    accumulate one per decode step.  A re-lowering mid-stream is a
    latency cliff, so it is a hard gate, not a statistic.

Writes ``BENCH_decode.json``.

    PYTHONPATH=src python -m benchmarks.decode_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import DecodeSession
from repro.core import NEUTRON_2TOPS
from repro.frontends import lm

PROMPT = [3, 17, 42, 5, 9, 1]


def _decode_step_parity(sess: DecodeSession, rid: str) -> float:
    """Run ``verify`` on the request's *live* decode-step feed (its
    actual caches and position) — raises on plan/interp divergence;
    returns the worst output error actually observed."""
    r = sess._requests[rid]
    m = sess.model(1, r.bucket)
    g = m.graph
    feed: Dict[str, np.ndarray] = {
        "x": sess._lm.embed(sess._emb, [r.tokens[-1]]),
        "pos": np.full((1, 1, 1), float(r.pos), np.float32)}
    feed.update(r.caches)
    rep = m.verify(feed)            # raises unless within parity tol
    assert rep.ok
    plan_out = m(feed)
    err = 0.0
    for t in g.outputs:
        err = max(err, float(np.max(np.abs(
            plan_out[t.name] - rep.outputs[t.name]))))
    return err


def bench_precision(precision: str, new_tokens: int, prefill_runs: int
                    ) -> Dict:
    sess = DecodeSession(spec=lm.tiny_spec(), precision=precision,
                         config=NEUTRON_2TOPS, cache=False)

    # compile + plan warmup on a throwaway request, then time prefill
    # on fresh requests (compile and lowering are one-time costs)
    rid0, _ = sess.prefill(PROMPT)
    sess.step(rid0)
    sess.step(rid0)
    parity_err = _decode_step_parity(sess, rid0)
    sess.finish(rid0)

    prefill_t = []
    for _ in range(max(1, prefill_runs)):
        t0 = time.monotonic()
        rid, _ = sess.prefill(PROMPT)
        prefill_t.append(time.monotonic() - t0)
        if len(prefill_t) < prefill_runs:
            sess.finish(rid)
    t_prefill = min(prefill_t)

    # steady state: every model involved is compiled/lowered by the
    # time the timed loop starts *except* grown buckets, which the
    # zero-relowering gate deliberately includes (first use builds
    # once, every later step must hit)
    builds_before = {k: s["plan"]["builds"]
                     for k, s in sess.stats().items()}
    step_t = []
    t0 = time.monotonic()
    for _ in range(new_tokens):
        t1 = time.monotonic()
        sess.step(rid)
        step_t.append(time.monotonic() - t1)
    t_loop = time.monotonic() - t0
    tokens = sess.tokens(rid)
    sess.finish(rid)

    st = sess.stats()
    builds = {k: s["plan"]["builds"] for k, s in st.items()}
    hits = sum(s["plan"]["hits"] for s in st.values())
    # warm models must not re-lower; models first used inside the loop
    # (bucket growth) build exactly once
    relower_ok = all(b == 1 for b in builds.values()) and all(
        builds[k] == builds_before[k] for k in builds_before)

    return {
        "precision": precision,
        "prompt_tokens": len(PROMPT),
        "new_tokens": new_tokens,
        "prefill_ms": round(t_prefill * 1e3, 3),
        "decode_ms_per_token": round(min(step_t) * 1e3, 3),
        "tokens_per_s": round(new_tokens / t_loop, 2),
        "parity_ok": True,           # _decode_step_parity raises if not
        "parity_err": parity_err,
        "zero_relowering": bool(relower_ok),
        "models": {k: {"builds": s["plan"]["builds"],
                       "hits": s["plan"]["hits"],
                       "source": s["source"]} for k, s in st.items()},
        "plan_hits": hits,
        "tokens_sample": tokens[len(PROMPT):len(PROMPT) + 8],
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter generation, fewer prefill repeats")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args(argv)

    new_tokens = 10 if args.quick else 40
    prefill_runs = 2 if args.quick else 4

    rows = []
    for precision in ("float32", "int8"):
        print(f"[decode_bench] lm-tiny [{precision}] prefill + "
              f"{new_tokens} tokens ...", flush=True)
        row = bench_precision(precision, new_tokens, prefill_runs)
        rows.append(row)
        print(f"  prefill {row['prefill_ms']:8.2f} ms   decode "
              f"{row['decode_ms_per_token']:6.2f} ms/tok "
              f"({row['tokens_per_s']:7.1f} tok/s)   parity "
              f"{row['parity_ok']} (err {row['parity_err']:.2e})   "
              f"relower-free {row['zero_relowering']}", flush=True)

    result = {
        "config": NEUTRON_2TOPS.name,
        "spec": lm.tiny_spec().name,
        "rows": rows,
        "all_parity_ok": all(r["parity_ok"] for r in rows),
        "zero_relowering_ok": all(r["zero_relowering"] for r in rows),
        "float32_parity_exact": bool(
            next(r for r in rows if r["precision"] == "float32")
            ["parity_err"] == 0.0),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[decode_bench] parity {result['all_parity_ok']}, "
          f"float32 exact {result['float32_parity_exact']}, "
          f"zero re-lowering {result['zero_relowering_ok']} "
          f"-> {args.out}")
    ok = (result["all_parity_ok"] and result["zero_relowering_ok"]
          and result["float32_parity_exact"])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
