"""Fusion-coverage benchmark: greedy vs capped-CP vs windowed-CP.

The compiler's fusion pass has three operating points per region:

  * **greedy**    — depth-first fused order, no CP anywhere
    (``max_cp_tiles=0, max_cp_window_tiles=0``);
  * **capped**    — the historical behaviour: joint tile-size + order CP
    for regions up to ``max_cp_tiles`` tiles, greedy above the cap
    (``max_cp_window_tiles=0``);
  * **windowed**  — the default: oversized regions are decomposed into
    overlapping windows, solved concurrently and stitched (the capped
    plan remains the per-rung fallback via the scheduler race).

This benchmark measures all three on detection-class models at full
resolution (``res_scale 1.0``, int8 PTQ — the deployment the paper's
numbers use, and the graphs whose largest fusion regions exceed the
single-CP cap), records modeled latency + DDR traffic per model and per
previously-greedy region, verifies the windowed program against the
functional oracle, and writes ``BENCH_fusion.json``:

  * ``geomean_prev_greedy_ddr_ratio`` — windowed/capped DDR restricted
    to tensors produced inside regions the capped compiler left greedy
    (target <= 0.9);
  * ``windowed_no_worse_latency`` / ``windowed_no_worse_ddr`` — windowed
    vs plain greedy, per model;
  * ``max_compile_ratio`` — windowed vs capped compile time (target
    <= 1.5: the windows solve concurrently through the existing pool);
  * ``all_oracle_ok`` — executor output stays oracle-exact.

    PYTHONPATH=src python -m benchmarks.fusion_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.api as api
from repro.core import NEUTRON_2TOPS, CompilerOptions
from repro.core.pipeline import program_cache_clear

#: (model, res_scale, precision) — full-resolution detectors: the graphs
#: whose largest regions exceed max_cp_tiles (Table IV suite).
MODELS: List[Tuple[str, float, str]] = [
    ("mobilenet_v1_ssd", 1.0, "int8"),
    ("mobilenet_v2_ssd", 1.0, "int8"),
    ("efficientdet_lite0", 1.0, "int8"),
]

QUICK_MODELS: List[Tuple[str, float, str]] = [
    ("mobilenet_v1_ssd", 0.5, "float32"),
    ("efficientdet_lite0", 0.5, "float32"),
]

#: latency/DDR "no worse" tolerance — the CP solvers run under
#: wall-clock deadlines, so repeat compiles jitter by a fraction of a
#: percent even on identical inputs.
_TOL = 1.005


def _variant_opts(mode: str) -> CompilerOptions:
    if mode == "greedy":
        return CompilerOptions(max_cp_tiles=0, max_cp_window_tiles=0)
    if mode == "capped":
        return CompilerOptions(max_cp_window_tiles=0)
    return CompilerOptions()          # windowed (defaults)


def _region_ddr(program, g, op_names) -> int:
    """Modeled DDR bytes attributable to one region: fetch/push traffic
    of tiles whose tensor is *produced* by a region op.  Parameter and
    model-input fetches are mandatory and excluded — this isolates the
    spill traffic fusion exists to remove."""
    ops = set(op_names)
    total = 0
    for tick in program.ticks:
        for j in tick.dma:
            if j.kind not in ("fetch", "push", "lfetch"):
                continue
            t = g.tensors.get(j.tile.tensor)
            if t is not None and t.producer in ops:
                total += j.nbytes
    return total


def bench_model(name: str, res_scale: float, precision: str,
                exec_check: bool = True) -> Dict:
    cfg = NEUTRON_2TOPS
    row: Dict = {"model": name, "res_scale": res_scale,
                 "precision": precision}
    models = {}
    for mode in ("greedy", "capped", "windowed"):
        program_cache_clear(stats=False)
        t0 = time.monotonic()
        m = api.compile(name, cfg, _variant_opts(mode),
                        res_scale=res_scale, precision=precision,
                        cache=False)
        dt = time.monotonic() - t0
        models[mode] = m
        s = m.program.stats()
        row[f"{mode}_latency_ms"] = round(s["latency_ms"], 4)
        row[f"{mode}_ddr_mb"] = round(s["ddr_mb"], 4)
        row[f"{mode}_compile_s"] = round(dt, 3)
    ts = models["windowed"].tiling.stats
    row["windowed_regions"] = ts.get("windowed_regions", 0)
    row["windows"] = ts.get("windows", 0)
    row["cp_regions"] = ts.get("cp_regions", 0)
    row["greedy_regions"] = ts.get("greedy_regions", 0)

    # previously-greedy regions: the greedy bucket of the *capped*
    # compile, matched into the windowed compile by op list
    cap_t = models["capped"].tiling
    win_t = models["windowed"].tiling
    win_by_ops = {tuple(r): i for i, r in enumerate(win_t.regions)}
    cap_detail = cap_t.stats.get("region_detail", [])
    regions = []
    for i, rops in enumerate(cap_t.regions):
        d = cap_detail[i] if i < len(cap_detail) else {}
        if d.get("ops", 0) <= 1 or d.get("mode") != "greedy":
            continue
        wi = win_by_ops.get(tuple(rops))
        win_mode = "unmatched"
        if wi is not None:
            win_mode = win_t.stats["region_detail"][wi].get("mode", "?")
        ddr_c = _region_ddr(models["capped"].program,
                            models["capped"].graph, rops)
        ddr_w = _region_ddr(models["windowed"].program,
                            models["windowed"].graph, rops)
        regions.append({
            "ops": d.get("ops"), "est_tiles": d.get("est_tiles"),
            "windowed_mode": win_mode,
            "ddr_capped_mb": round(ddr_c / 1e6, 4),
            "ddr_windowed_mb": round(ddr_w / 1e6, 4),
            "ddr_ratio": round(ddr_w / ddr_c, 4) if ddr_c else None,
        })
    row["prev_greedy_regions"] = regions
    row["prev_greedy_covered"] = sum(
        1 for r in regions if r["windowed_mode"] == "windowed")
    row["compile_ratio"] = round(
        row["windowed_compile_s"] / max(row["capped_compile_s"], 1e-9), 3)
    row["no_worse_latency"] = bool(
        row["windowed_latency_ms"] <= row["greedy_latency_ms"] * _TOL)
    row["no_worse_ddr"] = bool(
        row["windowed_ddr_mb"] <= row["greedy_ddr_mb"] * _TOL)

    if exec_check:
        rng = np.random.default_rng(0)
        t_in = models["windowed"].graph.inputs[0]
        rep = models["windowed"].verify(
            rng.normal(size=t_in.shape).astype(np.float32))
        row["oracle_ok"] = bool(rep.ok)
        row["oracle_max_err"] = float(rep.max_err)
    return row


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two models at 0.5 scale, float32 (smoke mode)")
    ap.add_argument("--no-exec-check", action="store_true")
    ap.add_argument("--out", default="BENCH_fusion.json")
    args = ap.parse_args(argv)

    models = QUICK_MODELS if args.quick else MODELS
    # the timed sections measure solving — keep the disk tier out
    from repro.core import program_cache_configure, program_cache_info
    saved_disk = program_cache_info()["disk_dir"]
    program_cache_configure(disk_dir=None)
    rows = []
    try:
        for name, scale, precision in models:
            print(f"[fusion_bench] {name} @ x{scale} [{precision}] ...",
                  flush=True)
            row = bench_model(name, scale, precision,
                              exec_check=not args.no_exec_check)
            rows.append(row)
            print(f"  greedy {row['greedy_latency_ms']:7.3f}ms "
                  f"{row['greedy_ddr_mb']:6.2f}MB | capped "
                  f"{row['capped_latency_ms']:7.3f}ms "
                  f"{row['capped_ddr_mb']:6.2f}MB | windowed "
                  f"{row['windowed_latency_ms']:7.3f}ms "
                  f"{row['windowed_ddr_mb']:6.2f}MB | "
                  f"{row['windowed_regions']} windowed region(s), "
                  f"compile x{row['compile_ratio']:.2f}", flush=True)
    finally:
        program_cache_configure(disk_dir=saved_disk)

    ratios = [r["ddr_ratio"] for row in rows
              for r in row["prev_greedy_regions"]
              if r["ddr_ratio"] is not None]
    geomean = math.exp(sum(math.log(max(x, 1e-9)) for x in ratios)
                       / len(ratios)) if ratios else 1.0
    result = {
        "config": NEUTRON_2TOPS.name,
        "models": rows,
        "prev_greedy_regions": len(ratios),
        "geomean_prev_greedy_ddr_ratio": round(geomean, 4),
        "models_with_windowed_coverage": sum(
            1 for r in rows if r["windowed_regions"] > 0),
        "windowed_no_worse_latency": all(r["no_worse_latency"]
                                         for r in rows),
        "windowed_no_worse_ddr": all(r["no_worse_ddr"] for r in rows),
        "max_compile_ratio": max(r["compile_ratio"] for r in rows),
        "all_oracle_ok": all(r.get("oracle_ok", True) for r in rows),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[fusion_bench] geomean prev-greedy region DDR ratio "
          f"{geomean:.3f} over {len(ratios)} region(s), "
          f"no-worse latency={result['windowed_no_worse_latency']} "
          f"ddr={result['windowed_no_worse_ddr']}, compile ratio "
          f"<= {result['max_compile_ratio']:.2f} -> {args.out}")
    if not result["all_oracle_ok"]:
        print("[fusion_bench] FAIL: windowed executor diverged from the "
              "reference oracle", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
