"""Serving benchmark: compiled replay plans vs the interpretive executor.

For each benchmarked vision model (float32 and int8) this measures

  * **single-request latency** — the interpretive executor (the
    validating replay: per-tick dict lookups, tile gathers, residency
    checks) against the compiled replay plan (:mod:`repro.core.
    execplan`: preplanned gathers/scatters, pre-gathered weights,
    preallocated arena);
  * **batched throughput** — requests/s of one batch-8 plan replay
    (``CompiledModel.run_many``) against the interpretive executor's
    one-at-a-time serving rate;
  * **parity** — plan outputs are asserted against the interpretive
    executor in-bench: bit-exact for float32, within one output
    quantization step for int8 (in practice the integers match
    exactly);
  * **DDR accounting** — both engines must report the same *per-request*
    modeled DDR bytes (batched plan replay reports per-request, not
    per-batch-aggregate, traffic).

Acceptance gates (int8 rows): >= 3x geomean single-request speedup and
>= 8x geomean batch-8 requests/s vs the interpretive executor.

Writes ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.api as api
from repro.core import NEUTRON_2TOPS

#: serving regime: quarter-resolution inputs (edge camera previews) —
#: latency here is interpreter/bookkeeping-bound, which is exactly the
#: overhead the plan engine exists to remove.
MODELS: List[Tuple[str, float]] = [
    ("mobilenet_v1", 0.25),
    ("mobilenet_v2", 0.25),
    ("mobilenet_v3_min", 0.25),
    ("efficientnet_lite0", 0.25),
    ("resnet50_v1", 0.25),
]

QUICK_MODELS: List[Tuple[str, float]] = [
    ("mobilenet_v1", 0.25),
    ("mobilenet_v2", 0.25),
]

BATCH = 8


def _geomean(vals: List[float]) -> float:
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def bench_model(name: str, res_scale: float, precision: str,
                interp_runs: int, plan_runs: int) -> Dict:
    cfg = NEUTRON_2TOPS
    m = api.compile(name, cfg, precision=precision, res_scale=res_scale,
                    cache=False)
    rng = np.random.default_rng(1234)
    t_in = m.graph.inputs[0]
    x = rng.normal(size=t_in.shape).astype(np.float32)

    # interpretive executor: single-request serving latency
    interp_t = []
    for _ in range(interp_runs):
        t0 = time.monotonic()
        interp_out = m(x, engine="interp")
        interp_t.append(time.monotonic() - t0)
    t_interp = min(interp_t)

    # compiled replay plan: single request
    m.plan_for(1)                    # lowering time excluded (one-time)
    m(x)                             # warmup: arena first-touch etc.
    plan_t = []
    for _ in range(plan_runs):
        t0 = time.monotonic()
        plan_out = m(x)
        plan_t.append(time.monotonic() - t0)
    t_plan = min(plan_t)

    # parity, asserted in-bench: the plan must reproduce the
    # interpretive executor (bit-exact float32; <= 1 quant step int8)
    parity_ok = True
    parity_err = 0.0
    for t in m.graph.outputs:
        err = float(np.max(np.abs(plan_out[t.name]
                                  - interp_out[t.name])))
        tol = m.semantics.plan_parity_tol(t.name)
        parity_err = max(parity_err, err)
        parity_ok = parity_ok and err <= tol
    assert parity_ok, (
        f"{name} [{precision}]: plan replay diverged from the "
        f"interpretive executor (max|err|={parity_err:.3e})")

    # per-request DDR accounting must agree across engines
    rep_interp = m.verify(x)         # interpretive + plan cross-check
    plan = m.plan_for(1)
    ddr_ok = rep_interp.ddr_bytes == plan.ddr_bytes_per_request

    # batched throughput: one batch-8 plan replay vs one-at-a-time
    # interpretive serving
    reqs = [rng.normal(size=t_in.shape).astype(np.float32)
            for _ in range(BATCH)]
    m.run_many(reqs)                 # builds the batch-8 plan
    batch_t = []
    for _ in range(plan_runs):
        t0 = time.monotonic()
        outs = m.run_many(reqs)
        batch_t.append(time.monotonic() - t0)
    t_batch = min(batch_t)
    # spot-check one batched request against the interpreter
    ref = m(reqs[3], engine="interp")
    for t in m.graph.outputs:
        err = float(np.max(np.abs(outs[3][t.name] - ref[t.name])))
        assert err <= m.semantics.plan_parity_tol(t.name), (
            f"{name} [{precision}]: batched replay diverged "
            f"(max|err|={err:.3e})")

    interp_rps = 1.0 / t_interp
    batch_rps = BATCH / t_batch
    return {
        "model": name,
        "precision": precision,
        "res_scale": res_scale,
        "interp_ms": round(t_interp * 1e3, 3),
        "plan_ms": round(t_plan * 1e3, 3),
        "speedup_single": round(t_interp / t_plan, 3),
        "interp_req_s": round(interp_rps, 2),
        "batch8_req_s": round(batch_rps, 2),
        "speedup_batch8": round(batch_rps / interp_rps, 3),
        "parity_ok": bool(parity_ok),
        "parity_err": parity_err,
        "ddr_per_request_ok": bool(ddr_ok),
        "ddr_mb_per_request": round(plan.ddr_bytes_per_request / 1e6, 3),
        "plan_kernels": len(plan.steps),
        "plan_build_ms": round(plan.build_s * 1e3, 2),
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two small models, fewer timing runs")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    models = QUICK_MODELS if args.quick else MODELS
    interp_runs = 2 if args.quick else 3
    plan_runs = 5

    rows = []
    for name, scale in models:
        for precision in ("float32", "int8"):
            print(f"[serve_bench] {name} @ x{scale} [{precision}] ...",
                  flush=True)
            row = bench_model(name, scale, precision,
                              interp_runs, plan_runs)
            rows.append(row)
            print(f"  interp {row['interp_ms']:8.2f} ms   plan "
                  f"{row['plan_ms']:7.2f} ms "
                  f"({row['speedup_single']:5.2f}x)   batch{BATCH} "
                  f"{row['batch8_req_s']:8.1f} req/s "
                  f"({row['speedup_batch8']:5.2f}x)   parity "
                  f"{row['parity_ok']}", flush=True)

    int8_rows = [r for r in rows if r["precision"] == "int8"]
    geo_single = _geomean([r["speedup_single"] for r in int8_rows])
    geo_batch = _geomean([r["speedup_batch8"] for r in int8_rows])
    result = {
        "config": NEUTRON_2TOPS.name,
        "batch": BATCH,
        "models": rows,
        "geomean_speedup_single_int8": round(geo_single, 3),
        "geomean_speedup_batch8_int8": round(geo_batch, 3),
        "meets_3x_single": bool(geo_single >= 3.0),
        "meets_8x_batch8": bool(geo_batch >= 8.0),
        "all_parity_ok": all(r["parity_ok"] for r in rows),
        "all_ddr_per_request_ok": all(r["ddr_per_request_ok"]
                                      for r in rows),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[serve_bench] int8 geomean: single {geo_single:.2f}x "
          f"(target >= 3x), batch{BATCH} {geo_batch:.2f}x "
          f"(target >= 8x) -> {args.out}")
    correctness_ok = (result["all_parity_ok"]
                      and result["all_ddr_per_request_ok"])
    speed_ok = result["meets_3x_single"] and result["meets_8x_batch8"]
    if not correctness_ok:
        print("[serve_bench] FAIL: parity or DDR accounting not met",
              file=sys.stderr)
        return 1
    if not speed_ok:
        if args.quick:
            # quick smoke gates correctness only: two models and few
            # timing runs on a shared CI box make the speed geomeans
            # noisy (CPU-quota throttling), while the full bench run
            # that produces the committed BENCH_serve.json enforces them
            print("[serve_bench] WARNING: quick-mode speed targets "
                  "missed (noisy box?) — full bench enforces them",
                  file=sys.stderr)
            return 0
        print("[serve_bench] FAIL: speedup targets not met",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
