"""Compile-latency benchmark: seed hot path vs overhauled hot path.

For each benchmarked vision-frontend model this times

  * **seed**  — the PR-0 compiler hot path: full-rescan CP engine
    (``cpsolver.solve_reference``), serial partition solving, no cost
    memoization, no program cache;
  * **new**   — the overhauled path: incremental CP engine, concurrent
    partition windows, memoized cost model (cold program cache);
  * **cached** — a repeat compile through the content-addressed
    compiled-program cache (the zero-recompile serving path);

verifies the new program against the pure-numpy ``reference_execute``
oracle, compares scheduled latency (the Eq. 8 objective), and writes
``BENCH_compile.json`` with per-model numbers plus the geometric-mean
compile-time speedup.

    PYTHONPATH=src python -m benchmarks.compile_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.api as api
from repro.core import NEUTRON_2TOPS, CompilerOptions, compile_graph
from repro.core import npu as npu_mod
from repro.core.pipeline import program_cache_clear
from repro.frontends.vision import build

#: (model, res_scale) — ordered small to large; resnet50_v1 is the
#: largest graph the acceptance target is measured on.
MODELS: List[Tuple[str, float]] = [
    ("mobilenet_v1", 0.5),
    ("mobilenet_v2", 0.5),
    ("mobilenet_v3_min", 0.5),
    ("efficientnet_lite0", 0.5),
    ("resnet50_v1", 0.5),
]

QUICK_MODELS: List[Tuple[str, float]] = [
    ("mobilenet_v1", 0.25),
    ("mobilenet_v2", 0.25),
]


def bench_model(name: str, res_scale: float, exec_check: bool = True
                ) -> Dict:
    cfg = NEUTRON_2TOPS

    # --- seed hot path (cost memo off, serial, reference engine) ---
    g_seed, _ = build(name, res_scale=res_scale)
    npu_mod.set_cost_memo(False)
    try:
        t0 = time.monotonic()
        seed = compile_graph(g_seed, cfg, CompilerOptions.seed_solver(),
                             cache=False)
        seed_s = time.monotonic() - t0
    finally:
        npu_mod.set_cost_memo(True)

    # --- overhauled hot path via the public API (cold program cache) ---
    program_cache_clear()
    t0 = time.monotonic()
    new = api.compile(name, cfg, res_scale=res_scale)
    new_s = time.monotonic() - t0
    assert not new.result.cache_hit

    # --- repeat compile: content-addressed program-cache hit ---
    t0 = time.monotonic()
    hit = api.compile(name, cfg, res_scale=res_scale)
    cached_s = time.monotonic() - t0
    assert hit.result.cache_hit and hit.program is new.program
    assert hit.cache_tier == "memory"

    row = {
        "model": name,
        "res_scale": res_scale,
        "ops": len(new.graph.ops),
        "sched_steps": len(new.tiling.order),
        "seed_compile_s": round(seed_s, 4),
        "new_compile_s": round(new_s, 4),
        "cached_compile_s": round(cached_s, 6),
        "compile_speedup": round(seed_s / new_s, 3),
        "seed_latency_ms": round(seed.program.latency_ms(), 5),
        "new_latency_ms": round(new.program.latency_ms(), 5),
        "latency_ratio": round(new.program.latency_ms()
                               / seed.program.latency_ms(), 5),
    }

    if exec_check:
        rng = np.random.default_rng(0)
        t_in = new.graph.inputs[0]
        rep = new.verify(rng.normal(size=t_in.shape).astype(np.float32))
        row["oracle_ok"] = bool(rep.ok)
        row["oracle_max_err"] = float(rep.max_err)
    return row


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two small models at 0.25 scale (smoke mode)")
    ap.add_argument("--no-exec-check", action="store_true",
                    help="skip the executor-vs-oracle verification")
    ap.add_argument("--out", default="BENCH_compile.json")
    args = ap.parse_args(argv)

    models = QUICK_MODELS if args.quick else MODELS
    rows = []
    # the timed section measures *solving*, so the disk cache tier (if
    # the process enabled one) must not serve these compiles
    from repro.core import program_cache_configure, program_cache_info
    saved_disk = program_cache_info()["disk_dir"]
    program_cache_configure(disk_dir=None)
    try:
        for name, scale in models:
            print(f"[compile_bench] {name} @ x{scale} ...", flush=True)
            row = bench_model(name, scale,
                              exec_check=not args.no_exec_check)
            rows.append(row)
            print(f"  seed {row['seed_compile_s']:7.2f}s   "
                  f"new {row['new_compile_s']:6.2f}s   "
                  f"cached {row['cached_compile_s']*1e3:7.2f}ms   "
                  f"speedup {row['compile_speedup']:5.2f}x   "
                  f"latency ratio {row['latency_ratio']:.4f}", flush=True)
    finally:
        program_cache_configure(disk_dir=saved_disk)

    geomean = math.exp(sum(math.log(r["compile_speedup"]) for r in rows)
                       / len(rows))
    worst_latency = max(r["latency_ratio"] for r in rows)
    result = {
        "config": NEUTRON_2TOPS.name,
        "models": rows,
        "geomean_compile_speedup": round(geomean, 3),
        "worst_latency_ratio": round(worst_latency, 5),
        "all_oracle_ok": all(r.get("oracle_ok", True) for r in rows),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[compile_bench] geomean compile speedup "
          f"{geomean:.2f}x, worst latency ratio {worst_latency:.4f} "
          f"-> {args.out}")
    if not result["all_oracle_ok"]:
        print("[compile_bench] FAIL: executor diverged from the "
              "reference oracle", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
