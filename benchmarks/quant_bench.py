"""Quantization benchmark: int8/int4 PTQ vs float32 at identical silicon.

For each benchmarked vision model this

  * compiles the float32 model and the int8-PTQ model (and an int4-weight
    variant) through the public ``repro.api`` surface at the same
    ``NPUConfig`` and compares scheduled latency (the Eq. 8 objective) —
    the paper's MAC arrays, TCM and DMA are sized for quantized tensors,
    so int8 should win well past the 1.5x acceptance bar;
  * replays the quantized program on the banked-TCM simulator
    (``CompiledModel.verify``) and checks it against the quantized
    functional oracle (exact to one output quantization step) and the
    float32 oracle (within the calibrated tolerance);
  * reports accuracy deltas: worst-output error vs the float oracle in
    units of the calibrated tolerance, plus top-1 argmax agreement for
    the classifier heads.

Writes ``BENCH_quant.json``.

    PYTHONPATH=src python -m benchmarks.quant_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.api as api
from repro import quant
from repro.core import NEUTRON_2TOPS
from repro.core.ir import reference_execute

MODELS: List[Tuple[str, float]] = [
    ("mobilenet_v1", 0.5),
    ("mobilenet_v2", 0.5),
    ("mobilenet_v3_min", 0.5),
    ("efficientnet_lite0", 0.5),
    ("resnet50_v1", 0.5),
]

QUICK_MODELS: List[Tuple[str, float]] = [
    ("mobilenet_v1", 0.25),
    ("mobilenet_v2", 0.25),
]


def bench_model(name: str, res_scale: float, samples: int = 2,
                exec_check: bool = True) -> Dict:
    cfg = NEUTRON_2TOPS

    # float32 baseline / int8 PTQ / int4-weight variant — precision (and
    # the PTQ flow for the quantized builds) is resolved inside compile;
    # the int4 variant reuses the int8 run's calibration table (tensor
    # names match across build() clones), skipping a second float sweep
    m_f = api.compile(name, cfg, precision="float32",
                      res_scale=res_scale, cache=False)
    m_q = api.compile(name, cfg, precision="int8", res_scale=res_scale,
                      calib_samples=samples, cache=False)
    m_4 = api.compile(name, cfg, precision="int8", res_scale=res_scale,
                      calib_samples=samples, weight_dtype="int4",
                      calibration=m_q.calibration, cache=False)
    float_ms = m_f.program.latency_ms()
    int8_ms = m_q.program.latency_ms()
    int4_ms = m_4.program.latency_ms()

    row = {
        "model": name,
        "res_scale": res_scale,
        "ops": len(m_q.graph.ops),
        "float_ms": round(float_ms, 5),
        "int8_ms": round(int8_ms, 5),
        "int4w_ms": round(int4_ms, 5),
        "speedup_int8": round(float_ms / int8_ms, 3),
        "speedup_int4w": round(float_ms / int4_ms, 3),
        "float_ddr_mb": round(m_f.program.ddr_bytes() / 1e6, 3),
        "int8_ddr_mb": round(m_q.program.ddr_bytes() / 1e6, 3),
    }

    if exec_check:
        # held-out input: the calibration draws came from rng seed 0,
        # so the accuracy check must not reuse that stream
        g_q, qm, sem = m_q.graph, m_q.qm, m_q.semantics
        rng = np.random.default_rng(1234)
        inp = {g_q.inputs[0].name: rng.normal(
            size=g_q.inputs[0].shape).astype(np.float32)}
        rep = m_q.verify(inp)
        row["replay_vs_qoracle_ok"] = bool(rep.ok)
        row["replay_vs_qoracle_err"] = float(rep.max_err)

        # accuracy vs the float oracle, in calibrated-tolerance units
        ref = reference_execute(g_q, inp, qm.weights_f)
        qref = quant.quantized_reference_execute(qm, inp)
        worst = 0.0
        argmax_match = None
        within = True
        for t in g_q.outputs:
            got = quant.dequantize(qref[t.name], qm.qp(t.name))
            err = float(np.max(np.abs(got - ref[t.name])))
            tol = sem.float_tolerance(t.name)
            worst = max(worst, err / tol)
            within = within and err <= tol
            if t.shape == (1, 1, t.shape[-1]):  # classifier logits
                argmax_match = bool(np.argmax(got) == np.argmax(ref[t.name]))
        row["float_oracle_within_tol"] = bool(within)
        row["float_oracle_worst_tol_frac"] = round(worst, 4)
        if argmax_match is not None:
            row["top1_argmax_match"] = argmax_match
    return row


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two small models at 0.25 scale (smoke mode)")
    ap.add_argument("--no-exec-check", action="store_true")
    ap.add_argument("--samples", type=int, default=2,
                    help="calibration sample count")
    ap.add_argument("--out", default="BENCH_quant.json")
    args = ap.parse_args(argv)

    models = QUICK_MODELS if args.quick else MODELS
    rows = []
    for name, scale in models:
        print(f"[quant_bench] {name} @ x{scale} ...", flush=True)
        row = bench_model(name, scale, samples=args.samples,
                          exec_check=not args.no_exec_check)
        rows.append(row)
        print(f"  float {row['float_ms']:9.3f} ms   "
              f"int8 {row['int8_ms']:8.3f} ms ({row['speedup_int8']:5.2f}x)"
              f"   int4w {row['int4w_ms']:8.3f} ms "
              f"({row['speedup_int4w']:5.2f}x)   "
              f"parity {row.get('replay_vs_qoracle_ok', '-')}", flush=True)

    geomean = math.exp(sum(math.log(r["speedup_int8"]) for r in rows)
                       / len(rows))
    min_speedup = min(r["speedup_int8"] for r in rows)
    result = {
        "config": NEUTRON_2TOPS.name,
        "models": rows,
        "geomean_speedup_int8": round(geomean, 3),
        "min_speedup_int8": round(min_speedup, 3),
        "meets_1p5x_target": bool(min_speedup >= 1.5),
        "all_parity_ok": all(r.get("replay_vs_qoracle_ok", True)
                             for r in rows),
        "all_within_calibrated_tol": all(
            r.get("float_oracle_within_tol", True) for r in rows),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[quant_bench] geomean int8 speedup {geomean:.2f}x "
          f"(min {min_speedup:.2f}x, target >= 1.5x) -> {args.out}")
    ok = (result["meets_1p5x_target"] and result["all_parity_ok"]
          and result["all_within_calibrated_tol"])
    if not ok:
        print("[quant_bench] FAIL: target or parity not met",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
