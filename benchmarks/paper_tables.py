"""Paper-table reproductions on the simulated-NPU backend.

  * Table I   — effective TOPS of ours vs the baseline-compiler NPU
  * Table II  — CP problem partitioning: compile time vs inference time
  * Table III — latency + LTP across the vision suite: ours vs eNPU-A
                (equal resources, baseline compiler) vs eNPU-B (2x
                resources, baseline compiler)
  * Fig. 6    — TCM memory-over-time with and without fusion+tiling
  * §VI       — GenAI (transformer-block) speedup vs a scalar-core model

"Ours" is the full CP stack (two formats + fusion CP + DAE scheduling);
"eNPU-X" is the same machine model driven by the baseline compiler
(single format, layer-by-layer, serialized DMA/compute) — the behavior
Table I attributes to the reference stacks.  Reported speedups are
therefore compiler-for-compiler at identical silicon, the paper's own
controlled comparison.

All tables run at the paper's deployment precision: graphs are cast to
int8 (repro.quant.cast_graph — dtype annotation only; the latency model
is what these tables measure) so MAC throughput, tile bytes and DMA
volumes match the INT8 numbers the paper reports.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.api as api
from repro.core import (ENPU_A, ENPU_B, NEUTRON_2TOPS, CompileResult,
                        CompilerOptions, cycles_to_ms, effective_tops)
from repro.frontends.vision import VISION_MODELS, build, table4_targets

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "paper")


#: models small enough to compile at full resolution quickly; the YOLO
#: family runs at misc-scale via res_scale (noted in the output).
TABLE3_MODELS = [
    ("mobilenet_v1", 1.0), ("mobilenet_v2", 1.0),
    ("mobilenet_v3_min", 1.0), ("resnet50_v1", 1.0),
    ("efficientnet_lite0", 1.0), ("efficientdet_lite0", 1.0),
    ("mobilenet_v1_ssd", 1.0), ("mobilenet_v2_ssd", 1.0),
    ("yolov8n_det", 0.5), ("yolov8n_seg", 0.5), ("yolov8s_det", 0.5),
    ("damo_yolo_nl", 0.5),
]


def _compile(name: str, res_scale: float, cfg, opts: CompilerOptions
             ) -> Tuple[CompileResult, float]:
    from repro.quant import cast_graph
    g, _ = build(name, res_scale=res_scale)
    cast_graph(g)                     # the paper benchmarks INT8 models
    t0 = time.monotonic()
    # cache=False: these tables *measure* compile time — a program-cache
    # hit on a repeated run would report the lookup, not the compile
    res = api.compile(g, cfg, opts, cache=False).result
    return res, time.monotonic() - t0


@dataclass
class Row:
    model: str
    res_scale: float
    ours_ms: float
    enpu_a_ms: float
    enpu_b_ms: float
    speedup_vs_a: float
    speedup_vs_b: float
    ours_ltp: float
    enpu_a_ltp: float
    enpu_b_ltp: float
    ours_eff_tops: float
    enpu_a_eff_tops: float


def bench_table3(models=None, verbose: bool = True) -> List[Row]:
    rows: List[Row] = []
    for name, rs in (models or TABLE3_MODELS):
        ours, _ = _compile(name, rs, NEUTRON_2TOPS, CompilerOptions())
        base_a, _ = _compile(name, rs, ENPU_A, CompilerOptions.baseline())
        base_b, _ = _compile(name, rs, ENPU_B, CompilerOptions.baseline())
        o = ours.program.stats()
        a = base_a.program.stats()
        b = base_b.program.stats()
        row = Row(
            model=name, res_scale=rs,
            ours_ms=o["latency_ms"], enpu_a_ms=a["latency_ms"],
            enpu_b_ms=b["latency_ms"],
            speedup_vs_a=a["latency_ms"] / o["latency_ms"],
            speedup_vs_b=b["latency_ms"] / o["latency_ms"],
            ours_ltp=o["latency_ms"] * NEUTRON_2TOPS.peak_tops,
            enpu_a_ltp=a["latency_ms"] * ENPU_A.peak_tops,
            enpu_b_ltp=b["latency_ms"] * ENPU_B.peak_tops,
            ours_eff_tops=o["effective_tops"],
            enpu_a_eff_tops=a["effective_tops"],
        )
        rows.append(row)
        if verbose:
            print(f"  {name:20s}(x{rs:3.1f}) ours {row.ours_ms:8.2f} ms"
                  f" | eNPU-A {row.enpu_a_ms:8.2f} ms ({row.speedup_vs_a:4.2f}x)"
                  f" | eNPU-B {row.enpu_b_ms:8.2f} ms ({row.speedup_vs_b:4.2f}x)"
                  f" | LTP {row.ours_ltp:7.1f} vs {row.enpu_a_ltp:7.1f}"
                  f"/{row.enpu_b_ltp:7.1f}", flush=True)
    gm_a = float(np.exp(np.mean([np.log(r.speedup_vs_a) for r in rows])))
    gm_b = float(np.exp(np.mean([np.log(r.speedup_vs_b) for r in rows])))
    best_ltp = all(r.ours_ltp <= min(r.enpu_a_ltp, r.enpu_b_ltp) + 1e-9
                   for r in rows)
    if verbose:
        print(f"  mean speedup vs eNPU-A {gm_a:.2f}x (paper: 1.8x), "
              f"vs eNPU-B {gm_b:.2f}x (paper: 1.3x); "
              f"best LTP everywhere: {best_ltp}")
    _save("table3", {"rows": [asdict(r) for r in rows],
                     "mean_speedup_vs_a": gm_a,
                     "mean_speedup_vs_b": gm_b,
                     "best_ltp_everywhere": best_ltp})
    return rows


def bench_table1(verbose: bool = True) -> Dict:
    """Effective TOPS on ResNet50V1 / EfficientNet-Lite0 (paper Table I
    measures how far real NPUs fall below peak)."""
    out = {}
    for name in ("resnet50_v1", "efficientnet_lite0"):
        ours, _ = _compile(name, 1.0, NEUTRON_2TOPS, CompilerOptions())
        base, _ = _compile(name, 1.0, ENPU_A, CompilerOptions.baseline())
        out[name] = {
            "peak_tops": NEUTRON_2TOPS.peak_tops,
            "ours_effective_tops": ours.program.stats()["effective_tops"],
            "baseline_effective_tops":
                base.program.stats()["effective_tops"],
        }
        if verbose:
            o = out[name]
            print(f"  {name:20s} peak {o['peak_tops']:.2f} | "
                  f"ours {o['ours_effective_tops']:.3f} | "
                  f"baseline-compiler {o['baseline_effective_tops']:.3f}")
    _save("table1", out)
    return out


def bench_table2(model: str = "yolov8n_det", res_scale: float = 0.4,
                 verbose: bool = True) -> Dict:
    """Partitioning ablation (paper Table II): compile time vs modeled
    inference time for the 2x2 {partition, monolithic} x phases grid."""
    variants = {
        "no_partitioning": CompilerOptions(partition=False,
                                           cp_time_limit_s=2.0,
                                           monolithic_time_limit_s=30.0),
        "both_partitioned": CompilerOptions(partition=True,
                                            cp_time_limit_s=0.5),
    }
    out = {}
    for nm, opts in variants.items():
        res, wall = _compile(model, res_scale, NEUTRON_2TOPS, opts)
        out[nm] = {"compile_s": wall,
                   "inference_ms": res.program.stats()["latency_ms"]}
        if verbose:
            print(f"  {nm:18s} compile {wall:7.2f} s   "
                  f"inference {out[nm]['inference_ms']:7.2f} ms")
    if verbose:
        c0 = out["no_partitioning"]
        c1 = out["both_partitioned"]
        print(f"  compile-time cut {100*(1-c1['compile_s']/c0['compile_s']):.0f}% "
              f"(paper: ~81%), inference cost "
              f"{100*(c1['inference_ms']/c0['inference_ms']-1):+.1f}% "
              f"(paper: ~+3.3%)")
    _save("table2", out)
    return out


def bench_fig6(model: str = "mobilenet_v2", verbose: bool = True) -> Dict:
    """Memory-over-time with vs without fusion+tiling (paper Fig. 6)."""
    from repro.quant import cast_graph
    g, _ = build(model)
    cast_graph(g)
    with_f = api.compile(g, NEUTRON_2TOPS, CompilerOptions())
    g2, _ = build(model)
    cast_graph(g2)
    # "without" = the paper's comparison point: naive tile bounds and
    # layer-by-layer order (no fusion), DAE overlap unchanged
    no_f = api.compile(g2, NEUTRON_2TOPS,
                       CompilerOptions(fusion=False, overlap=True,
                                       naive_tiling=True))
    tl_f = with_f.program.memory_timeline()
    tl_n = no_f.program.memory_timeline()
    sf, sn = with_f.program.stats(), no_f.program.stats()
    out = {
        "with_fusion_peak_banks": max(tl_f) if tl_f else 0,
        "without_fusion_peak_banks": max(tl_n) if tl_n else 0,
        "with_fusion_mean_banks": float(np.mean(tl_f)) if tl_f else 0,
        "without_fusion_mean_banks": float(np.mean(tl_n)) if tl_n else 0,
        # the paper's point is the *off-chip* consequence of the on-chip
        # profile: fused execution keeps intermediates out of DRAM
        "with_fusion_ddr_mb": sf["ddr_mb"],
        "without_fusion_ddr_mb": sn["ddr_mb"],
        "with_fusion_ms": sf["latency_ms"],
        "without_fusion_ms": sn["latency_ms"],
        "timeline_with": tl_f[:400],
        "timeline_without": tl_n[:400],
    }
    if verbose:
        print(f"  mean banks {out['with_fusion_mean_banks']:.1f} vs "
              f"{out['without_fusion_mean_banks']:.1f} | DDR "
              f"{out['with_fusion_ddr_mb']:.1f} vs "
              f"{out['without_fusion_ddr_mb']:.1f} MB | latency "
              f"{out['with_fusion_ms']:.2f} vs "
              f"{out['without_fusion_ms']:.2f} ms")
    _save("fig6", out)
    return out


def bench_genai(verbose: bool = True) -> Dict:
    """§VI: transformer matmuls on the NPU vs 4x Cortex-A55 at 1.8x clock.

    A55: 2x 128-bit NEON pipes -> 16 int8 MACs/cycle/core; 4 cores at
    1.8 GHz ~ 0.23 TOPS peak, ~60% sustained on GEMM.  The NPU runs the
    same (batch=1) decoder-block GEMMs through the compiler."""
    from repro.core.ir import GraphBuilder
    # matrix-matrix regime (prefill block of 64 tokens), as §VI states —
    # batch-1 single-token GEMV is DDR-bound on BOTH sides and
    # uninformative.  Tokens map to the H dimension (paper §IV-A).
    d_model, d_ff, seq = 768, 3072, 64
    b = GraphBuilder("genai_block")
    x = b.input((seq, 1, d_model))
    for blk in range(4):
        q = b.conv(x, d_model, k=1)
        o = b.conv(q, d_model, k=1)
        h = b.conv(o, d_ff, k=1, act="gelu")
        x = b.conv(h, d_model, k=1)
    b.mark_output(x)
    g = b.build()
    from repro.quant import cast_graph
    cast_graph(g)                     # int8 GEMMs on both sides (§VI)
    res = api.compile(g, NEUTRON_2TOPS, CompilerOptions())
    npu_ms = res.program.stats()["latency_ms"]
    macs = g.total_macs()
    a55_macs_per_s = 4 * 16 * 1.8e9 * 0.6
    w_bytes = g.total_param_bytes()
    cpu_ms = max(macs / a55_macs_per_s,
                 w_bytes / 8e9) * 1e3          # A55 cluster DDR ~8 GB/s
    out = {"npu_ms": npu_ms, "cpu_ms": cpu_ms,
           "speedup": cpu_ms / npu_ms, "gmacs": macs / 1e9}
    if verbose:
        print(f"  GEMM block: NPU {npu_ms:.3f} ms vs 4xA55 {cpu_ms:.3f} "
              f"ms -> {out['speedup']:.1f}x (paper: ~10x)")
    _save("genai", out)
    return out


def _save(name: str, obj: Dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


def run_all():
    print("[Table I] effective TOPS")
    bench_table1()
    print("[Table III] latency + LTP across the vision suite")
    bench_table3()
    print("[Table II] CP partitioning")
    bench_table2()
    print("[Fig 6] fusion memory profile")
    bench_fig6()
    print("[§VI] GenAI GEMM speedup")
    bench_genai()


if __name__ == "__main__":
    run_all()
