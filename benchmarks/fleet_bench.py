"""Fleet-serving benchmark: replicated pools under replica death,
stalls, and silent corruption.

Drives a :class:`repro.runtime.fleet.Fleet` (N replica Sessions, each
its own worker pool) with **open-loop bursty traffic** while
:mod:`repro.runtime.chaos` injects one fault class per scenario:

  * ``baseline``       — fault-free fleet traffic (throughput + p99
    reference; exports the fleet Chrome trace + metrics);
  * ``unhedged_stalls``— closed-loop traffic while workers randomly
    stall mid-batch with the pool supervisor *disabled* (long
    heartbeat): the client tail eats every stall.  Closed-loop is the
    regime where hedging is honest — in a saturated open loop the tail
    is queueing, and a hedge there only duplicates load;
  * ``hedged_stalls``  — identical stall schedule, hedging on: the
    router re-issues slow requests to the other replica after the
    p99-derived timeout, and the **hedged p99 must not exceed the
    unhedged p99** (the speculative-execution payoff, gated);
  * ``replica_kill``   — whole replica pools die mid-burst: queued
    attempts fail over to survivors with backoff, dead replicas recycle
    in the background, **zero ticket loss**;
  * ``bitflip``        — one replica silently flips output bits (no
    error is ever raised): the sampling auditor's interp-oracle
    re-execution must catch it and **quarantine the replica** (gated).

After the scenarios, a rolling-update drill (canary-verified swap of
every replica, then a chaos-corrupted canary that must *reject* with
zero replicas swapped) and a paired fleet-vs-single-pool throughput
measurement (equal total workers; the fleet layer's routing tax is
gated at ``FLEET_RATIO_FLOOR``).

The fleet robustness contract mirrors the pool-level one, one layer
up: **every fleet ticket terminates** — with a result or a typed
error — under every scenario, and corruption that never raises is
still caught and contained.

Writes ``BENCH_fleet.json``.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

import repro.api as api
import repro.runtime.chaos as chaos
from repro.api import (DeadlineExceeded, Overloaded, UpdateRejected,
                       WorkerLost)
from repro.obs import trace as obs_trace
from repro.obs.trace import validate_chrome_trace

MODEL = ("mobilenet_v2", 0.25)     # same serving regime as robust_bench
BATCH = 4
REPLICAS = 2
WORKERS = 2                        # per replica; the single-pool
                                   # comparator gets REPLICAS * WORKERS

#: event names the exported fleet trace must contain — every routing
#: decision leaves a mark, and the hedged scenario must show the
#: hedge machinery actually firing
REQUIRED_FLEET_EVENTS = ("fleet_route",)
REQUIRED_HEDGE_EVENTS = ("fleet_hedge", "fleet_hedge_win")


def _visible_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:
        return os.cpu_count() or 1


#: fault-free fleet throughput floor vs one pool with the same total
#: worker count.  The fleet adds a router hop, per-attempt ticket
#:  indirection and health scoring per request; with >= 2 CPUs that
#: overlaps worker compute and must come near-free (>= 0.90x).  On a
#: 1-CPU host every routing decision serializes with the kernels, so
#: the floor drops to a documented 0.75 instead of failing on a box
#: where 0.90 is structurally unreachable.
FLEET_RATIO_FLOOR = 0.90 if _visible_cpus() >= 2 else 0.75

#: per-scenario p99 ceilings (ms) — generous, box-independent; they
#: catch *unbounded* tails (lost wakeup, stranded backoff), not box
#: speed.  unhedged_stalls eats full stalls by design.
P99_BOUND_MS = {"baseline": 2_000.0, "unhedged_stalls": 10_000.0,
                "hedged_stalls": 5_000.0, "replica_kill": 15_000.0,
                "bitflip": 5_000.0}


def _check_fleet_trace(doc: Dict, hedged: bool) -> List[str]:
    problems = list(validate_chrome_trace(doc))
    names = {e.get("name") for e in doc.get("traceEvents", [])}
    want = REQUIRED_FLEET_EVENTS + (REQUIRED_HEDGE_EVENTS
                                    if hedged else ())
    for n in want:
        if n not in names:
            problems.append(f"missing required fleet event {n!r}")
    return problems


def _fleet(**kw):
    kw.setdefault("replicas", REPLICAS)
    kw.setdefault("workers", WORKERS)
    kw.setdefault("max_batch", BATCH)
    kw.setdefault("max_queue", 256)
    kw.setdefault("linger_ms", 1.0)
    return api.Session.fleet(**kw)


def _percentile(lat_ms: List[float], p: float) -> float:
    if not lat_ms:
        return 0.0
    return float(np.percentile(np.asarray(lat_ms), p))


def run_scenario(scenario: str, duration_s: float, seed: int = 0,
                 trace_out: Optional[str] = None,
                 metrics_out: Optional[str] = None) -> Dict:
    """One fault class, one fresh Fleet.  The stall pair runs
    closed-loop (per-request client latency is the comparison the
    hedging gate needs); the rest run open-loop bursty traffic and
    gate termination, not latency shape."""
    rng = np.random.default_rng(seed)
    name, scale = MODEL
    closed_loop = scenario in ("unhedged_stalls", "hedged_stalls")
    hedged = scenario in ("baseline", "hedged_stalls", "replica_kill")
    tracer = obs_trace.enable() if trace_out else None
    kw = dict(hedge=hedged)
    if closed_loop:
        # the pool supervisor must NOT rescue stalls — only hedging
        # may; and a stall storm deserves a bigger hedge budget than
        # the steady-state default
        kw.update(heartbeat_timeout_s=60.0, hedge_budget=0.5)
    if scenario == "bitflip":
        kw.update(audit_fraction=0.35, audit_threshold=3)
    fleet = _fleet(**kw)
    m = fleet.add(name, precision="int8", res_scale=scale)
    feed = rng.normal(size=m.graph.inputs[0].shape).astype(np.float32)

    # fault-free warmup: builds every replica's plans and seeds the
    # fleet latency histogram the p99-derived hedge timeout reads —
    # matched to the scenario's regime (the closed-loop pair must not
    # inherit a burst-queueing p99, or the hedge timeout would be as
    # long as the stalls it exists to cut)
    if closed_loop:
        for _ in range(32):
            fleet.submit(name, feed).result(timeout=120)
    else:
        warm = [fleet.submit(name, feed) for _ in range(32)]
        for t in warm:
            t.result(timeout=120)
    fleet.flush(60)

    tickets = []
    client_lat: List[float] = []
    submitted = 0
    ok = misses = failed = 0
    next_fault = 0.0
    t0 = time.monotonic()
    with chaos.inject() as c:
        if scenario == "bitflip":          # replica r1 lies from t=0
            c.corrupt_output(name, times=1_000_000, tag="r1")
        if closed_loop:
            # one request at a time; every 5th arms a worker stall the
            # next claim eats — the tail is anomaly-driven by design
            i = 0
            while time.monotonic() - t0 < duration_s:
                if i % 5 == 0:
                    c.stall_worker(int(rng.integers(0, WORKERS)),
                                   seconds=float(rng.uniform(0.3, 0.5)))
                s0 = time.monotonic()
                t = fleet.submit(name, feed)
                tickets.append(t)
                submitted += 1
                try:
                    t.result(timeout=120)
                    ok += 1
                except (WorkerLost, Overloaded, chaos.ChaosError,
                        Exception):
                    failed += 1
                client_lat.append((time.monotonic() - s0) * 1e3)
                i += 1
        else:
            while time.monotonic() - t0 < duration_s:
                el = time.monotonic() - t0
                if el >= next_fault:
                    if scenario == "replica_kill":
                        c.kill_pool(int(rng.integers(0, REPLICAS)))
                        next_fault = el + 2.0   # recycle lands between
                    else:
                        next_fault = float("inf")
                burst = int(rng.integers(1, 2 * BATCH + 1))
                for _ in range(burst):
                    deadline = float(rng.uniform(100, 1000)) \
                        if scenario == "replica_kill" \
                        and rng.random() < 0.2 else None
                    tickets.append(fleet.submit(name, feed,
                                                deadline_ms=deadline))
                    submitted += 1
                time.sleep(float(rng.uniform(0.0, 0.02)))

            # drain: every fleet ticket terminates with a value or a
            # typed error — the fleet-level zero-ticket-loss contract
            for t in tickets:
                try:
                    t.result(timeout=120)
                    ok += 1
                except DeadlineExceeded:
                    misses += 1
                except (WorkerLost, Overloaded, chaos.ChaosError,
                        Exception):
                    failed += 1
        lost = sum(1 for t in tickets if not t.done)
        if scenario == "bitflip":
            # give the background auditor time to cross the threshold
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                if fleet.stats()["quarantines"] >= 1:
                    break
                time.sleep(0.1)
        injected = dict(c.injected)
    wall = time.monotonic() - t0

    s = fleet.stats()
    if closed_loop:
        lat = {"p50_ms": _percentile(client_lat, 50),
               "p99_ms": _percentile(client_lat, 99)}
    else:
        lat = s["latency"].get(name, {})
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(fleet.metrics())
    fleet.close()
    trace_problems: List[str] = []
    if tracer is not None:
        obs_trace.disable()
        doc = tracer.chrome_trace()
        with open(trace_out, "w") as f:
            json.dump(doc, f)
        trace_problems = _check_fleet_trace(doc, hedged=hedged)
        for p in trace_problems[:5]:
            print(f"  [trace] {p}", file=sys.stderr)
    row = {
        "scenario": scenario,
        "duration_s": round(wall, 2),
        "submitted": submitted,
        "ok": ok,
        "deadline_misses": misses,
        "failed_typed": failed,
        "lost": lost,
        "zero_ticket_loss": bool(lost == 0
                                 and ok + misses + failed
                                 == len(tickets)),
        "req_s": round(ok / wall, 1),
        "p50_ms": round(lat.get("p50_ms", 0.0), 2),
        "p99_ms": round(lat.get("p99_ms", 0.0), 2),
        "p99_bound_ms": P99_BOUND_MS[scenario],
        "p99_bounded": bool(lat.get("p99_ms", 0.0)
                            <= P99_BOUND_MS[scenario]),
        "hedges": s["hedges"],
        "hedge_wins": s["hedge_wins"],
        "redispatches": s["redispatches"],
        "pool_deaths": s["pool_deaths"],
        "recycles": s["recycles"],
        "quarantines": s["quarantines"],
        "audit_ok": s["audit_ok"],
        "audit_mismatch": s["audit_mismatch"],
        "replicas": {str(rid): r["state"]
                     for rid, r in s["replicas"].items()},
        "injected": injected,
    }
    if tracer is not None:
        row["trace_events"] = len(tracer)
        row["trace_problems"] = len(trace_problems)
        row["trace_ok"] = not trace_problems
    return row


def rolling_update_drill() -> Dict:
    """Canary-gated rolling update under live traffic: a clean artifact
    swaps every replica one at a time while requests keep serving; a
    chaos-corrupted canary must reject with zero replicas swapped."""
    rng = np.random.default_rng(11)
    name, scale = MODEL
    fleet = _fleet(hedge=False)
    try:
        m = fleet.add(name, precision="int8", res_scale=scale)
        feed = rng.normal(size=m.graph.inputs[0].shape
                          ).astype(np.float32)
        for _ in range(8):
            fleet.submit(name, feed).result(timeout=120)
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "fleet_update.rpa")
            m.save(path)
            # traffic stays open-loop across the swap
            inflight = [fleet.submit(name, feed) for _ in range(16)]
            swapped = fleet.update(name, path)
            for t in inflight:
                t.result(timeout=120)
            served_through = all(t.done and t.error is None
                                 for t in inflight)
            rolled_back = False
            with chaos.inject() as c:
                c.corrupt_canary(name, times=1)
                try:
                    fleet.update(name, path)
                except UpdateRejected:
                    rolled_back = True
            # the rejected update left every replica live on the old
            # (still canary-clean) artifact
            post = fleet.submit(name, feed).result(timeout=120)
        s = fleet.stats()
        return {
            "swapped": swapped,
            "served_through_update": bool(served_through),
            "updates_ok": s["updates_ok"],
            "updates_rolled_back": s["updates_rolled_back"],
            "rollback_rejected_cleanly": bool(
                rolled_back and post is not None
                and all(st == "live"
                        for st in fleet.replicas().values())),
        }
    finally:
        fleet.close()


def paired_fleet_throughput(rounds: int) -> Dict:
    """Fleet (REPLICAS x WORKERS) vs one Session pool with the same
    total worker count, measured *paired* (rounds alternate) so host
    drift cannot bias the ratio.  Best round each, req/s."""
    name, scale = MODEL
    rng = np.random.default_rng(7)
    fleet = _fleet(hedge=False)
    sess = api.Session(max_batch=BATCH, workers=REPLICAS * WORKERS,
                       max_queue=256, linger_ms=1.0,
                       heartbeat_timeout_s=5.0)
    n_round = 128
    bests = {"fleet": 0.0, "single": 0.0}
    try:
        fm = fleet.add(name, precision="int8", res_scale=scale)
        sm = sess.add(name, precision="int8", res_scale=scale,
                      warmup=True)
        feeds = {
            "fleet": rng.normal(size=fm.graph.inputs[0].shape
                                ).astype(np.float32),
            "single": rng.normal(size=sm.graph.inputs[0].shape
                                 ).astype(np.float32)}
        # warmup round each (plan builds on every worker)
        ts = [fleet.submit(name, feeds["fleet"])
              for _ in range(n_round)]
        for t in ts:
            t.result(timeout=120)
        ts = [sess.submit(name, feeds["single"]) for _ in range(n_round)]
        sess.flush(name)
        for _ in range(rounds):
            t0 = time.monotonic()
            ts = [fleet.submit(name, feeds["fleet"])
                  for _ in range(n_round)]
            for t in ts:
                t.result(timeout=120)
            bests["fleet"] = max(bests["fleet"],
                                 n_round / (time.monotonic() - t0))
            t0 = time.monotonic()
            ts = [sess.submit(name, feeds["single"])
                  for _ in range(n_round)]
            sess.flush(name)
            dt = time.monotonic() - t0
            assert all(t.done and t.error is None for t in ts)
            bests["single"] = max(bests["single"], n_round / dt)
    finally:
        fleet.close()
        sess.close()
    return {"fleet_req_s": round(bests["fleet"], 1),
            "single_pool_req_s": round(bests["single"], 1),
            "ratio": round(bests["fleet"]
                           / max(1e-9, bests["single"]), 3)}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter scenarios; the throughput gate is "
                         "warn-only")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--trace-out", default="TRACE_fleet.json",
                    help="Chrome trace from the hedged_stalls scenario "
                         "(routing + hedge decisions as instants)")
    ap.add_argument("--metrics-out", default="METRICS_fleet.prom",
                    help="Prometheus exposition of the baseline "
                         "fleet's repro_fleet_* families")
    args = ap.parse_args(argv)

    duration = 1.5 if args.quick else 4.0
    scenarios = ["baseline", "unhedged_stalls", "hedged_stalls",
                 "replica_kill", "bitflip"]
    rows = []
    for i, sc in enumerate(scenarios):
        print(f"[fleet_bench] scenario {sc} ({duration:.0f}s) ...",
              flush=True)
        # seed the stall schedules identically so hedged vs unhedged
        # compare against the same fault sequence
        seed = 1 if sc in ("unhedged_stalls", "hedged_stalls") else i
        row = run_scenario(
            sc, duration, seed=seed,
            trace_out=args.trace_out if sc == "hedged_stalls" else None,
            metrics_out=args.metrics_out if sc == "baseline" else None)
        rows.append(row)
        print(f"  {row['req_s']:8.1f} req/s   p50 {row['p50_ms']:7.2f}"
              f" ms   p99 {row['p99_ms']:8.2f} ms   loss {row['lost']}"
              f"   hedges {row['hedges']}   deaths "
              f"{row['pool_deaths']}   quarantines "
              f"{row['quarantines']}", flush=True)

    print("[fleet_bench] rolling-update drill ...", flush=True)
    update = rolling_update_drill()
    print("[fleet_bench] measuring fleet vs single-pool throughput "
          "(paired) ...", flush=True)
    thr = paired_fleet_throughput(rounds=3 if args.quick else 6)

    unhedged = next(r for r in rows
                    if r["scenario"] == "unhedged_stalls")
    hedged = next(r for r in rows if r["scenario"] == "hedged_stalls")
    kill = next(r for r in rows if r["scenario"] == "replica_kill")
    flip = next(r for r in rows if r["scenario"] == "bitflip")

    result = {
        "model": MODEL[0],
        "replicas": REPLICAS,
        "workers_per_replica": WORKERS,
        "batch": BATCH,
        "cpus_visible": _visible_cpus(),
        "scenarios": rows,
        "update": update,
        "throughput": thr,
        "fleet_ratio_floor": FLEET_RATIO_FLOOR,
        # ---- gates -------------------------------------------------
        "all_zero_ticket_loss": all(r["zero_ticket_loss"]
                                    for r in rows),
        "all_p99_bounded": all(r["p99_bounded"] for r in rows),
        "replica_kill_zero_loss": bool(kill["zero_ticket_loss"]),
        "replica_kill_exercised": bool(kill["pool_deaths"] >= 1
                                       and kill["recycles"] >= 1),
        "hedging_exercised": bool(hedged["hedges"] >= 1
                                  and hedged["hedge_wins"] >= 1),
        "hedged_p99_le_unhedged": bool(hedged["p99_ms"]
                                       <= unhedged["p99_ms"]),
        "unhedged_p99_ms": unhedged["p99_ms"],
        "hedged_p99_ms": hedged["p99_ms"],
        "auditor_quarantined": bool(flip["quarantines"] >= 1
                                    and flip["audit_mismatch"]
                                    >= 3),
        "update_ok": bool(update["swapped"] == REPLICAS
                          and update["served_through_update"]),
        "rollback_ok": bool(update["rollback_rejected_cleanly"]),
        "meets_fleet_throughput": bool(thr["ratio"]
                                       >= FLEET_RATIO_FLOOR),
        "trace_ok": bool(hedged.get("trace_ok", False)),
        "trace_path": args.trace_out,
        "metrics_path": args.metrics_out,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[fleet_bench] zero-loss {result['all_zero_ticket_loss']}   "
          f"hedged p99 {hedged['p99_ms']:.1f} ms vs unhedged "
          f"{unhedged['p99_ms']:.1f} ms   fleet/single "
          f"{thr['ratio']:.3f} (floor {FLEET_RATIO_FLOOR:.2f}, "
          f"{_visible_cpus()} cpu) -> {args.out}")

    if not result["all_zero_ticket_loss"]:
        print("[fleet_bench] FAIL: fleet ticket loss detected",
              file=sys.stderr)
        return 1
    if not result["all_p99_bounded"]:
        print("[fleet_bench] FAIL: p99 exceeded its scenario bound",
              file=sys.stderr)
        return 1
    if not result["replica_kill_exercised"]:
        print("[fleet_bench] FAIL: replica_kill did not exercise the "
              "failover path (no death / recycle)", file=sys.stderr)
        return 1
    if not result["hedging_exercised"]:
        print("[fleet_bench] FAIL: hedging never fired under stalls",
              file=sys.stderr)
        return 1
    if not result["hedged_p99_le_unhedged"]:
        print("[fleet_bench] FAIL: hedging did not improve the stall "
              f"tail (hedged {hedged['p99_ms']} ms > unhedged "
              f"{unhedged['p99_ms']} ms)", file=sys.stderr)
        return 1
    if not result["auditor_quarantined"]:
        print("[fleet_bench] FAIL: the auditor did not quarantine the "
              "silently-corrupting replica", file=sys.stderr)
        return 1
    if not result["update_ok"] or not result["rollback_ok"]:
        print("[fleet_bench] FAIL: rolling update / canary rollback "
              "drill failed", file=sys.stderr)
        return 1
    if not result["trace_ok"]:
        print("[fleet_bench] FAIL: exported fleet trace failed "
              "schema/coverage validation", file=sys.stderr)
        return 1
    if not result["meets_fleet_throughput"]:
        if args.quick:
            print("[fleet_bench] WARNING: quick-mode fleet throughput "
                  f"< {FLEET_RATIO_FLOOR:.2f}x single pool (noisy "
                  "box?) — full bench enforces it", file=sys.stderr)
            return 0
        print(f"[fleet_bench] FAIL: fleet slower than "
              f"{FLEET_RATIO_FLOOR:.2f}x a single pool with equal "
              "total workers", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
