"""Serving robustness benchmark: bursty open-loop traffic under faults.

Drives a pooled :class:`repro.api.Session` with **open-loop bursty
traffic** (bursts submit without waiting for results — the generator
never self-throttles to hide server slowness) while
:mod:`repro.runtime.chaos` injects one fault class per scenario:

  * ``baseline``  — fault-free saturating traffic (the throughput and
    p99 reference; also the <= 5% pool-overhead gate vs a direct
    ``CompiledModel.run_many`` batch-8 loop on the same box);
  * ``stalls``    — workers randomly stop heartbeating mid-batch
    (hung-kernel signature) -> detection, re-dispatch, recycling;
  * ``poison``    — plan executions raise injected faults -> retry,
    circuit breaker, degraded oracle serving, recovery probes;
  * ``corrupt``   — concurrent compiles read corrupted disk-tier
    artifacts -> reject-and-recompile, serving unaffected;
  * ``skew``      — the deadline clock jumps forward -> expiries fire
    early but remain *typed* outcomes, never losses;
  * ``proc_kill`` — ``workers=("process", 2)``: worker *processes* are
    SIGKILLed mid-batch -> pipe-EOF detection, re-dispatch to the
    survivor, respawn off the request path, still zero ticket loss.

Per scenario it records req/s, p50/p99 latency, shed/deadline-miss/
degraded counts and — the robustness contract — **zero ticket loss**:
every accepted ticket terminates with a result or a typed error.  Each
scenario also asserts a p99 *bound* (generous, box-independent): a
regression to unbounded tail latency (hung worker, lost wakeup) fails
the bench rather than just skewing a number.

Writes ``BENCH_robust.json``.

    PYTHONPATH=src python -m benchmarks.robust_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.api as api
import repro.runtime.chaos as chaos
from repro.api import DeadlineExceeded, Overloaded, WorkerLost
from repro.core import (NEUTRON_2TOPS, program_cache_clear,
                        program_cache_configure, program_cache_info)
from repro.obs import trace as obs_trace
from repro.obs.trace import validate_chrome_trace

#: span names the exported baseline trace must contain — the request
#: path submit -> queue_wait -> batch -> worker, plus at least one
#: per-kernel ("plan" category) span from ExecPlan replay
REQUIRED_SPANS = ("submit", "queue_wait", "batch", "worker")


def _check_trace(doc: Dict) -> List[str]:
    """Schema validation + the serving-path coverage contract."""
    problems = validate_chrome_trace(doc)
    evs = doc.get("traceEvents", [])
    names = {d.get("name") for d in evs}
    for want in REQUIRED_SPANS:
        if want not in names:
            problems.append(f"missing span {want!r}")
    if not any(d.get("cat") == "plan" for d in evs):
        problems.append("no per-kernel ('plan' category) spans")
    return problems


def _check_proc_trace(doc: Dict) -> List[str]:
    """The merged process-mode trace: schema-valid, request-path spans
    from the parent, and at least one child-process batch span on a
    *different* pid (proving the merge actually rebased child events)."""
    problems = validate_chrome_trace(doc)
    evs = doc.get("traceEvents", [])
    names = {d.get("name") for d in evs}
    for want in ("submit", "queue_wait"):
        if want not in names:
            problems.append(f"missing span {want!r}")
    child_pids = {d.get("pid") for d in evs
                  if d.get("name") == "proc_batch"}
    if not child_pids:
        problems.append("no child 'proc_batch' spans in merged trace")
    elif child_pids == {os.getpid()}:
        problems.append("'proc_batch' spans carry the parent pid")
    return problems

MODEL = ("mobilenet_v2", 0.25)     # serving regime: edge camera preview
BATCH = 8
WORKERS = 2


def _visible_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:         # non-Linux fallback
        return os.cpu_count() or 1


#: fault-free process-pool throughput floor vs the thread pool.  With
#: >= 2 visible CPUs the parent's dispatch + IPC work overlaps child
#: compute, so process-level fault isolation must come near-free:
#: >= 0.95x the thread pool.  On a 1-CPU host overlap is impossible —
#: every frame pack, pipe syscall and wakeup strictly serializes with
#: the kernels — so the gate drops to a reduced, *documented* floor
#: (the measured single-core isolation tax is ~10-13%) instead of
#: failing on a box where 0.95 is structurally unreachable.  The
#: emitted JSON records both the floor used and the visible-CPU count.
PROC_RATIO_FLOOR = 0.95 if _visible_cpus() >= 2 else 0.80

#: per-scenario p99 ceilings (ms) — generous and box-independent; they
#: exist to catch *unbounded* tails (hung worker, lost wakeup), not to
#: benchmark the box.  stalls include one full stall + re-dispatch.
P99_BOUND_MS = {"baseline": 1_000.0, "stalls": 5_000.0,
                "poison": 5_000.0, "corrupt": 2_000.0, "skew": 2_000.0,
                "proc_kill": 10_000.0}


def _percentile(lat_ms: List[float], p: float) -> float:
    if not lat_ms:
        return 0.0
    return float(np.percentile(np.asarray(lat_ms), p))


def _tiny_graph(seed: int = 0):
    """A small conv net used by the ``corrupt`` scenario's *neighbor*
    compiles — cheap enough to recompile repeatedly mid-traffic."""
    from repro.core.ir import GraphBuilder
    b = GraphBuilder(f"robust_tiny{seed}", seed=seed)
    x = b.input((16, 16, 4))
    x = b.conv(x, 8, k=3, act="relu")
    x = b.conv(x, 8, k=3, act="relu")
    b.mark_output(x)
    return b.build(), b


def run_scenario(scenario: str, duration_s: float, seed: int = 0,
                 cache_dir: Optional[str] = None,
                 trace_out: Optional[str] = None,
                 metrics_out: Optional[str] = None) -> Dict:
    """One fault class, one fresh Session, open-loop bursty traffic.

    With ``trace_out``/``metrics_out`` set (the baseline scenario in
    ``main``) the scenario runs with the tracer armed, exports the
    Chrome trace + Prometheus exposition, and gates on
    :func:`_check_trace` (``row["trace_ok"]``)."""
    rng = np.random.default_rng(seed)
    name, scale = MODEL
    tracer = obs_trace.enable() if trace_out else None
    # proc_kill drives real worker processes; the longer heartbeat
    # keeps a child's cold-start plan build from reading as a stall
    workers = ("process", WORKERS) if scenario == "proc_kill" \
        else WORKERS
    hb_s = 3.0 if scenario == "proc_kill" else 0.15
    sess = api.Session(max_batch=BATCH, workers=workers, max_queue=256,
                       linger_ms=1.0, heartbeat_timeout_s=hb_s,
                       breaker_threshold=3, breaker_cooldown_s=0.2,
                       retry_backoff_ms=2.0, cache_dir=cache_dir)
    m = sess.add(name, precision="int8", res_scale=scale, warmup=True)
    t_in = m.graph.inputs[0]
    feed = rng.normal(size=t_in.shape).astype(np.float32)
    if scenario == "corrupt":      # seed the neighbor's disk artifact
        api.compile(_tiny_graph(), NEUTRON_2TOPS)

    tickets, shed = [], 0
    submitted = 0
    rejects_before = program_cache_info()["disk_rejects"]
    next_fault = 0.0
    t0 = time.monotonic()
    with chaos.inject() as c:
        while time.monotonic() - t0 < duration_s:
            el = time.monotonic() - t0
            if scenario != "baseline" and el >= next_fault:
                if scenario == "stalls":
                    c.stall_worker(int(rng.integers(0, 2 * WORKERS)),
                                   seconds=float(rng.uniform(0.2, 0.4)))
                    next_fault = el + float(rng.uniform(0.3, 0.6))
                elif scenario == "poison":
                    c.poison_plan(name, times=int(rng.integers(1, 3)))
                    next_fault = el + float(rng.uniform(0.1, 0.3))
                elif scenario == "corrupt":
                    # a neighboring compile hits a corrupted disk-tier
                    # artifact *while* this session keeps serving
                    c.corrupt_artifacts(times=1)
                    program_cache_clear()
                    api.compile(_tiny_graph(), NEUTRON_2TOPS)
                    next_fault = el + 0.25
                elif scenario == "skew":
                    c.skew_clock(float(rng.uniform(0.0, 0.03)))
                    next_fault = el + float(rng.uniform(0.1, 0.2))
                elif scenario == "proc_kill":
                    # SIGKILL whichever worker process claims the next
                    # batch; spaced so the respawn (child reload +
                    # re-lower) lands before the next kill
                    c.kill_worker(-1, mode="kill")
                    next_fault = el + 1.5
            # open-loop burst: submit without waiting on results
            burst = int(rng.integers(1, 2 * BATCH + 1))
            for _ in range(burst):
                deadline = float(rng.uniform(50, 500)) \
                    if scenario != "baseline" and rng.random() < 0.3 \
                    else None
                try:
                    tickets.append(sess.submit(name, feed,
                                               deadline_ms=deadline))
                except Overloaded:
                    shed += 1
                submitted += 1
            time.sleep(float(rng.uniform(0.0, 0.02)))    # bursty gaps

        # drain: the robustness contract — every accepted ticket
        # terminates with a value or a *typed* error
        ok = misses = failed = 0
        for t in tickets:
            try:
                t.result(timeout=60)
                ok += 1
            except DeadlineExceeded:
                misses += 1
            except (WorkerLost, chaos.ChaosError, Exception):
                failed += 1
        lost = sum(1 for t in tickets if not t.done)
        kills = int(c.injected.get("kills", 0))
    wall = time.monotonic() - t0

    st = sess.stats()
    ms = st["models"][name]
    lat = ms.get("latency", {})
    pool = st["pool"]
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(sess.metrics())
    children = []
    if tracer is not None and scenario == "proc_kill":
        # pull the surviving children's tracer rings before teardown
        children = sess._pool.collect_child_traces()
    sess.close()
    trace_problems: List[str] = []
    if tracer is not None:
        obs_trace.disable()
        doc = tracer.chrome_trace()
        if scenario == "proc_kill":
            doc = obs_trace.merge_chrome_traces(doc, tracer.epoch,
                                                children)
        with open(trace_out, "w") as f:
            json.dump(doc, f)
        trace_problems = _check_proc_trace(doc) \
            if scenario == "proc_kill" else _check_trace(doc)
        for p in trace_problems[:5]:
            print(f"  [trace] {p}", file=sys.stderr)
    row = {
        "scenario": scenario,
        "duration_s": round(wall, 2),
        "submitted": submitted,
        "accepted": len(tickets),
        "ok": ok,
        "shed": shed,
        "deadline_misses": misses,
        "failed_typed": failed,
        "lost": lost,
        "zero_ticket_loss": bool(lost == 0
                                 and ok + misses + failed == len(tickets)),
        "req_s": round(ok / wall, 1),
        "shed_rate": round(shed / max(1, submitted), 4),
        "p50_ms": round(lat.get("p50_ms", 0.0), 2),
        "p99_ms": round(lat.get("p99_ms", 0.0), 2),
        "p99_bound_ms": P99_BOUND_MS[scenario],
        "p99_bounded": bool(lat.get("p99_ms", 0.0)
                            <= P99_BOUND_MS[scenario]),
        "degraded_requests": ms["degraded_requests"],
        "retries": ms["retries"],
        "breaker_trips": ms["breaker_trips"],
        "recoveries": ms["recoveries"],
        "recycled_workers": pool["recycled_workers"],
        "redispatched_batches": pool["redispatched_batches"],
        "speculative_backups": pool["speculative_backups"],
    }
    if scenario == "proc_kill":
        row["kills"] = kills
        row["crash_redispatches"] = ms.get("crash_redispatches", 0)
    if scenario == "corrupt":
        row["disk_rejects"] = program_cache_info()["disk_rejects"] \
            - rejects_before
    if tracer is not None:
        row["trace_events"] = len(tracer)
        row["trace_problems"] = len(trace_problems)
        row["trace_ok"] = not trace_problems
    return row


def pooled_batch8_req_s(rounds: int, workers=WORKERS) -> float:
    """Fault-free saturated throughput through the pool: rounds of
    ``max_queue`` back-to-back submissions, each drained to empty (the
    generator sleeps inside ``flush`` while the workers run).

    ``workers=("process", n)`` measures the process pool on the same
    traffic — the long heartbeat keeps child cold-start plan builds
    from reading as stalls."""
    name, scale = MODEL
    rng = np.random.default_rng(7)
    hb_s = 5.0 if isinstance(workers, tuple) else 0.5
    sess = api.Session(max_batch=BATCH, workers=workers, max_queue=256,
                       linger_ms=1.0, heartbeat_timeout_s=hb_s)
    m = sess.add(name, precision="int8", res_scale=scale, warmup=True)
    t_in = m.graph.inputs[0]
    feed = rng.normal(size=t_in.shape).astype(np.float32)
    n_round = 128
    ts = [sess.submit(name, feed) for _ in range(n_round)]
    sess.flush(name)                         # warmup round (plan builds)
    assert all(t.done for t in ts)
    best = 0.0
    for _ in range(rounds):
        t0 = time.monotonic()
        ts = [sess.submit(name, feed) for _ in range(n_round)]
        sess.flush(name)
        dt = time.monotonic() - t0
        assert all(t.done and t.error is None for t in ts)
        best = max(best, n_round / dt)
    sess.close()
    return best


def paired_pool_throughput(rounds: int) -> Tuple[float, float]:
    """Thread-pool vs process-pool batch-8 throughput, measured
    *paired*: both sessions stay open and rounds alternate
    thread/process, so host-load drift between two long separate
    measurements cannot bias the ratio.  Returns
    ``(thread_best, proc_best)`` in req/s (best round each — the
    standard noise-floor estimator for a timing benchmark)."""
    name, scale = MODEL
    rng = np.random.default_rng(7)
    t_sess = api.Session(max_batch=BATCH, workers=WORKERS, max_queue=256,
                         linger_ms=1.0, heartbeat_timeout_s=0.5)
    p_sess = api.Session(max_batch=BATCH, workers=("process", WORKERS),
                         max_queue=256, linger_ms=1.0,
                         heartbeat_timeout_s=5.0)
    n_round = 128
    bests = {"thread": 0.0, "proc": 0.0}
    try:
        feeds = {}
        for tag, sess in (("thread", t_sess), ("proc", p_sess)):
            m = sess.add(name, precision="int8", res_scale=scale,
                         warmup=True)
            feeds[tag] = rng.normal(
                size=m.graph.inputs[0].shape).astype(np.float32)
            ts = [sess.submit(name, feeds[tag]) for _ in range(n_round)]
            sess.flush(name)                # warmup round (plan builds)
            assert all(t.done for t in ts)
        for _ in range(rounds):
            for tag, sess in (("thread", t_sess), ("proc", p_sess)):
                t0 = time.monotonic()
                ts = [sess.submit(name, feeds[tag])
                      for _ in range(n_round)]
                sess.flush(name)
                dt = time.monotonic() - t0
                assert all(t.done and t.error is None for t in ts)
                bests[tag] = max(bests[tag], n_round / dt)
    finally:
        t_sess.close()
        p_sess.close()
    return bests["thread"], bests["proc"]


def direct_batch8_req_s(runs: int) -> float:
    """The pool-overhead reference: direct batch-8 plan replay on the
    same box, same model — no queue, no threads."""
    name, scale = MODEL
    rng = np.random.default_rng(99)
    m = api.compile(name, NEUTRON_2TOPS, precision="int8",
                    res_scale=scale, cache=False)
    t_in = m.graph.inputs[0]
    reqs = [rng.normal(size=t_in.shape).astype(np.float32)
            for _ in range(BATCH)]
    m.run_many(reqs)                        # build the batch-8 plan
    best = min(_timed(m, reqs) for _ in range(runs))
    return BATCH / best


def _timed(m, reqs) -> float:
    t0 = time.monotonic()
    m.run_many(reqs)
    return time.monotonic() - t0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter scenarios; speed gates warn-only")
    ap.add_argument("--out", default="BENCH_robust.json")
    ap.add_argument("--trace-out", default="TRACE_robust.json",
                    help="Chrome trace from the baseline scenario "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="METRICS_robust.prom",
                    help="Prometheus exposition from the baseline "
                         "scenario's Session.metrics()")
    ap.add_argument("--proc-trace-out", default="TRACE_robust_proc.json",
                    help="merged parent+child Chrome trace from the "
                         "proc_kill scenario")
    args = ap.parse_args(argv)

    duration = 1.5 if args.quick else 4.0
    scenarios = ["baseline", "stalls", "poison", "corrupt", "skew",
                 "proc_kill"]
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        for i, sc in enumerate(scenarios):
            print(f"[robust_bench] scenario {sc} ({duration:.0f}s) ...",
                  flush=True)
            trace_out = None
            if sc == "baseline":
                trace_out = args.trace_out
            elif sc == "proc_kill":
                trace_out = args.proc_trace_out
            row = run_scenario(
                sc, duration, seed=i,
                cache_dir=tmp if sc == "corrupt" else None,
                trace_out=trace_out,
                metrics_out=args.metrics_out
                if sc == "baseline" else None)
            rows.append(row)
            print(f"  {row['req_s']:8.1f} req/s   p50 {row['p50_ms']:7.2f}"
                  f" ms   p99 {row['p99_ms']:8.2f} ms   shed "
                  f"{row['shed_rate']:6.1%}   loss {row['lost']}",
                  flush=True)
        program_cache_configure(disk_dir=None)
        program_cache_clear()

    print("[robust_bench] measuring pool overhead ...", flush=True)
    pooled_rps = pooled_batch8_req_s(rounds=3 if args.quick else 6)
    direct_rps = direct_batch8_req_s(runs=3 if args.quick else 5)
    overhead_ratio = pooled_rps / direct_rps
    print("[robust_bench] measuring thread vs process pool (paired) ...",
          flush=True)
    paired_thread_rps, proc_rps = paired_pool_throughput(
        rounds=3 if args.quick else 6)
    proc_ratio = proc_rps / paired_thread_rps
    stall_row = next(r for r in rows if r["scenario"] == "stalls")
    pk_row = next(r for r in rows if r["scenario"] == "proc_kill")

    result = {
        "config": NEUTRON_2TOPS.name,
        "model": MODEL[0],
        "batch": BATCH,
        "workers": WORKERS,
        "scenarios": rows,
        "pooled_batch8_req_s": round(pooled_rps, 1),
        "direct_batch8_req_s": round(direct_rps, 1),
        "pool_vs_direct_ratio": round(overhead_ratio, 3),
        "meets_overhead_5pct": bool(overhead_ratio >= 0.95),
        "paired_thread_batch8_req_s": round(paired_thread_rps, 1),
        "proc_pooled_batch8_req_s": round(proc_rps, 1),
        "proc_vs_thread_ratio": round(proc_ratio, 3),
        "cpus_visible": _visible_cpus(),
        "proc_ratio_floor": PROC_RATIO_FLOOR,
        "meets_proc_throughput": bool(proc_ratio >= PROC_RATIO_FLOOR),
        "all_zero_ticket_loss": all(r["zero_ticket_loss"] for r in rows),
        "all_p99_bounded": all(r["p99_bounded"] for r in rows),
        "proc_kill_zero_loss": bool(pk_row["zero_ticket_loss"]),
        "proc_kill_respawned": bool(pk_row["kills"] >= 1
                                    and pk_row["recycled_workers"] >= 1
                                    and pk_row["crash_redispatches"]
                                    >= 1),
        "proc_trace_ok": bool(pk_row.get("trace_ok", False)),
        "trace_ok": bool(next(r for r in rows
                              if r["scenario"] == "baseline")
                         .get("trace_ok", False)),
        "trace_path": args.trace_out,
        "proc_trace_path": args.proc_trace_out,
        "metrics_path": args.metrics_out,
        "faults_exercised": bool(
            stall_row["recycled_workers"] >= 1
            and any(r["breaker_trips"] >= 1 or r["retries"] >= 1
                    for r in rows if r["scenario"] == "poison")
            and next(r for r in rows
                     if r["scenario"] == "corrupt").get("disk_rejects",
                                                        0) >= 1),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[robust_bench] pool/direct throughput {overhead_ratio:.3f} "
          f"(target >= 0.95)   proc/thread {proc_ratio:.3f} "
          f"(target >= {PROC_RATIO_FLOOR:.2f}, "
          f"{_visible_cpus()} cpu)   zero-loss "
          f"{result['all_zero_ticket_loss']}   p99-bounded "
          f"{result['all_p99_bounded']} -> {args.out}")

    if not result["all_zero_ticket_loss"]:
        print("[robust_bench] FAIL: ticket loss detected",
              file=sys.stderr)
        return 1
    if not result["all_p99_bounded"]:
        print("[robust_bench] FAIL: p99 exceeded its scenario bound",
              file=sys.stderr)
        return 1
    if not result["proc_kill_respawned"]:
        print("[robust_bench] FAIL: proc_kill did not exercise the "
              "crash path (no kill / redispatch / respawn)",
              file=sys.stderr)
        return 1
    if not result["proc_trace_ok"]:
        print("[robust_bench] FAIL: merged process-mode trace failed "
              "schema/coverage validation", file=sys.stderr)
        return 1
    if not result["faults_exercised"]:
        print("[robust_bench] FAIL: a fault class did not actually "
              "fire (injection wiring broken?)", file=sys.stderr)
        return 1
    if not result["trace_ok"]:
        print("[robust_bench] FAIL: exported Chrome trace failed "
              "schema/coverage validation", file=sys.stderr)
        return 1
    if not result["meets_overhead_5pct"]:
        if args.quick:
            # quick smoke gates robustness only: the throughput ratio is
            # noisy on shared CI boxes; the full bench that produces the
            # committed BENCH_robust.json enforces it
            print("[robust_bench] WARNING: quick-mode pool overhead "
                  "> 5% (noisy box?) — full bench enforces it",
                  file=sys.stderr)
            return 0
        print("[robust_bench] FAIL: pool overhead exceeds 5%",
              file=sys.stderr)
        return 1
    if not result["meets_proc_throughput"]:
        if args.quick:
            print("[robust_bench] WARNING: quick-mode process-pool "
                  f"throughput < {PROC_RATIO_FLOOR:.2f}x thread pool "
                  "(noisy box?) — full bench enforces it",
                  file=sys.stderr)
            return 0
        print(f"[robust_bench] FAIL: process pool slower than "
              f"{PROC_RATIO_FLOOR:.2f}x the thread pool on fault-free "
              "batch-8 traffic", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
