"""Benchmark entrypoint: `PYTHONPATH=src python -m benchmarks.run`.

Runs the paper-table reproductions on the simulated-NPU backend and then
prints the roofline table from any cached dry-run artifacts.  Pass
``--fast`` to restrict Table III to the four small classification models
(full suite ~6 min single-core).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="sub-minute smoke: fast-marked tier-1 tests + "
                         "compile_bench --quick; skips tables/roofline")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-quant", action="store_true")
    args = ap.parse_args(argv)

    if args.quick:
        import subprocess
        import sys as _sys
        print("=" * 72)
        print("QUICK SMOKE (pytest -m fast + compile_bench --quick "
              "+ quant_bench --quick)")
        print("=" * 72)
        rc = subprocess.call(
            [_sys.executable, "-m", "pytest", "-q", "-m", "fast"])
        from . import compile_bench
        rc |= compile_bench.main(["--quick",
                                  "--out", "BENCH_compile_quick.json"])
        from . import quant_bench
        rc |= quant_bench.main(["--quick",
                                "--out", "BENCH_quant_quick.json"])
        return rc

    if not args.skip_tables:
        from . import paper_tables as pt
        print("=" * 72)
        print("PAPER-TABLE REPRODUCTIONS (simulated Neutron NPU)")
        print("=" * 72)
        print("[Table I] effective TOPS")
        pt.bench_table1()
        print("[Table III] latency + LTP")
        models = None
        if args.fast:
            models = [("mobilenet_v1", 1.0), ("mobilenet_v2", 1.0),
                      ("mobilenet_v3_min", 1.0),
                      ("efficientnet_lite0", 1.0)]
        pt.bench_table3(models=models)
        print("[Table II] CP partitioning")
        pt.bench_table2()
        print("[Fig 6] fusion memory profile")
        pt.bench_fig6()
        print("[§VI] GenAI GEMM speedup")
        pt.bench_genai()

    rc = 0
    if not args.skip_quant:
        print("=" * 72)
        print("QUANTIZATION (int8/int4 PTQ vs float32, BENCH_quant.json)")
        print("=" * 72)
        from . import quant_bench
        # --fast smoke must not clobber the canonical full-run artifact
        rc = quant_bench.main(["--quick", "--out",
                               "BENCH_quant_quick.json"]
                              if args.fast else [])

    if not args.skip_roofline:
        print("=" * 72)
        print("ROOFLINE (from cached dry-run artifacts)")
        print("=" * 72)
        from . import roofline as rf
        rf.main()
    return rc


if __name__ == "__main__":
    sys.exit(main())
