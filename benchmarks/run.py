"""Benchmark entrypoint: `PYTHONPATH=src python -m benchmarks.run`.

Runs the paper-table reproductions on the simulated-NPU backend and then
prints the roofline table from any cached dry-run artifacts.  Pass
``--fast`` to restrict Table III to the four small classification models
(full suite ~6 min single-core).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def write_summary(entries, out="BENCH_summary.json"):
    """Aggregate every bench artifact of this run into one
    machine-readable summary: per bench, its exit code, its artifact's
    top-level boolean gates, and a pass verdict (rc == 0 AND every gate
    true AND the artifact exists).  Returns 0 when every bench passed,
    1 otherwise — ``main`` folds this into its exit code so a red gate
    fails the run even if the bench's own main() was lenient."""
    benches = []
    ok = True
    for name, path, rc in entries:
        gates = {}
        exists = os.path.exists(path)
        if exists:
            try:
                with open(path) as f:
                    doc = json.load(f)
                gates = {k: v for k, v in doc.items()
                         if isinstance(v, bool)}
            except (OSError, ValueError) as e:
                exists = False
                gates = {"parse_error": False}
                print(f"[summary] {name}: unreadable artifact {path}: "
                      f"{e}")
        passed = bool(exists and rc == 0 and all(gates.values()))
        ok &= passed
        benches.append({"bench": name, "artifact": path, "rc": int(rc),
                        "artifact_exists": exists, "gates": gates,
                        "passed": passed})
    doc = {"ok": ok, "benches": benches}
    with open(out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"[summary] {out}: "
          + ", ".join(f"{b['bench']}={'PASS' if b['passed'] else 'FAIL'}"
                      for b in benches)
          + f" -> {'OK' if ok else 'FAILED'}")
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="sub-minute smoke: fast-marked tier-1 tests + "
                         "compile_bench --quick; skips tables/roofline")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-quant", action="store_true")
    ap.add_argument("--skip-fusion", action="store_true")
    ap.add_argument("--skip-serve", action="store_true")
    ap.add_argument("--skip-robust", action="store_true")
    ap.add_argument("--skip-fleet", action="store_true")
    ap.add_argument("--skip-decode", action="store_true")
    ap.add_argument("--cache-dir", default=None,
                    help="enable the on-disk program-cache tier at this "
                         "directory (CI keys its cache on it; a warm dir "
                         "turns every repeat compile into an artifact "
                         "load)")
    args = ap.parse_args(argv)

    if args.cache_dir:
        from repro.core import program_cache_configure
        program_cache_configure(disk_dir=args.cache_dir)

    def _cache_summary():
        if not args.cache_dir:
            return
        from repro.core import program_cache_info
        info = program_cache_info()
        print(f"[program-cache] disk tier at {info['disk_dir']}: "
              f"{info['disk_entries']} artifacts, "
              f"{info['disk_hits']} hits / {info['disk_misses']} misses "
              f"/ {info['disk_rejects']} rejects this run")

    if args.quick:
        import subprocess
        import sys as _sys
        print("=" * 72)
        print("QUICK SMOKE (pytest -m fast + compile/quant/fusion/serve/"
              "robust/fleet/decode benches --quick)")
        print("=" * 72)
        rc = subprocess.call(
            [_sys.executable, "-m", "pytest", "-q", "-m", "fast"])
        entries = []
        from . import compile_bench
        r = compile_bench.main(["--quick",
                                "--out", "BENCH_compile_quick.json"])
        entries.append(("compile", "BENCH_compile_quick.json", r))
        from . import quant_bench
        r = quant_bench.main(["--quick",
                              "--out", "BENCH_quant_quick.json"])
        entries.append(("quant", "BENCH_quant_quick.json", r))
        from . import fusion_bench
        r = fusion_bench.main(["--quick",
                               "--out", "BENCH_fusion_quick.json"])
        entries.append(("fusion", "BENCH_fusion_quick.json", r))
        from . import serve_bench
        r = serve_bench.main(["--quick",
                              "--out", "BENCH_serve_quick.json"])
        entries.append(("serve", "BENCH_serve_quick.json", r))
        from . import robust_bench
        r = robust_bench.main(["--quick",
                               "--out", "BENCH_robust_quick.json"])
        entries.append(("robust", "BENCH_robust_quick.json", r))
        from . import fleet_bench
        r = fleet_bench.main(["--quick",
                              "--out", "BENCH_fleet_quick.json"])
        entries.append(("fleet", "BENCH_fleet_quick.json", r))
        from . import decode_bench
        r = decode_bench.main(["--quick",
                               "--out", "BENCH_decode_quick.json"])
        entries.append(("decode", "BENCH_decode_quick.json", r))
        rc |= max(e[2] for e in entries)
        rc |= write_summary(entries)
        if args.cache_dir:
            # exercise the disk tier with real programs: cold CI solves
            # and writes artifacts; a restored cache dir serves them in
            # milliseconds (the cross-process warm-start path)
            import time as _time
            import repro.api as api_mod
            from repro.core import program_cache_clear
            program_cache_clear(stats=False)   # force past the LRU tier
            for name in ("mobilenet_v1", "mobilenet_v2"):
                t0 = _time.monotonic()
                m = api_mod.compile(name, res_scale=0.25)
                print(f"[program-cache] {name}: "
                      f"tier={m.cache_tier or 'solved'} "
                      f"{_time.monotonic() - t0:.3f}s")
        _cache_summary()
        return rc

    if not args.skip_tables:
        from . import paper_tables as pt
        print("=" * 72)
        print("PAPER-TABLE REPRODUCTIONS (simulated Neutron NPU)")
        print("=" * 72)
        print("[Table I] effective TOPS")
        pt.bench_table1()
        print("[Table III] latency + LTP")
        models = None
        if args.fast:
            models = [("mobilenet_v1", 1.0), ("mobilenet_v2", 1.0),
                      ("mobilenet_v3_min", 1.0),
                      ("efficientnet_lite0", 1.0)]
        pt.bench_table3(models=models)
        print("[Table II] CP partitioning")
        pt.bench_table2()
        print("[Fig 6] fusion memory profile")
        pt.bench_fig6()
        print("[§VI] GenAI GEMM speedup")
        pt.bench_genai()

    rc = 0
    entries = []
    if not args.skip_fusion:
        print("=" * 72)
        print("FUSION WINDOWING (greedy vs capped vs windowed CP, "
              "BENCH_fusion.json)")
        print("=" * 72)
        from . import fusion_bench
        path = "BENCH_fusion_quick.json" if args.fast \
            else "BENCH_fusion.json"
        r = fusion_bench.main(["--quick", "--out", path]
                              if args.fast else [])
        entries.append(("fusion", path, r))
        rc |= r

    if not args.skip_quant:
        print("=" * 72)
        print("QUANTIZATION (int8/int4 PTQ vs float32, BENCH_quant.json)")
        print("=" * 72)
        from . import quant_bench
        # --fast smoke must not clobber the canonical full-run artifact
        path = "BENCH_quant_quick.json" if args.fast \
            else "BENCH_quant.json"
        r = quant_bench.main(["--quick", "--out", path]
                             if args.fast else [])
        entries.append(("quant", path, r))
        rc |= r

    if not args.skip_serve:
        print("=" * 72)
        print("SERVING (compiled replay plans vs interpretive executor, "
              "BENCH_serve.json)")
        print("=" * 72)
        from . import serve_bench
        path = "BENCH_serve_quick.json" if args.fast \
            else "BENCH_serve.json"
        r = serve_bench.main(["--quick", "--out", path]
                             if args.fast else [])
        entries.append(("serve", path, r))
        rc |= r

    if not args.skip_robust:
        print("=" * 72)
        print("SERVING ROBUSTNESS (fault injection: stalls/poison/"
              "corrupt/skew, BENCH_robust.json)")
        print("=" * 72)
        from . import robust_bench
        path = "BENCH_robust_quick.json" if args.fast \
            else "BENCH_robust.json"
        r = robust_bench.main(["--quick", "--out", path]
                              if args.fast else [])
        entries.append(("robust", path, r))
        rc |= r

    if not args.skip_fleet:
        print("=" * 72)
        print("FLEET SERVING (replicated pools: hedging, failover, "
              "audit, BENCH_fleet.json)")
        print("=" * 72)
        from . import fleet_bench
        path = "BENCH_fleet_quick.json" if args.fast \
            else "BENCH_fleet.json"
        r = fleet_bench.main(["--quick", "--out", path]
                             if args.fast else [])
        entries.append(("fleet", path, r))
        rc |= r

    if not args.skip_decode:
        print("=" * 72)
        print("LM DECODE (prefill + streaming tokens/s on the NPU "
              "path, BENCH_decode.json)")
        print("=" * 72)
        from . import decode_bench
        path = "BENCH_decode_quick.json" if args.fast \
            else "BENCH_decode.json"
        r = decode_bench.main(["--quick", "--out", path]
                              if args.fast else [])
        entries.append(("decode", path, r))
        rc |= r

    if entries:
        rc |= write_summary(entries)

    if not args.skip_roofline:
        print("=" * 72)
        print("ROOFLINE (from cached dry-run artifacts)")
        print("=" * 72)
        from . import roofline as rf
        rf.main()
    _cache_summary()
    return rc


if __name__ == "__main__":
    sys.exit(main())
