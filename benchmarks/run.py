"""Benchmark entrypoint: `PYTHONPATH=src python -m benchmarks.run`.

Runs the paper-table reproductions on the simulated-NPU backend and then
prints the roofline table from any cached dry-run artifacts.  Pass
``--fast`` to restrict Table III to the four small classification models
(full suite ~6 min single-core).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="sub-minute smoke: fast-marked tier-1 tests + "
                         "compile_bench --quick; skips tables/roofline")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-quant", action="store_true")
    ap.add_argument("--skip-fusion", action="store_true")
    ap.add_argument("--skip-serve", action="store_true")
    ap.add_argument("--skip-robust", action="store_true")
    ap.add_argument("--cache-dir", default=None,
                    help="enable the on-disk program-cache tier at this "
                         "directory (CI keys its cache on it; a warm dir "
                         "turns every repeat compile into an artifact "
                         "load)")
    args = ap.parse_args(argv)

    if args.cache_dir:
        from repro.core import program_cache_configure
        program_cache_configure(disk_dir=args.cache_dir)

    def _cache_summary():
        if not args.cache_dir:
            return
        from repro.core import program_cache_info
        info = program_cache_info()
        print(f"[program-cache] disk tier at {info['disk_dir']}: "
              f"{info['disk_entries']} artifacts, "
              f"{info['disk_hits']} hits / {info['disk_misses']} misses "
              f"/ {info['disk_rejects']} rejects this run")

    if args.quick:
        import subprocess
        import sys as _sys
        print("=" * 72)
        print("QUICK SMOKE (pytest -m fast + compile/quant/fusion/serve/"
              "robust benches --quick)")
        print("=" * 72)
        rc = subprocess.call(
            [_sys.executable, "-m", "pytest", "-q", "-m", "fast"])
        from . import compile_bench
        rc |= compile_bench.main(["--quick",
                                  "--out", "BENCH_compile_quick.json"])
        from . import quant_bench
        rc |= quant_bench.main(["--quick",
                                "--out", "BENCH_quant_quick.json"])
        from . import fusion_bench
        rc |= fusion_bench.main(["--quick",
                                 "--out", "BENCH_fusion_quick.json"])
        from . import serve_bench
        rc |= serve_bench.main(["--quick",
                                "--out", "BENCH_serve_quick.json"])
        from . import robust_bench
        rc |= robust_bench.main(["--quick",
                                 "--out", "BENCH_robust_quick.json"])
        if args.cache_dir:
            # exercise the disk tier with real programs: cold CI solves
            # and writes artifacts; a restored cache dir serves them in
            # milliseconds (the cross-process warm-start path)
            import time as _time
            import repro.api as api_mod
            from repro.core import program_cache_clear
            program_cache_clear(stats=False)   # force past the LRU tier
            for name in ("mobilenet_v1", "mobilenet_v2"):
                t0 = _time.monotonic()
                m = api_mod.compile(name, res_scale=0.25)
                print(f"[program-cache] {name}: "
                      f"tier={m.cache_tier or 'solved'} "
                      f"{_time.monotonic() - t0:.3f}s")
        _cache_summary()
        return rc

    if not args.skip_tables:
        from . import paper_tables as pt
        print("=" * 72)
        print("PAPER-TABLE REPRODUCTIONS (simulated Neutron NPU)")
        print("=" * 72)
        print("[Table I] effective TOPS")
        pt.bench_table1()
        print("[Table III] latency + LTP")
        models = None
        if args.fast:
            models = [("mobilenet_v1", 1.0), ("mobilenet_v2", 1.0),
                      ("mobilenet_v3_min", 1.0),
                      ("efficientnet_lite0", 1.0)]
        pt.bench_table3(models=models)
        print("[Table II] CP partitioning")
        pt.bench_table2()
        print("[Fig 6] fusion memory profile")
        pt.bench_fig6()
        print("[§VI] GenAI GEMM speedup")
        pt.bench_genai()

    rc = 0
    if not args.skip_fusion:
        print("=" * 72)
        print("FUSION WINDOWING (greedy vs capped vs windowed CP, "
              "BENCH_fusion.json)")
        print("=" * 72)
        from . import fusion_bench
        rc |= fusion_bench.main(["--quick", "--out",
                                 "BENCH_fusion_quick.json"]
                                if args.fast else [])

    if not args.skip_quant:
        print("=" * 72)
        print("QUANTIZATION (int8/int4 PTQ vs float32, BENCH_quant.json)")
        print("=" * 72)
        from . import quant_bench
        # --fast smoke must not clobber the canonical full-run artifact
        rc |= quant_bench.main(["--quick", "--out",
                                "BENCH_quant_quick.json"]
                               if args.fast else [])

    if not args.skip_serve:
        print("=" * 72)
        print("SERVING (compiled replay plans vs interpretive executor, "
              "BENCH_serve.json)")
        print("=" * 72)
        from . import serve_bench
        rc |= serve_bench.main(["--quick", "--out",
                                "BENCH_serve_quick.json"]
                               if args.fast else [])

    if not args.skip_robust:
        print("=" * 72)
        print("SERVING ROBUSTNESS (fault injection: stalls/poison/"
              "corrupt/skew, BENCH_robust.json)")
        print("=" * 72)
        from . import robust_bench
        rc |= robust_bench.main(["--quick", "--out",
                                 "BENCH_robust_quick.json"]
                                if args.fast else [])

    if not args.skip_roofline:
        print("=" * 72)
        print("ROOFLINE (from cached dry-run artifacts)")
        print("=" * 72)
        from . import roofline as rf
        rf.main()
    _cache_summary()
    return rc


if __name__ == "__main__":
    sys.exit(main())
