"""Roofline table from cached dry-run artifacts.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints/saves the per-(arch x shape x mesh) three-term roofline table of
EXPERIMENTS.md §Roofline.  Does not recompile anything.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.analysis.roofline import Roofline, format_table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_rows(dryrun_dir: str = DRYRUN_DIR, mesh: Optional[str] = None,
              tag: str = "") -> List[Roofline]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if (art.get("tag") or "") != tag:
            continue
        if mesh and art["mesh"] != mesh:
            continue
        rows.append(Roofline(**art["roofline"]))
    return rows


def summarize(rows: List[Roofline]) -> Dict:
    if not rows:
        return {}
    worst = min(rows, key=lambda r: r.peak_fraction)
    coll = max(rows, key=lambda r: r.t_collective /
               max(r.t_compute + r.t_memory + r.t_collective, 1e-12))
    return {
        "n_cells": len(rows),
        "worst_roofline": (worst.arch, worst.shape,
                           round(worst.peak_fraction, 3)),
        "most_collective_bound": (coll.arch, coll.shape,
                                  round(coll.t_collective /
                                        max(coll.t_compute, 1e-12), 2)),
        "bottleneck_histogram": {
            b: sum(1 for r in rows if r.bottleneck == b)
            for b in ("compute", "memory", "collective")},
    }


def main():
    rows = load_rows()
    if not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print(format_table(rows))
    s = summarize(rows)
    print("\nsummary:", json.dumps(s, indent=1))


if __name__ == "__main__":
    main()
