"""Observability layer: tracer, metrics registry, Chrome export,
profiler, and their serving-runtime integration.

The contract under test: (1) the exported Chrome trace is structurally
valid (per-thread span nesting, required keys per phase) and carries
one request's trace id from the submitting thread to the worker that
served it; (2) ``Session.metrics()`` renders a Prometheus exposition
covering latency, shedding, breaker state and the program cache;
(3) tracing costs <= 5% on the batch-8 replay hot path; (4) the bench
summary aggregator fails red gates.
"""
import json
import threading
import time

import numpy as np
import pytest

import repro.api as api
from repro.core import NEUTRON_2TOPS, program_cache_clear, \
    program_cache_configure, program_cache_info
from repro.obs import trace
from repro.obs.metrics import LogHistogram, MetricsRegistry
from repro.obs.trace import Tracer, validate_chrome_trace

from test_execplan import random_graph, _inputs


@pytest.fixture(autouse=True)
def _tracer_disarmed():
    """No test leaks an armed global tracer into its neighbours."""
    trace.disable()
    yield
    trace.disable()


@pytest.fixture(autouse=True)
def _isolated_cache():
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(max_entries=64, max_bytes=None, disk_dir=None)
    yield
    program_cache_clear()
    program_cache_configure(max_entries=saved["max_entries"],
                            max_bytes=saved["max_bytes"],
                            disk_dir=saved["disk_dir"])


# --------------------------------------------------------------------------
# LogHistogram / metric families / registry
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_loghistogram_percentiles_and_snapshot():
    h = LogHistogram()
    for v in [1.0] * 90 + [100.0] * 10:
        h.record(v)
    assert h.count == 100
    assert h.percentile(50) == pytest.approx(1.0, rel=0.10)
    assert h.percentile(99) == pytest.approx(100.0, rel=0.10)
    snap = h.snapshot()
    assert set(snap) == {"count", "mean_ms", "p50_ms", "p99_ms", "max_ms"}
    assert snap["max_ms"] == 100.0
    # serving-era aliases survive the absorption
    assert h.sum_ms == h.sum and h.max_ms == h.max


@pytest.mark.fast
def test_loghistogram_empty_and_clamping():
    h = LogHistogram()
    assert h.percentile(99) == 0.0
    h.record(-5.0)                     # clamped into the lowest bucket
    assert h.percentile(50) <= h._lo


@pytest.mark.fast
def test_registry_families_are_idempotent():
    reg = MetricsRegistry()
    c1 = reg.counter("repro_x_total", "x", ("model",))
    c2 = reg.counter("repro_x_total", "ignored", ("model",))
    assert c1 is c2
    c1.inc(2, model="a")
    c2.inc(3, model="a")
    assert c1.value(model="a") == 5.0


@pytest.mark.fast
def test_registry_rejects_kind_and_label_mismatch():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "x", ("model",))
    with pytest.raises(ValueError):
        reg.gauge("repro_x_total")             # kind changed
    with pytest.raises(ValueError):
        reg.counter("repro_x_total", "x", ("worker",))  # labels changed
    with pytest.raises(ValueError):
        reg.counter("bad name")                # invalid metric name
    c = reg.counter("repro_y_total", "y", ("model",))
    with pytest.raises(ValueError):
        c.inc(1, worker="w0")                  # wrong label set
    with pytest.raises(ValueError):
        c.inc(-1, model="a")                   # counters only go up


@pytest.mark.fast
def test_registry_render_and_collector():
    reg = MetricsRegistry()
    reg.counter("repro_req_total", "requests", ("model",)).inc(3, model="a")
    reg.histogram("repro_lat_ms", "latency", ("model",)) \
        .observe(12.5, model="a")
    seen = []
    reg.register_collector(
        lambda: (seen.append(1),
                 reg.gauge("repro_depth", "queue depth").set(4))[0])
    text = reg.render()
    assert seen, "collector must run at render time"
    assert "# TYPE repro_req_total counter" in text
    assert 'repro_req_total{model="a"} 3' in text
    assert "# TYPE repro_lat_ms summary" in text
    assert 'repro_lat_ms{model="a",quantile="0.99"}' in text
    assert 'repro_lat_ms_count{model="a"} 1' in text
    assert "repro_depth 4" in text
    snap = reg.snapshot()
    assert snap["repro_req_total"]["model=a"] == 3.0
    assert snap["repro_lat_ms"]["model=a"]["count"] == 1


# --------------------------------------------------------------------------
# tracer + Chrome export schema
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_tracer_ring_is_bounded():
    tr = Tracer(capacity=8)
    for i in range(50):
        tr.instant(f"e{i}", "t")
    assert len(tr) == 8
    assert tr.events()[0][0] == "e42"          # oldest evicted first


@pytest.mark.fast
def test_chrome_export_schema_and_nesting():
    tr = Tracer()
    t0 = tr.clock()
    tr.complete("outer", "c", t0, t0 + 0.010)
    tr.complete("inner", "c", t0 + 0.002, t0 + 0.006)
    tr.instant("tick", "c", args={"k": 1})
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    phs = [d["ph"] for d in doc["traceEvents"]]
    assert "M" in phs and "X" in phs and "i" in phs
    assert doc["displayTimeUnit"] == "ms"
    json.dumps(doc)                            # must be serializable


@pytest.mark.fast
def test_validator_flags_partial_overlap_and_bad_events():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": 10},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5, "dur": 10},
        {"name": "c", "ph": "X", "pid": 1, "tid": 1, "ts": 0},
        {"name": "d", "ph": "b", "pid": 1, "tid": 1, "ts": 0},
    ]}
    problems = validate_chrome_trace(bad)
    assert any("partially overlaps" in p for p in problems)
    assert any("needs dur" in p for p in problems)
    assert any("missing id" in p for p in problems)
    assert validate_chrome_trace({}) != []


@pytest.mark.fast
def test_async_cat_exports_begin_end_pairs():
    """cat='async:*' spans become b/e pairs keyed by trace id — the
    cross-thread queue-wait representation that keeps per-thread
    nesting valid."""
    tr = Tracer()
    t0 = tr.clock()
    tr.complete("queue_wait", "async:serving", t0 - 0.005, t0,
                trace_id=41)
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    pair = [d for d in doc["traceEvents"] if d["name"] == "queue_wait"]
    assert [d["ph"] for d in pair] == ["b", "e"]
    assert all(d["id"] == 41 and d["cat"] == "serving" for d in pair)


@pytest.mark.fast
def test_flow_arrows_stitch_trace_id_across_threads():
    tr = Tracer()
    t0 = tr.clock()
    tr.complete("submit", "serving", t0, t0 + 0.001, trace_id=7)

    def worker():
        tr.complete("serve", "serving", t0 + 0.002, t0 + 0.004,
                    trace_id=7)

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    flows = [d for d in doc["traceEvents"] if d.get("cat") == "flow"]
    assert [d["ph"] for d in flows] == ["s", "f"]
    assert all(d["id"] == 7 for d in flows)
    assert flows[-1]["bp"] == "e"


@pytest.mark.fast
def test_switchboard_and_maybe_span():
    assert trace.active() is None
    with trace.maybe_span("noop", "t"):        # disabled: no-op
        pass
    tr = trace.enable(capacity=64)
    assert trace.active() is tr
    with trace.maybe_span("op", "t", trace_id=3, k=1):
        pass
    trace.instant("i1", "t")
    got = trace.disable()
    assert got is tr and trace.active() is None
    names = [e[0] for e in tr.events()]
    assert names == ["op", "i1"]
    assert tr.events()[0][6] == 3              # trace_id threaded


@pytest.mark.fast
def test_trace_session_context_manager(tmp_path):
    with trace.session(capacity=32) as tr:
        with tr.span("work", "t", n=2):
            pass
    assert trace.active() is None
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert validate_chrome_trace(doc) == []
    assert any(d.get("name") == "work" for d in doc["traceEvents"])


# --------------------------------------------------------------------------
# compile + replay instrumentation (single-threaded, fast)
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_compile_and_replay_emit_spans():
    with trace.session() as tr:
        m = api.compile(random_graph(3), NEUTRON_2TOPS, precision="int8",
                        cache=False)
        m(_inputs(m.graph, 1, seed=3)[0])
    names = {e[0] for e in tr.events()}
    cats = {e[1] for e in tr.events()}
    assert "compile" in names
    assert "compile:formats" in names
    assert "compile:schedule_allocate" in names
    assert "plan" in cats, "ExecPlan must emit per-kernel spans"
    assert validate_chrome_trace(tr.chrome_trace()) == []


@pytest.mark.fast
def test_plan_steps_false_skips_kernel_spans():
    m = api.compile(random_graph(3), NEUTRON_2TOPS, precision="int8",
                    cache=False)
    x = _inputs(m.graph, 1, seed=3)[0]
    with trace.session(plan_steps=False) as tr:
        m(x)
    assert not any(e[1] == "plan" for e in tr.events())


@pytest.mark.fast
def test_program_cache_tier_instants():
    with trace.session() as tr:
        api.compile(random_graph(4), precision="int8")    # miss
        api.compile(random_graph(4), precision="int8")    # memory hit
    tiers = [e[7]["tier"] for e in tr.events()
             if e[0] == "program_cache"]
    assert tiers[0] == "miss" and "memory" in tiers[1:]


# --------------------------------------------------------------------------
# profiler
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_profile_correlates_model_and_measurement():
    m = api.compile(random_graph(5), NEUTRON_2TOPS, precision="int8",
                    cache=False)
    rep = m.profile(batch=2, runs=1)
    assert rep.modeled["latency_ms"] > 0
    assert rep.measured["wall_ms_per_request"] > 0
    assert 0 < rep.modeled["utilization"] <= 1.0
    assert rep.measured["model_vs_actual"] > 0
    assert rep.ops, "per-op attribution must be populated"
    shares = sum(op.measured_share for op in rep.ops)
    assert shares == pytest.approx(1.0, abs=1e-6)
    top = rep.ops[0]
    assert top.kernels >= 1 and top.measured_ms >= 0
    text = rep.render()
    assert "modeled" in text and top.op in text
    d = rep.as_dict()
    json.dumps(d)
    assert d["ops"][0]["op"] == top.op


# --------------------------------------------------------------------------
# Session metrics exposition
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_session_metrics_exposition_covers_runtime():
    with api.Session(max_batch=4) as sess:
        sess.add(random_graph(0), name="m0", precision="int8")
        x = _inputs(sess["m0"].graph, 1)[0]
        tickets = [sess.submit("m0", x) for _ in range(3)]
        sess.flush("m0")
        assert all(t.done and t.error is None for t in tickets)
        text = sess.metrics()
    assert "# TYPE repro_request_latency_ms summary" in text
    assert 'repro_request_latency_ms_count{model="m0"} 3' in text
    assert 'repro_requests_total{model="m0"} 3' in text
    assert "# TYPE repro_shed_total counter" in text
    assert 'repro_breaker_state{model="m0"} 0' in text
    assert "repro_program_cache_total" in text
    assert 'repro_modeled_latency_ms{model="m0"}' in text
    assert "repro_queue_depth 0" in text
    # exposition and stats() share one histogram: no dual bookkeeping
    st = sess.stats()
    assert st["models"]["m0"]["latency"]["count"] == 3


# --------------------------------------------------------------------------
# pooled round trip through the exporter (live worker threads)
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_pooled_round_trip_trace_and_metrics():
    tr = trace.enable()
    sess = api.Session(max_batch=4, workers=2, max_queue=64,
                       linger_ms=1.0)
    sess.add(random_graph(0), name="m0", precision="int8")
    x = _inputs(sess["m0"].graph, 1)[0]
    tickets = [sess.submit("m0", x) for _ in range(12)]
    for t in tickets:
        t.result(timeout=30)
    metrics_text = sess.metrics()
    sess.close()
    trace.disable()

    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    names = {d.get("name") for d in evs}
    for want in ("submit", "queue_wait", "batch", "worker", "serve"):
        assert want in names, f"missing {want!r} span"
    assert any(d.get("cat") == "plan" for d in evs)

    # trace-id propagation: some request's submit span (caller thread)
    # and serve span (worker thread) share a trace id on distinct tids
    def ids(name):
        return {d["args"]["trace_id"]: d["tid"] for d in evs
                if d.get("name") == name and d.get("ph") == "X"
                and "trace_id" in d.get("args", {})}

    submits, serves = ids("submit"), ids("serve")
    crossed = [i for i in submits.keys() & serves.keys()
               if submits[i] != serves[i]]
    assert crossed, "no request crossed submitter -> worker thread"
    flow_ids = {d["id"] for d in evs if d.get("cat") == "flow"}
    assert flow_ids & set(crossed), "flow arrows missing for the hop"

    assert 'repro_pool_batch_ms' in metrics_text
    assert 'repro_worker_alive' in metrics_text
    assert 'repro_pool_workers 2' in metrics_text


@pytest.mark.chaos
def test_dispatch_estimate_from_batch_time_p99():
    """Satellite: deadline auto-flush dispatch estimate is the p99 of
    the pool's observed batch service times, not an EWMA."""
    sess = api.Session(max_batch=4, workers=1, max_queue=64,
                       linger_ms=1.0)
    sess.add(random_graph(0), name="m0", precision="int8")
    pool = sess._pool
    assert pool._dispatch_est_ms("m0") == pool.DEFAULT_EST_MS
    x = _inputs(sess["m0"].graph, 1)[0]
    ts = [sess.submit("m0", x) for _ in range(16)]
    for t in ts:
        t.result(timeout=30)
    h = pool._batch_ms.labels(model="m0")
    assert h.count >= pool.MIN_EST_SAMPLES
    est = pool._dispatch_est_ms("m0")
    assert est == pytest.approx(h.percentile(99))
    st = pool.stats()
    assert "dispatch_est_ms" in st and "ewma_batch_ms" not in st
    assert st["batch_ms"]["m0"]["count"] == h.count
    sess.close()


# --------------------------------------------------------------------------
# overhead gate: tracing <= 5% on the batch-8 replay hot path
# --------------------------------------------------------------------------


def test_tracing_overhead_under_5pct_on_batch8_replay():
    m = api.compile("mobilenet_v2", NEUTRON_2TOPS, precision="int8",
                    res_scale=0.25, cache=False)
    rng = np.random.default_rng(0)
    t_in = m.graph.inputs[0]
    reqs = [rng.normal(size=t_in.shape).astype(np.float32)
            for _ in range(8)]
    m.run_many(reqs)                          # build the batch-8 plan

    def best_of(n):
        best = float("inf")
        for _ in range(n):
            t0 = time.monotonic()
            m.run_many(reqs)
            best = min(best, time.monotonic() - t0)
        return best

    base = best_of(5)
    tr = trace.enable()
    traced = best_of(5)
    trace.disable()
    assert len(tr) > 0, "tracer saw no events while armed"
    assert traced <= base * 1.05 + 2e-3, \
        f"tracing overhead {traced / base - 1:.1%} exceeds 5% " \
        f"(base {base * 1e3:.2f} ms, traced {traced * 1e3:.2f} ms)"


# --------------------------------------------------------------------------
# bench summary aggregator
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_write_summary_gates(tmp_path, monkeypatch, capsys):
    from benchmarks.run import write_summary
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"ok_gate": True, "speed": 1.2}))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"ok_gate": True, "red_gate": False}))
    out = tmp_path / "summary.json"

    rc = write_summary([("good", str(good), 0)], out=str(out))
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["ok"] and doc["benches"][0]["passed"]
    assert doc["benches"][0]["gates"] == {"ok_gate": True}

    # a red gate fails the summary even when the bench's rc was 0
    assert write_summary([("bad", str(bad), 0)], out=str(out)) == 1
    assert not json.loads(out.read_text())["ok"]
    # a nonzero bench rc fails it even with green gates
    assert write_summary([("good", str(good), 1)], out=str(out)) == 1
    # a missing artifact fails it
    assert write_summary(
        [("ghost", str(tmp_path / "nope.json"), 0)], out=str(out)) == 1
