"""Fleet-level serving: replicated pools, health-aware routing, hedged
requests, pool failover, rolling updates, and the silent-corruption
auditor.

The fleet contract under test extends the single-pool one: **every
fleet ticket terminates exactly once** — with a result or a typed
error — under replica death, request hedging, cancellation, artifact
swaps and silently-corrupting replicas; and corruption that never
raises is still *caught* (audited against the interpretive oracle) and
*contained* (the corrupting replica quarantined and recycled).
"""
import random
import threading
import time

import numpy as np
import pytest

import repro.api as api
import repro.runtime.chaos as chaos
from repro.api import (Cancelled, DeadlineExceeded, Overloaded,
                       UpdateRejected, WorkerLost)
from repro.core import program_cache_clear, program_cache_configure, \
    program_cache_info
from repro.runtime.fleet import Fleet

from test_execplan import random_graph, _inputs


@pytest.fixture(autouse=True)
def _isolated_cache():
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(max_entries=64, max_bytes=None, disk_dir=None)
    yield
    program_cache_clear()
    program_cache_configure(max_entries=saved["max_entries"],
                            max_bytes=saved["max_bytes"],
                            disk_dir=saved["disk_dir"])


def _fleet(**kw):
    kw.setdefault("replicas", 2)
    kw.setdefault("workers", 1)
    kw.setdefault("max_batch", 4)
    fleet = api.Session.fleet(**kw)
    fleet.add(random_graph(0), name="m0", precision="int8")
    return fleet


def _feed(fleet, name="m0", seed=0):
    return _inputs(fleet._oracles[name].graph, 1, seed)[0]


def _check(fleet, name, out, feed):
    oracle = fleet._oracles[name]
    want = oracle(feed, engine="interp")
    for k in want:
        err = float(np.max(np.abs(out[k] - want[k])))
        assert err <= oracle.semantics.plan_parity_tol(k), \
            f"{name}/{k}: served output diverged from oracle by {err}"


def _wait_all_live(fleet, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if all(s == "live" for s in fleet.replicas().values()):
            return True
        time.sleep(0.1)
    return False


# --------------------------------------------------------------------------
# construction / placement units
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_fleet_requires_worker_pools():
    with pytest.raises(ValueError, match="workers"):
        Fleet(replicas=2, workers=0)
    with pytest.raises(ValueError, match="replica"):
        Fleet(replicas=0)


@pytest.mark.chaos
def test_fleet_placement_and_unknown_model():
    fleet = _fleet()
    try:
        assert fleet.placement() == {"m0": [0, 1]}
        fleet.add(random_graph(1), name="m1", precision="int8",
                  replicas=[1])
        assert fleet.placement()["m1"] == [1]
        assert fleet.models() == ["m0", "m1"]
        with pytest.raises(KeyError, match="m9"):
            fleet.submit("m9", {})
        with pytest.raises(ValueError, match="unknown replica"):
            fleet.add(random_graph(2), name="m2", replicas=[7])
    finally:
        fleet.close()


@pytest.mark.chaos
def test_fleet_serves_with_parity_and_balanced_routing():
    """Requests spread across replicas (health scores tie, served-count
    breaks ties) and every output matches the interpretive oracle."""
    fleet = _fleet(hedge=False)
    try:
        feeds = [_feed(fleet, seed=i) for i in range(8)]
        ts = [fleet.submit("m0", f) for f in feeds]
        for t, f in zip(ts, feeds):
            _check(fleet, "m0", t.result(timeout=60), f)
        assert fleet.flush(30)
        s = fleet.stats()
        assert s["completed"] == 8 and s["failed"] == 0
        served = [r["served"] for r in s["replicas"].values()]
        assert all(v > 0 for v in served), served
        assert "repro_fleet_requests_total" in fleet.metrics()
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# hedging
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_hedge_rescues_stalled_replica():
    """A request stuck behind a stalled worker is re-issued to the
    other replica after the hedge timeout; the hedge's result settles
    the ticket long before the stall clears (the roadmap's speculative
    execution across pools)."""
    fleet = _fleet(hedge_after_ms=80.0, heartbeat_timeout_s=60.0)
    try:
        x = _feed(fleet)
        for _ in range(4):                       # warm both replicas
            fleet.submit("m0", x).result(timeout=60)
        with chaos.inject() as c:
            c.stall_worker(0, seconds=3.0)       # one replica's worker
            t0 = time.monotonic()
            t = fleet.submit("m0", x)
            out = t.result(timeout=60)
            dt = time.monotonic() - t0
        _check(fleet, "m0", out, x)
        s = fleet.stats()
        assert s["hedges"] >= 1 and s["hedge_wins"] >= 1, s
        assert dt < 2.0, f"hedge did not rescue: {dt:.2f}s"
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# pool-level failover
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_replica_kill_zero_ticket_loss():
    """Killing a whole replica pool mid-burst loses no ticket: queued
    attempts fail over to the survivor with backoff, the dead replica
    recycles in the background and serves again."""
    fleet = _fleet(hedge=False)
    try:
        feeds = [_feed(fleet, seed=i) for i in range(10)]
        with chaos.inject() as c:
            ts = [fleet.submit("m0", f) for f in feeds]
            c.kill_pool(0)
            for t, f in zip(ts, feeds):
                _check(fleet, "m0", t.result(timeout=60), f)
            assert c.stats()["pool_kills"] == 1
        s = fleet.stats()
        assert s["pool_deaths"] == 1 and s["failed"] == 0
        assert _wait_all_live(fleet), fleet.replicas()
        assert fleet.stats()["recycles"] >= 1
        t = fleet.submit("m0", feeds[0])         # post-recycle health
        _check(fleet, "m0", t.result(timeout=60), feeds[0])
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# silent-corruption auditor
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_auditor_quarantines_corrupting_replica():
    """A replica that silently flips output bits (no error raised!) is
    caught by the sampling auditor's interp-oracle re-execution,
    quarantined once its mismatch count crosses the threshold, and
    recycled back to honest service."""
    fleet = _fleet(audit_fraction=1.0, audit_threshold=2, hedge=False)
    try:
        x = _feed(fleet)
        with chaos.inject() as c:
            c.corrupt_output("m0", times=50, tag="r1")   # only replica 1
            ts = [fleet.submit("m0", x) for _ in range(12)]
            for t in ts:
                t.result(timeout=60)
            fleet.flush(30)
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                if fleet.stats()["quarantines"] >= 1:
                    break
                time.sleep(0.1)
        s = fleet.stats()
        assert s["audit_mismatch"] >= 2, s
        assert s["quarantines"] >= 1, s
        assert s["replicas"][1]["quarantines"] >= 1
        assert s["replicas"][0]["quarantines"] == 0      # honest one
        assert _wait_all_live(fleet), fleet.replicas()
        # recycled replica serves honestly again; audits come back clean
        before = fleet.stats()["audit_mismatch"]
        ts = [fleet.submit("m0", x) for _ in range(6)]
        for t in ts:
            _check(fleet, "m0", t.result(timeout=60), x)
        fleet.flush(30)
        time.sleep(1.0)                                  # auditor drains
        assert fleet.stats()["audit_mismatch"] == before
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# rolling artifact updates
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_rolling_update_and_canary_rollback(tmp_path):
    """update() swaps replicas one at a time behind a canary that
    shadow-verifies the new artifact against the interpretive oracle;
    a corrupted canary rejects the update with zero replicas swapped."""
    fleet = _fleet(hedge=False)
    try:
        x = _feed(fleet)
        p = str(tmp_path / "m0.rpa")
        fleet._oracles["m0"].save(p)
        assert fleet.update("m0", p) == 2
        assert fleet._specs["m0"]["kind"] == "load"
        t = fleet.submit("m0", x)
        _check(fleet, "m0", t.result(timeout=60), x)

        with chaos.inject() as c:
            c.corrupt_canary("m0", times=1)
            with pytest.raises(UpdateRejected, match="canary"):
                fleet.update("m0", p)
            assert c.stats()["canary_corruptions"] == 1
        s = fleet.stats()
        assert s["updates_ok"] == 1 and s["updates_rolled_back"] == 1
        assert all(st == "live" for st in fleet.replicas().values())
        t = fleet.submit("m0", x)                # old artifact serves on
        _check(fleet, "m0", t.result(timeout=60), x)
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# pin rebalancing
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_rebalance_rehomes_and_repins():
    """rebalance() re-homes models (heaviest traffic first) onto the
    least-loaded replicas; program-cache pins follow the move."""
    fleet = _fleet(replicas=2, hedge=False)
    try:
        # both models pinned on replica 0 only; m0 carries the traffic
        fleet.add(random_graph(1), name="m1", precision="int8",
                  replicas=[0], pin=True)
        with fleet._cv:
            fleet._placement["m0"] = {0}
            fleet._specs["m0"]["pin"] = True
        fleet._replicas[0].session.pin("m0")
        for i in range(6):
            fleet.submit("m0", _feed(fleet, seed=i)).result(timeout=60)
        fleet.submit("m1", _feed(fleet, "m1")).result(timeout=60)
        moves = fleet.rebalance()
        # heaviest (m0) keeps r0; m1 moves to the now-less-loaded r1
        assert fleet.placement() == {"m0": [0], "m1": [1]}
        assert moves == {"m1": [1]}
        assert "m1" in fleet._replicas[1].session
        assert "m1" in fleet._replicas[1].session._pinned
        assert "m1" not in fleet._replicas[0].session._pinned
        t = fleet.submit("m1", _feed(fleet, "m1"))
        _check(fleet, "m1", t.result(timeout=60), _feed(fleet, "m1"))
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# cancellation through the fleet
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_cancel_settles_exactly_once():
    fleet = _fleet(hedge=False)
    try:
        x = _feed(fleet)
        results = {"cancelled": 0, "served": 0}
        for _ in range(6):
            t = fleet.submit("m0", x)
            won = t.cancel()
            try:
                out = t.result(timeout=60)
                assert not won
                _check(fleet, "m0", out, x)
                results["served"] += 1
            except Cancelled:
                assert won
                results["cancelled"] += 1
        s = fleet.stats()
        assert s["cancelled"] == results["cancelled"]
        assert s["completed"] == results["served"]
        assert s["completed"] + s["cancelled"] == 6
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# property: randomized kills + hedges + cancels, exactly-once settlement
# --------------------------------------------------------------------------


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1])
def test_fleet_random_faults_every_ticket_settles_once(seed):
    """A randomized schedule of replica kills, hedged requests and
    cancellations never loses or double-settles a ticket: every ticket
    terminates with a correct result or a typed error, and the fleet's
    settlement counters (each bumped exactly once per first-wins
    settlement) sum to the request count."""
    rng = random.Random(seed)
    fleet = _fleet(hedge_after_ms=40.0, max_redispatch=10,
                   audit_fraction=0.2, backoff_cap_ms=50.0)
    try:
        feeds = [_feed(fleet, seed=i) for i in range(6)]
        n = 24
        with chaos.inject() as c:
            tickets = []
            for i in range(n):
                t = fleet.submit("m0", feeds[i % 6],
                                 deadline_ms=5000.0
                                 if rng.random() < 0.3 else None)
                tickets.append((t, i % 6))
                r = rng.random()
                if r < 0.10:
                    c.kill_pool(rng.randrange(2))
                elif r < 0.25:
                    t.cancel()
                time.sleep(rng.random() * 0.01)
            for t, fi in tickets:
                try:
                    out = t.result(timeout=120)
                    _check(fleet, "m0", out, feeds[fi])
                except (Cancelled, DeadlineExceeded, WorkerLost,
                        Overloaded, chaos.ChaosError):
                    pass          # typed terminations are all legal
        assert fleet.flush(60)
        s = fleet.stats()
        assert s["completed"] + s["failed"] + s["cancelled"] == n, s
        assert _wait_all_live(fleet, timeout=60), fleet.replicas()
        t = fleet.submit("m0", feeds[0])
        _check(fleet, "m0", t.result(timeout=60), feeds[0])
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# lifecycle
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_fleet_close_fails_inflight_with_typed_error():
    fleet = _fleet(hedge=False)
    x = _feed(fleet)
    ts = [fleet.submit("m0", x) for _ in range(4)]
    fleet.close()
    for t in ts:
        assert t.done
        if t.error is not None:
            assert isinstance(t.error, WorkerLost)
    with pytest.raises(Exception):
        fleet.submit("m0", x)
    fleet.close()                                # idempotent
