"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernel body executes on CPU)."""
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------
# neutron_matmul
# --------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 300, 70),
                                   (128, 512, 128), (33, 65, 129)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_neutron_matmul_shapes(m, k, n, dtype):
    x = RNG.normal(size=(m, k)).astype(dtype)
    w = RNG.normal(size=(k, n)).astype(dtype)
    got = ops.neutron_matmul(x, w, impl="pallas")
    want = ops.neutron_matmul(x, w, impl="ref")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2 if dtype == "bfloat16" else 2e-3,
                               rtol=3e-2 if dtype == "bfloat16" else 1e-3)


@pytest.mark.parametrize("act", ["none", "relu", "relu6", "silu", "gelu",
                                 "sqrelu", "mish", "sigmoid"])
def test_neutron_matmul_activations(act):
    x = RNG.normal(size=(32, 64)).astype(np.float32)
    w = RNG.normal(size=(64, 48)).astype(np.float32)
    b = RNG.normal(size=(48,)).astype(np.float32)
    got = ops.neutron_matmul(x, w, bias=b, act=act, impl="pallas")
    want = ops.neutron_matmul(x, w, bias=b, act=act, impl="ref")
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)


def test_neutron_matmul_int8_requant_bit_exact():
    x = RNG.integers(-128, 128, size=(64, 256)).astype(np.int8)
    w = RNG.integers(-128, 128, size=(256, 96)).astype(np.int8)
    got = ops.neutron_matmul(x, w, scale=np.float32(0.02), act="relu",
                             out_scale=0.7, impl="pallas")
    want = ops.neutron_matmul(x, w, scale=np.float32(0.02), act="relu",
                              out_scale=0.7, impl="ref")
    assert got.dtype == np.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_neutron_matmul_per_channel_scale():
    x = RNG.integers(-64, 64, size=(16, 128)).astype(np.int8)
    w = RNG.integers(-64, 64, size=(128, 32)).astype(np.int8)
    sc = RNG.uniform(0.001, 0.1, size=(32,)).astype(np.float32)
    got = ops.neutron_matmul(x, w, scale=sc, impl="pallas")
    want = ops.neutron_matmul(x, w, scale=sc, impl="ref")
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-4)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 1, 1, 16, 8), (2, 4, 2, 100, 32), (2, 8, 1, 64, 16),
    (1, 6, 3, 77, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, Hkv, S, D, causal):
    q = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    k = RNG.normal(size=(B, Hkv, S, D)).astype(np.float32)
    v = RNG.normal(size=(B, Hkv, S, D)).astype(np.float32)
    got = ops.flash_attention(q, k, v, causal=causal, impl="pallas",
                              block_q=32, block_k=32)
    want = ops.flash_attention(q, k, v, causal=causal, impl="ref")
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("window", [1, 7, 64])
def test_flash_attention_sliding_window(window):
    B, H, S, D = 2, 2, 90, 16
    q = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    k = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    v = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    got = ops.flash_attention(q, k, v, window=window, impl="pallas",
                              block_q=32, block_k=32)
    want = ref.attention_naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)


def test_flash_attention_mla_head_dims():
    # MLA: value head dim differs from qk head dim
    B, H, S, Dqk, Dv = 2, 4, 48, 24, 16
    q = RNG.normal(size=(B, H, S, Dqk)).astype(np.float32)
    k = RNG.normal(size=(B, H, S, Dqk)).astype(np.float32)
    v = RNG.normal(size=(B, H, S, Dv)).astype(np.float32)
    got = ops.flash_attention(q, k, v, impl="pallas", block_q=16,
                              block_k=16)
    want = ops.flash_attention(q, k, v, impl="ref")
    assert got.shape == (B, H, S, Dv)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)


def test_flash_attention_bf16():
    B, H, S, D = 1, 2, 64, 32
    q = RNG.normal(size=(B, H, S, D)).astype("bfloat16")
    k = RNG.normal(size=(B, H, S, D)).astype("bfloat16")
    v = RNG.normal(size=(B, H, S, D)).astype("bfloat16")
    got = ops.flash_attention(q, k, v, impl="pallas", block_q=32,
                              block_k=32)
    want = ops.flash_attention(q, k, v, impl="ref")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_flash_fused_vjp_grads():
    import jax
    import jax.numpy as jnp
    B, H, S, D = 2, 2, 40, 16
    q = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    k = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    v = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    do = RNG.normal(size=(B, H, S, D)).astype(np.float32)

    def f_fused(q, k, v):
        return (ops.flash_attention(q, k, v, impl="ref", fused_vjp=True,
                                    block_k=16) * do).sum()

    def f_exact(q, k, v):
        return (ref.attention_naive(q, k, v) * do).sum()

    g1 = jax.grad(f_fused, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_exact, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-4, rtol=1e-3)


# --------------------------------------------------------------------------
# flash decode
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 1, 1, 32, 8), (3, 4, 2, 200, 32), (2, 8, 8, 128, 64),
])
def test_flash_decode_sweep(B, H, Hkv, S, D):
    q = RNG.normal(size=(B, H, D)).astype(np.float32)
    k = RNG.normal(size=(B, Hkv, S, D)).astype(np.float32)
    v = RNG.normal(size=(B, Hkv, S, D)).astype(np.float32)
    kvl = RNG.integers(1, S + 1, size=(B,)).astype(np.int32)
    got, lg = ops.flash_decode(q, k, v, kv_len=kvl, return_lse=True,
                               impl="pallas", block_k=64)
    want, lw = ops.flash_decode(q, k, v, kv_len=kvl, return_lse=True,
                                impl="ref")
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(lg, lw, atol=2e-3, rtol=1e-3)


def test_decode_shard_combine_exact():
    """Sequence-sharded decode: combining per-shard partials via LSE must
    equal the unsharded result (the long_500k mechanism)."""
    B, H, S, D = 2, 4, 96, 16
    q = RNG.normal(size=(B, H, D)).astype(np.float32)
    k = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    v = RNG.normal(size=(B, H, S, D)).astype(np.float32)
    full = ops.flash_decode(q, k, v, impl="ref")
    n_shards = 4
    outs, lses = [], []
    for i in range(n_shards):
        ks = k[:, :, i * S // n_shards:(i + 1) * S // n_shards]
        vs = v[:, :, i * S // n_shards:(i + 1) * S // n_shards]
        o, l = ops.flash_decode(q, ks, vs, return_lse=True, impl="ref")
        outs.append(o)
        lses.append(l)
    combined = ops.combine_decode_shards(np.stack(outs), np.stack(lses))
    np.testing.assert_allclose(combined, full, atol=2e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 1, 8, 4, 8), (2, 128, 3, 16, 8, 32), (2, 100, 2, 32, 16, 32),
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    x = RNG.normal(size=(B, S, H, P)).astype(np.float32)
    dt = RNG.uniform(0.001, 0.1, size=(B, S, H)).astype(np.float32)
    A = -RNG.uniform(0.5, 2.0, size=(H,)).astype(np.float32)
    Bm = RNG.normal(size=(B, S, N)).astype(np.float32)
    Cm = RNG.normal(size=(B, S, N)).astype(np.float32)
    yg, sg = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, impl="pallas")
    yw, sw = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, impl="ref")
    np.testing.assert_allclose(yg, yw, atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(sg, sw, atol=2e-3, rtol=1e-3)


def test_ssd_chunked_equals_stepwise():
    """Chunked scan == token-by-token recurrence (train/decode parity)."""
    B, S, H, P, N = 2, 48, 2, 8, 8
    x = RNG.normal(size=(B, S, H, P)).astype(np.float32)
    dt = RNG.uniform(0.001, 0.2, size=(B, S, H)).astype(np.float32)
    A = -RNG.uniform(0.2, 1.5, size=(H,)).astype(np.float32)
    Bm = RNG.normal(size=(B, S, N)).astype(np.float32)
    Cm = RNG.normal(size=(B, S, N)).astype(np.float32)
    y, s_final = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, impl="ref")
    state = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(S):
        yt, state = ops.ssd_step(state, x[:, t], dt[:, t], A,
                                 Bm[:, t], Cm[:, t])
        ys.append(np.asarray(yt))
    np.testing.assert_allclose(np.stack(ys, 1), y, atol=5e-3, rtol=1e-2)
    np.testing.assert_allclose(state, s_final, atol=5e-3, rtol=1e-2)


def test_ssd_chunk_invariance():
    """Result must not depend on the chunk size (property)."""
    B, S, H, P, N = 1, 64, 2, 8, 4
    x = RNG.normal(size=(B, S, H, P)).astype(np.float32)
    dt = RNG.uniform(0.001, 0.1, size=(B, S, H)).astype(np.float32)
    A = -RNG.uniform(0.5, 1.0, size=(H,)).astype(np.float32)
    Bm = RNG.normal(size=(B, S, N)).astype(np.float32)
    Cm = RNG.normal(size=(B, S, N)).astype(np.float32)
    y8, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=8, impl="ref")
    y32, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32, impl="ref")
    np.testing.assert_allclose(y8, y32, atol=2e-3, rtol=1e-3)
