"""End-to-end training integration: loss decreases, checkpoints restart
deterministically, serve generates."""
import os
import shutil
import tempfile

import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train_loop


def test_train_loss_decreases():
    losses = train_loop("qwen2-vl-2b", steps=25, smoke=True,
                        seq_len=64, global_batch=8, log_every=100)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_restart_is_bit_deterministic():
    d = tempfile.mkdtemp()
    try:
        a = train_loop("mamba2-370m", steps=8, smoke=True, ckpt_dir=d,
                       ckpt_every=4, seq_len=32, global_batch=4,
                       log_every=100)
        b = train_loop("mamba2-370m", steps=12, smoke=True, ckpt_dir=d,
                       ckpt_every=4, seq_len=32, global_batch=4,
                       log_every=100)
        c = train_loop("mamba2-370m", steps=12, smoke=True,
                       ckpt_dir=None, seq_len=32, global_batch=4,
                       log_every=100)
        # resumed steps 8..11 must match the uninterrupted run
        np.testing.assert_allclose(b[-4:], c[-4:], atol=1e-4)
    finally:
        shutil.rmtree(d)


def test_microbatch_and_compression_train():
    losses = train_loop("granite-moe-1b-a400m", steps=6, smoke=True,
                        seq_len=32, global_batch=8, n_micro=2,
                        compress=True, log_every=100)
    assert np.isfinite(losses).all()


def test_serve_generates():
    toks = serve("minitron-4b", batch=2, prompt_len=8, gen=4, smoke=True)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all()
