"""The `repro.api` deployment surface: CompiledModel compile/call/save/
load round trips (bit-exact for float32 and int8), artifact corruption
and staleness rejection, the two-tier program cache (LRU caps, hit/miss
/evict counters, disk tier, cross-process reuse) and the multi-model
serving Session."""
import json
import os
import subprocess
import sys
import zipfile

import numpy as np
import pytest

import repro.api as api
from repro.core import (NEUTRON_2TOPS, CompilerOptions,
                        program_cache_clear, program_cache_configure,
                        program_cache_info)
from repro.core.ir import GraphBuilder
from repro.core.serialize import ArtifactError

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _isolated_cache():
    """Every test starts with a clean, disk-less, default-sized store;
    teardown restores whatever configuration the process had before
    (the store is process-wide — later test modules may rely on an
    environment-configured disk tier)."""
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(max_entries=64, max_bytes=None, disk_dir=None)
    yield
    program_cache_clear()
    program_cache_configure(max_entries=saved["max_entries"],
                            max_bytes=saved["max_bytes"],
                            disk_dir=saved["disk_dir"])


def _tiny_graph(seed: int = 0, name: str = "apitiny"):
    b = GraphBuilder(name, seed=seed)
    x = b.input((16, 16, 8))
    x = b.conv(x, 16, k=3, act="relu")
    x = b.dwconv(x, k=3, act="relu6")
    x = b.maxpool(x, k=2)
    x = b.conv(x, 24, k=1, act="silu")
    x = b.global_avgpool(x)
    x = b.fc(x, 10)
    b.mark_output(x)
    return b.build(), b


def _input(g, seed=0):
    t = g.inputs[0]
    rng = np.random.default_rng(seed)
    return rng.normal(size=t.shape).astype(np.float32)


# --------------------------------------------------------------------------
# compile() resolution + callable surface
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_compile_graph_pair_and_call():
    m = api.compile(_tiny_graph(), cache=False)
    assert m.precision == "float32"
    x = _input(m.graph)
    out = m(x)
    assert set(out) == {t.name for t in m.graph.outputs}
    rep = m.verify(x)
    assert rep.ok
    # stats/report surface
    s = m.stats()
    assert s["precision"] == "float32" and "latency_ms" in s
    assert "CompiledModel" in m.report()


@pytest.mark.fast
def test_compile_batched_call():
    m = api.compile(_tiny_graph(), cache=False)
    x = np.stack([_input(m.graph, 0), _input(m.graph, 1),
                  _input(m.graph, 2)])
    out = m(x)
    for t in m.graph.outputs:
        assert out[t.name].shape[0] == 3
        single = m(x[1])
        np.testing.assert_array_equal(out[t.name][1], single[t.name])


@pytest.mark.fast
def test_compile_int8_runs_ptq_internally():
    """precision='int8' must quantize inside — no quant imports at the
    call site — and produce int8 semantics + calibrated tolerances."""
    m = api.compile(_tiny_graph(), precision="int8", calib_samples=2,
                    cache=False)
    assert m.precision == "int8"
    assert m.qm is not None and m.qm.calib_error
    from repro.core.ir import graph_precision
    assert graph_precision(m.graph) == "int8"
    rep = m.verify(_input(m.graph))
    assert rep.ok


@pytest.mark.fast
def test_compile_calibration_reuse():
    """An int4-weight re-quantize can reuse the int8 compile's
    calibration table — identical activation qparams, no second float
    reference sweep."""
    m8 = api.compile(_tiny_graph(), precision="int8", calib_samples=2,
                     cache=False)
    assert m8.calibration is not None
    m4 = api.compile(_tiny_graph(), precision="int8",
                     weight_dtype="int4", calibration=m8.calibration,
                     cache=False)
    assert m4.calibration is m8.calibration
    for t8, t4 in zip(sorted(m8.graph.tensors), sorted(m4.graph.tensors)):
        qp8 = m8.graph.tensors[t8].qparams
        qp4 = m4.graph.tensors[t4].qparams
        if qp8 is not None and not m8.graph.tensors[t8].is_param:
            np.testing.assert_array_equal(np.atleast_1d(qp8.scale),
                                          np.atleast_1d(qp4.scale))
    assert m4.verify(_input(m4.graph)).ok


@pytest.mark.fast
def test_compile_precision_mismatch_raises():
    g, b = _tiny_graph()
    with pytest.raises(ValueError):
        # quantized graph without its QuantizedModel bundle
        from repro import quant
        cal = quant.synthetic_calibration(g, samples=1)
        calib = quant.calibrate(g, b._weights, cal)
        quant.quantize_graph(g, b._weights, calib)
        api.compile(g, weights=b._weights, cache=False)


@pytest.mark.fast
def test_compile_rejects_unknown_source():
    with pytest.raises(TypeError):
        api.compile(12345)


# --------------------------------------------------------------------------
# artifact round trip: save -> load -> execute bit-exact
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_artifact_round_trip_float32_bit_exact(tmp_path):
    m = api.compile(_tiny_graph(), cache=False)
    x = _input(m.graph)
    want = m(x)
    p = m.save(str(tmp_path / "m.rpa"))
    m2 = api.CompiledModel.load(p)
    assert m2.fingerprint == m.fingerprint
    assert m2.precision == "float32"
    got = m2(x)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])
    assert m2.verify(x).ok
    # latency accounting survives serialization exactly
    assert m2.program.latency_cycles() == m.program.latency_cycles()


@pytest.mark.fast
def test_artifact_round_trip_int8_bit_exact(tmp_path):
    m = api.compile(_tiny_graph(), precision="int8", calib_samples=2,
                    cache=False)
    x = _input(m.graph)
    want = m(x)
    p = m.save(str(tmp_path / "q.rpa"))
    m2 = api.CompiledModel.load(p)
    assert m2.precision == "int8"
    got = m2(x)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])
    # semantics resolved from artifact metadata: same calibrated band
    for t in m.graph.outputs:
        assert m2.semantics.float_tolerance(t.name) == \
            pytest.approx(m.semantics.float_tolerance(t.name))
    assert m2.verify(x).ok


def test_artifact_round_trip_int8_vision(tmp_path):
    m = api.compile("mobilenet_v1", precision="int8", res_scale=0.125,
                    calib_samples=2, cache=False)
    x = _input(m.graph, seed=7)
    want = m(x)
    m2 = api.CompiledModel.load(m.save(str(tmp_path / "v.rpa")))
    got = m2(x)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])


@pytest.mark.fast
def test_artifact_corruption_rejected(tmp_path):
    m = api.compile(_tiny_graph(), cache=False)
    p = m.save(str(tmp_path / "m.rpa"))
    blob = bytearray(open(p, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p)
    # truncated file
    open(p, "wb").write(bytes(blob[: len(blob) // 3]))
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p)
    # not an artifact at all
    open(p, "wb").write(b"not a zip")
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p)


@pytest.mark.fast
def test_artifact_tampered_entry_rejected(tmp_path):
    """A re-zipped artifact with an edited payload fails the sha256
    manifest even though the zip itself is valid."""
    m = api.compile(_tiny_graph(), cache=False)
    p = str(tmp_path / "m.rpa")
    m.save(p)
    with zipfile.ZipFile(p) as zf:
        entries = {n: zf.read(n) for n in zf.namelist()}
    entries["model.json"] = entries["model.json"].replace(
        b"float32", b"floatXX")
    with zipfile.ZipFile(p, "w") as zf:
        for n, blob in entries.items():
            zf.writestr(n, blob)
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p)


@pytest.mark.fast
def test_artifact_stale_for_other_graph_rejected(tmp_path):
    m = api.compile(_tiny_graph(), cache=False)
    p = m.save(str(tmp_path / "m.rpa"))
    other, _ = _tiny_graph(name="other")
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p, expect_graph=other)
    from dataclasses import replace as dc_replace
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(
            p, expect_cfg=dc_replace(NEUTRON_2TOPS, tcm_banks=16))
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(
            p, expect_options=CompilerOptions(fusion=False))
    # matching expectations load fine
    g, _ = _tiny_graph()
    api.CompiledModel.load(p, expect_graph=g, expect_cfg=NEUTRON_2TOPS,
                           expect_options=m.options)


# --------------------------------------------------------------------------
# two-tier program cache: LRU caps + counters + disk tier
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_program_cache_lru_cap_and_counters():
    program_cache_configure(max_entries=2)
    graphs = [_tiny_graph(name=f"lru{i}") for i in range(3)]
    for g, _ in graphs:
        api.compile((g, graphs[0][1]), cache=True)
    info = program_cache_info()
    assert info["entries"] == 2            # capped
    assert info["mem_evictions"] == 1      # oldest evicted
    assert info["mem_misses"] == 3
    # oldest graph was evicted -> recompiling it misses
    api.compile((graphs[0][0], graphs[0][1]), cache=True)
    assert program_cache_info()["mem_hits"] == 0
    # newest still cached
    m = api.compile((graphs[2][0], graphs[2][1]), cache=True)
    assert m.result.cache_tier == "memory"
    assert program_cache_info()["mem_hits"] == 1


@pytest.mark.fast
def test_program_cache_byte_cap_evicts():
    g, b = _tiny_graph()
    api.compile((g, b), cache=True)
    assert program_cache_info()["entries"] == 1
    assert program_cache_info()["bytes"] > 0
    program_cache_configure(max_bytes=1)   # below any entry estimate
    assert program_cache_info()["entries"] == 0
    assert program_cache_info()["mem_evictions"] == 1


@pytest.mark.fast
def test_program_cache_disk_tier_round_trip(tmp_path):
    program_cache_configure(disk_dir=str(tmp_path))
    g, b = _tiny_graph()
    a = api.compile((g, b), cache=True)
    assert not a.result.cache_hit
    assert program_cache_info()["disk_entries"] == 1
    # drop the memory tier -> next compile must come from disk
    program_cache_clear(stats=False)
    g2, b2 = _tiny_graph()
    c = api.compile((g2, b2), cache=True)
    assert c.result.cache_hit and c.result.cache_tier == "disk"
    x = _input(g)
    np.testing.assert_array_equal(
        a(x)[g.outputs[0].name], c(x)[g2.outputs[0].name])
    info = program_cache_info()
    assert info["disk_hits"] == 1 and info["disk_writes"] == 1


@pytest.mark.fast
def test_program_cache_disk_corruption_recompiles(tmp_path):
    program_cache_configure(disk_dir=str(tmp_path))
    g, b = _tiny_graph()
    api.compile((g, b), cache=True)
    (path,) = [p for p in os.listdir(str(tmp_path)) if p.endswith(".rpa")]
    full = os.path.join(str(tmp_path), path)
    open(full, "wb").write(b"garbage")
    program_cache_clear(stats=False)
    g2, b2 = _tiny_graph()
    c = api.compile((g2, b2), cache=True)
    assert not c.result.cache_hit          # rejected, recompiled
    assert program_cache_info()["disk_rejects"] == 1
    # the recompile overwrote the bad file with a good one
    program_cache_clear(stats=False)
    g3, b3 = _tiny_graph()
    d = api.compile((g3, b3), cache=True)
    assert d.result.cache_tier == "disk"


def test_program_cache_cross_process(tmp_path):
    """Acceptance: a second process with the same artifact dir skips
    compilation entirely — its compile_s is load time, not solve time."""
    script = r"""
import json
import repro.api as api

# a real benchmark model: the CP solve takes O(seconds) cold, so the
# solve-vs-load timing assertion below has a wide margin
m = api.compile("mobilenet_v1", res_scale=0.25)
res = m.result
print(json.dumps({"compile_s": res.compile_s,
                  "cache_hit": res.cache_hit,
                  "cache_tier": res.cache_tier,
                  "disk_load": res.phase_s.get("disk_load")}))
"""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""),
               REPRO_PROGRAM_CACHE_DIR=str(tmp_path))
    runs = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, check=True)
        runs.append(json.loads(out.stdout.strip().splitlines()[-1]))
    first, second = runs
    assert not first["cache_hit"]
    assert second["cache_hit"] and second["cache_tier"] == "disk"
    # compile_s in the warm process is artifact-load time, not CP-solve
    # time: orders of magnitude under the cold solve
    assert second["compile_s"] < first["compile_s"] * 0.25
    assert second["disk_load"] is not None
    assert second["compile_s"] < second["disk_load"] + 0.25


# --------------------------------------------------------------------------
# Session
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_session_multi_model_precisions(tmp_path):
    sess = api.Session(cache_dir=str(tmp_path / "cache"))
    f = sess.add(_tiny_graph(name="sfloat"), name="tiny_f32")
    q = sess.add(_tiny_graph(name="squant"), name="tiny_int8",
                 precision="int8", calib_samples=2)
    assert f.precision == "float32" and q.precision == "int8"
    assert set(sess.models()) == {"tiny_f32", "tiny_int8"}
    x = _input(f.graph)
    out = sess.run("tiny_f32", x)
    assert set(out) == {t.name for t in f.graph.outputs}
    sess.run("tiny_int8", x)
    st = sess.stats()
    assert st["models"]["tiny_f32"]["requests"] == 1
    assert st["models"]["tiny_int8"]["precision"] == "int8"
    assert st["models"]["tiny_f32"]["compiles"]["solved"] == 1
    # re-adding hits the in-process tier
    sess.add(_tiny_graph(name="sfloat"), name="tiny_f32")
    assert sess.stats()["models"]["tiny_f32"]["compiles"]["memory"] == 1
    assert "Session" in sess.report()
    with pytest.raises(KeyError):
        sess.run("nope", x)


@pytest.mark.fast
def test_session_load_artifact_and_warmup(tmp_path):
    m = api.compile(_tiny_graph(), cache=False)
    p = m.save(str(tmp_path / "m.rpa"))
    sess = api.Session()
    sess.load(p, name="from_disk")
    sess.warmup("from_disk")
    x = _input(m.graph)
    np.testing.assert_array_equal(
        sess.run("from_disk", x)[m.graph.outputs[0].name],
        m(x)[m.graph.outputs[0].name])
    assert sess.stats()["models"]["from_disk"]["compiles"]["artifact"] == 1


# --------------------------------------------------------------------------
# executor row-window cache: replay stays exact with fused row tiling
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_window_cache_replay_exact_deep_rows():
    """A taller model with many row-tiled steps per op exercises the
    window cache's slice/extend paths; the replay must stay oracle-exact
    (execute() checks against reference_execute internally)."""
    b = GraphBuilder("wincache", seed=3)
    x = b.input((48, 48, 16))
    x = b.conv(x, 24, k=3, act="relu")
    x = b.conv(x, 24, k=5, s=1, act="relu6")
    x = b.dwconv(x, k=3, act="relu")
    x = b.maxpool(x, k=2)
    x = b.conv(x, 32, k=3, act="silu")
    b.mark_output(x)
    g = b.build()
    m = api.compile((g, b), cache=False)
    rep = m.verify(_input(g, seed=5))
    assert rep.ok
