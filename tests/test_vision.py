"""Vision frontends: MAC/param accounting vs Table IV; functional
compile+execute equivalence at reduced resolution."""
import numpy as np
import pytest

import repro.api as api
from repro.core.ir import reference_execute
from repro.frontends.vision import VISION_MODELS, build, table4_targets

#: per-model MAC tolerance — most are exact-architecture matches; the
#: approximated detectors get wider bands (documented in DESIGN.md):
#: resnet50: He et al. count 3.8G multiply-adds; Table IV lists 2.0 under
#: a different counting convention — we keep the canonical architecture.
_TOL = {
    "resnet50_v1": None,            # checked against 3.87 instead
    "efficientdet_lite0": 0.35,
    "mobilenet_v1_ssd": 0.30,
    "damo_yolo_nl": 0.30,
    "yolov8n_seg": 0.15,
    "mobilenet_v2_ssd": 0.25,
}


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_macs_match_table4(name):
    g, _ = build(name)
    gmacs = g.total_macs() / 1e9
    target, _ = table4_targets(name)
    if name == "resnet50_v1":
        assert abs(gmacs - 3.87) / 3.87 < 0.05
        return
    tol = _TOL.get(name) or 0.10
    assert abs(gmacs - target) / target < tol, (gmacs, target)


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_params_match_table4(name):
    g, _ = build(name)
    mparams = sum(t.elems for t in g.params) / 1e6
    _, target = table4_targets(name)
    tol = 0.30 if name in _TOL else 0.12
    assert abs(mparams - target) / target < tol, (mparams, target)


@pytest.mark.parametrize("name", ["mobilenet_v1", "mobilenet_v2",
                                  "efficientnet_lite0"])
def test_vision_compile_execute(name):
    model = api.compile(name, res_scale=0.25)
    inp = np.random.default_rng(1).normal(
        size=model.graph.inputs[0].shape).astype(np.float32)
    rep = model.verify(inp)
    assert rep.ok


def test_reference_executor_deterministic():
    g, b = build("mobilenet_v3_min", res_scale=0.25)
    inp = {g.inputs[0].name: np.random.default_rng(2).normal(
        size=g.inputs[0].shape).astype(np.float32)}
    a = reference_execute(g, inp, b._weights)
    bb = reference_execute(g, inp, b._weights)
    for t in g.outputs:
        np.testing.assert_array_equal(a[t.name], bb[t.name])
