"""Per-architecture smoke tests: reduced config of the same family runs
one forward + one train step + a decode step on CPU; output shapes right,
no NaNs.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.registry import ARCH_IDS, get_arch
from repro.models.train import TrainOptions, init_train_state, \
    make_train_step


def _batch_for(cfg, n=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, size=(n, S)).astype(np.int32)
    batch = {"tokens": tokens, "labels": tokens.copy()}
    if cfg.enc_dec:
        batch["audio_embed"] = rng.normal(
            size=(n, cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["vision_embed"] = rng.normal(
            size=(n, cfg.n_vision_tokens, cfg.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_smoke(arch):
    cfg = get_arch(arch).reduced(dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits = lm.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_smoke(arch):
    cfg = get_arch(arch).reduced(dtype="float32")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    batch = _batch_for(cfg)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(metrics["step"]) == 1
    # params actually changed
    state2, metrics2 = step(state, batch)
    assert float(metrics2["loss"]) != loss or \
        float(metrics2["grad_norm"]) != float(metrics["grad_norm"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced(dtype="float32",
                                 capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n, S = 2, 12
    batch = _batch_for(cfg, n=n, S=S)
    aux = None
    if cfg.enc_dec:
        enc = lm.encode_audio(cfg, params, batch["audio_embed"])
        aux = {"enc_states": enc,
               "cross_kv": lm.cross_kv(cfg, params, enc)}
    if cfg.family == "vlm":
        aux = {"vision_embed": batch["vision_embed"]}
    ref_logits = lm.forward(cfg, params, batch)
    cache = lm.init_cache(cfg, n, S)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos,
                                                       aux=aux))
    errs = []
    for t in range(S):
        lg, cache = step(params, cache, batch["tokens"][:, t],
                         jnp.int32(t))
        errs.append(float(jnp.abs(lg - ref_logits[:, t]).max()))
    assert max(errs) < 5e-3, (arch, errs)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the assigned dimensions."""
    expected = {
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab=32000,
                            ssm_state=64),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6,
                             n_kv_heads=6, d_ff=1536, vocab=51865),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024,
                                     n_heads=16, n_kv_heads=8,
                                     vocab=49155, n_experts=32, top_k=8,
                                     moe_d_ff=512),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 n_kv_heads=128, vocab=129280,
                                 n_experts=256, top_k=8, moe_d_ff=2048),
        "mamba2-370m": dict(n_layers=48, d_model=1024, vocab=50280,
                            ssm_state=128),
        "minitron-4b": dict(n_layers=32, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=9216, vocab=256000),
        "gemma3-27b": dict(n_layers=62, d_model=5376, n_heads=32,
                           n_kv_heads=16, d_ff=21504, vocab=262144,
                           local_global_ratio=5),
        "nemotron-4-340b": dict(n_layers=96, d_model=18432, n_heads=96,
                                n_kv_heads=8, d_ff=73728, vocab=256000),
        "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48,
                            n_kv_heads=1, d_ff=24576, vocab=49152),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12,
                            n_kv_heads=2, d_ff=8960, vocab=151936,
                            mrope=True),
    }
    cfg = get_arch(arch)
    for k, v in expected[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_long_context_cells_only_subquadratic():
    from repro.models.registry import cells
    runs_500k = {a for a in ARCH_IDS
                 if "long_500k" in cells(get_arch(a))}
    assert runs_500k == {"zamba2-2.7b", "mamba2-370m", "gemma3-27b"}
