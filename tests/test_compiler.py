"""Compiler end-to-end: every compiled program must be functionally
bit-equivalent to the numpy oracle, respect the TCM bank ledger, and the
CP stack must never be slower than the baseline on the model's own
latency metric.  Property-based over randomly generated CNN graphs."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (ENPU_A, NEUTRON_2TOPS, CompilerOptions,
                        compile_graph)
from repro.core.executor import execute
from repro.core.ir import GraphBuilder


def _random_graph(seed: int):
    rng = np.random.default_rng(seed)
    b = GraphBuilder(f"rand{seed}", seed=seed)
    h = int(rng.integers(12, 40))
    c = int(rng.choice([3, 4, 8]))
    x = b.input((h, h, c))
    skip = None
    n_ops = int(rng.integers(3, 9))
    for i in range(n_ops):
        kind = rng.choice(["conv", "dwconv", "pool", "act", "add"])
        cur_c = b.g.tensors[x].hwc[2]
        if kind == "conv":
            x = b.conv(x, int(rng.choice([8, 16, 24])),
                       k=int(rng.choice([1, 3])),
                       s=int(rng.choice([1, 1, 2])),
                       act=str(rng.choice(["relu", "silu", "none"])))
        elif kind == "dwconv":
            x = b.dwconv(x, k=3, s=1, act="relu")
        elif kind == "pool" and b.g.tensors[x].hwc[0] >= 4:
            x = b.maxpool(x, k=2)
        elif kind == "act":
            x = b.activation(x, "relu6")
        elif kind == "add" and skip is not None and \
                b.g.tensors[skip].hwc == b.g.tensors[x].hwc:
            x = b.add(x, skip)
        skip = x
    x = b.global_avgpool(x)
    x = b.fc(x, int(rng.integers(4, 32)))
    b.mark_output(x)
    return b.build(), b


@given(seed=st.integers(0, 500))
@settings(max_examples=12, deadline=None)
def test_compiled_program_matches_oracle(seed):
    g, b = _random_graph(seed)
    res = compile_graph(g, NEUTRON_2TOPS, CompilerOptions())
    inp = {g.inputs[0].name: np.random.default_rng(seed).normal(
        size=g.inputs[0].shape).astype(np.float32)}
    rep = execute(res.program, g, res.tiling, inp, b._weights)
    assert rep.ok
    # allocation invariants recorded by the allocator
    assert res.program.meta["peak_banks"] <= NEUTRON_2TOPS.tcm_banks


@given(seed=st.integers(0, 500))
@settings(max_examples=8, deadline=None)
def test_baseline_also_correct_and_not_faster(seed):
    g, b = _random_graph(seed)
    ours = compile_graph(g, NEUTRON_2TOPS, CompilerOptions())
    g2, b2 = _random_graph(seed)
    base = compile_graph(g2, NEUTRON_2TOPS, CompilerOptions.baseline())
    inp = {g2.inputs[0].name: np.random.default_rng(seed).normal(
        size=g2.inputs[0].shape).astype(np.float32)}
    rep = execute(base.program, g2, base.tiling, inp, b2._weights)
    assert rep.ok
    # the CP compiler never loses on its own latency model
    assert ours.program.latency_ms() <= base.program.latency_ms() * 1.001


def test_fusion_reduces_offchip_traffic():
    from repro.frontends.vision import build
    g, _ = build("mobilenet_v2", res_scale=0.5)
    fused = compile_graph(g, NEUTRON_2TOPS, CompilerOptions())
    g2, _ = build("mobilenet_v2", res_scale=0.5)
    layerwise = compile_graph(g2, NEUTRON_2TOPS,
                              CompilerOptions.baseline())
    assert fused.program.latency_ms() < layerwise.program.latency_ms()


def test_overlap_never_hurts():
    from repro.frontends.vision import build
    g, _ = build("mobilenet_v1", res_scale=0.25)
    on = compile_graph(g, NEUTRON_2TOPS, CompilerOptions())
    # same program accounted serially must not be faster
    assert on.program.latency_cycles(overlap=True) <= \
        on.program.latency_cycles(overlap=False)


def test_format_plan_covers_all_ops():
    from repro.core.formats import select_formats
    g, _ = _random_graph(7)
    plan = select_formats(NEUTRON_2TOPS, g)
    for op in g.ops:
        assert plan[op.name] in ("depth", "line")


def test_enpu_b_scaling():
    from repro.core import ENPU_B
    assert ENPU_B.peak_tops == pytest.approx(2 * ENPU_A.peak_tops)
    assert ENPU_B.tcm_bytes == 2 * ENPU_A.tcm_bytes
    assert ENPU_B.ddr_gbps == 2 * ENPU_A.ddr_gbps
