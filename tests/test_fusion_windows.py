"""Windowed fusion-CP machinery: region partition/order soundness
properties, the windowed order stitcher, the greedy-order safety net,
the CP-eligibility estimate, cpsolver fixed-assignment support, and the
disk-tier artifact GC."""
import numpy as np
import pytest

from repro.core import (NEUTRON_2TOPS, CompilerOptions, compile_graph,
                        cpsolver, program_cache_clear,
                        program_cache_configure, program_cache_info)
from repro.core.executor import execute
from repro.core.formats import select_formats
from repro.core.ir import GraphBuilder
from repro.core.npu import cross_window_spill_cost
from repro.core.tiling import (TensorTiles, _est_region_tiles,
                               _greedy_order, _mk_tiles, _regions,
                               _tile_options, _window_bounds,
                               plan_tiling, validate_order)

CFG = NEUTRON_2TOPS


def _chain_graph(h=40, c=8, n=4):
    b = GraphBuilder("chain", seed=0)
    x = b.input((h, h, 3))
    for i in range(n):
        x = b.conv(x, c, k=3, act="relu")
        x = b.dwconv(x, k=3, act="relu")
    b.mark_output(x)
    return b.build(), b


# --------------------------------------------------------------------------
# _regions partitions topo_ops exactly once
# --------------------------------------------------------------------------


@pytest.mark.fast
@pytest.mark.parametrize("model,scale", [("mobilenet_v1", 0.25),
                                         ("mobilenet_v2", 0.25),
                                         ("yolov8n_det", 0.1)])
def test_regions_partition_topo_ops_exactly_once(model, scale):
    from repro.frontends.vision import build
    g, _ = build(model, res_scale=scale)
    for frac in (0.5, 0.125):
        opts = _tile_options(CFG, g, budget_frac=frac)
        regions = _regions(CFG, g, opts)
        flat = [op.name for r in regions for op in r]
        assert flat == [op.name for op in g.topo_ops()]


@pytest.mark.fast
def test_regions_partition_random_graphs():
    g, _ = _chain_graph()
    opts = _tile_options(CFG, g)
    regions = _regions(CFG, g, opts)
    flat = [op.name for r in regions for op in r]
    assert flat == [op.name for op in g.topo_ops()]


# --------------------------------------------------------------------------
# est_tiles counts every output of multi-output ops
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_est_region_tiles_counts_all_outputs():
    b = GraphBuilder("split", seed=0)
    x = b.input((16, 16, 8))
    x = b.conv(x, 8, k=1)
    parts = b.split(x, 2)
    y = b.add(parts[0], parts[1])
    b.mark_output(y)
    g = b.build()
    opts = {name: (2, 4, "rows") for name in g.tensors}
    split_op = next(op for op in g.ops if op.kind == "split")
    # the split op alone contributes BOTH outputs at the larger option
    assert _est_region_tiles(opts, [split_op]) == 8
    assert _est_region_tiles(opts, g.ops) == 4 * sum(
        len(op.outputs) for op in g.ops)


# --------------------------------------------------------------------------
# windowed stitched orders: every tile exactly once + row-dep sound
# --------------------------------------------------------------------------


def _region_orders(g, tiling):
    """Split the global order into per-region sub-orders."""
    own = {}
    for ri, names in enumerate(tiling.regions):
        for n in names:
            own[n] = ri
    orders = {ri: [] for ri in range(len(tiling.regions))}
    for st in tiling.order:
        orders[own[st.op_name]].append(st)
    return orders


@pytest.mark.fast
def test_windowed_orders_sound_and_complete():
    from repro.frontends.vision import build
    g, _ = build("mobilenet_v2", res_scale=0.5)
    plan = select_formats(CFG, g)
    # max_cp_tiles=0 forces every multi-op region onto the windowed
    # path; a small window size forces multi-window decompositions
    tiling = plan_tiling(CFG, g, plan, max_cp_tiles=0,
                         max_cp_window_tiles=4, region_overlap=2)
    st = tiling.stats
    assert st["windowed_regions"] >= 1
    assert st["windows"] >= 2
    name_to_op = {op.name: op for op in g.ops}
    orders = _region_orders(g, tiling)
    for ri, names in enumerate(tiling.regions):
        region = [name_to_op[n] for n in names]
        if len(region) <= 1:
            continue
        errs = validate_order(g, region, tiling.tiles, orders[ri])
        assert not errs, errs
    # the fallback plan (if any) must be equally sound
    if tiling.fallback is not None:
        fb_orders = _region_orders(g, tiling.fallback)
        for ri, names in enumerate(tiling.fallback.regions):
            region = [name_to_op[n] for n in names]
            if len(region) <= 1:
                continue
            errs = validate_order(g, region, tiling.fallback.tiles,
                                  fb_orders[ri])
            assert not errs, errs


@pytest.mark.fast
def test_sequential_window_refinement_sound_and_no_worse():
    """The sequential refinement (carry=1 for tiles the previous window
    holds at its end) must keep the stitched order dependency-sound and
    never worsen the fusion objective vs. the concurrent-only solve."""
    from repro.frontends.vision import build
    g, _ = build("mobilenet_v2", res_scale=0.5)
    plan = select_formats(CFG, g)
    kw = dict(max_cp_tiles=0, max_cp_window_tiles=4, region_overlap=2)
    base = plan_tiling(CFG, g, plan, window_refine=False, **kw)
    ref = plan_tiling(CFG, g, plan, window_refine=True, **kw)
    assert base.stats["window_refined"] == 0
    assert ref.stats["windows"] >= 2
    assert ref.stats["window_refined"] >= 1
    # held tiles stop paying the phantom DDR re-entry at the seam
    assert ref.fusion_objective <= base.fusion_objective
    name_to_op = {op.name: op for op in g.ops}
    orders = _region_orders(g, ref)
    for ri, names in enumerate(ref.regions):
        region = [name_to_op[n] for n in names]
        if len(region) <= 1:
            continue
        errs = validate_order(g, region, ref.tiles, orders[ri])
        assert not errs, errs


def test_windowed_compile_executes_oracle_exact():
    g, b = _chain_graph(h=48, c=12, n=5)
    opts = CompilerOptions(max_cp_tiles=0, max_cp_window_tiles=6,
                           region_overlap=2)
    res = compile_graph(g, CFG, opts, cache=False)
    inp = {g.inputs[0].name: np.random.default_rng(0).normal(
        size=g.inputs[0].shape).astype(np.float32)}
    rep = execute(res.program, g, res.tiling, inp, b._weights)
    assert rep.ok
    assert res.program.meta["peak_banks"] <= CFG.tcm_banks


@pytest.mark.fast
def test_window_bounds_cover_and_overlap():
    for T in (1, 2, 7, 24, 100):
        for size in (2, 8, 24):
            for ov in (0, 3, 30):
                bounds = _window_bounds(T, size, ov)
                assert bounds[0][0] == 0 and bounds[-1][1] == T
                for (a, b), (a2, b2) in zip(bounds, bounds[1:]):
                    assert a < a2 <= b <= b2     # progress, no gaps
                covered = set()
                for a, b in bounds:
                    covered |= set(range(a, b))
                assert covered == set(range(T))


# --------------------------------------------------------------------------
# greedy-order safety net is row-dependency-sound
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_greedy_order_safety_net_sound_for_shuffled_region():
    g, _ = _chain_graph(h=32, c=8, n=3)
    opts = _tile_options(CFG, g, budget_frac=0.125)
    region = [op for op in g.topo_ops() if op.kind in ("conv", "dwconv")]
    tiles = {}
    for op in region:
        for oname in op.outputs:
            t = g.tensors[oname]
            tiles[oname] = TensorTiles(oname, _mk_tiles(
                t, opts[oname][0], CFG.bank_bytes, opts[oname][2]))
    # reversed + interleaved region order still must come out sound —
    # the fixpoint loop stalls on some ops and the topological-order
    # safety net has to finish the job
    for perm in (list(reversed(region)),
                 region[1::2] + region[0::2]):
        order = _greedy_order(g, perm, tiles)
        errs = validate_order(g, region, tiles, order)
        assert not errs, errs


# --------------------------------------------------------------------------
# cpsolver: fixed assignments
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_fix_many_respected_and_excluded_from_branching():
    m = cpsolver.CPModel("fix")
    xs = [m.bool(f"x{i}") for i in range(6)]
    m.add_exactly_one(xs[:3])
    m.add_exactly_one(xs[3:])
    m.minimize([(v, c) for v, c in zip(xs, (3, 2, 1, 1, 2, 3))])
    m.fix_many({xs[2]: 0, xs[3]: 0})
    sol = cpsolver.solve(m, time_limit_s=2.0)
    ref = cpsolver.brute_force(m)
    assert sol.feasible and sol.optimal
    assert sol.objective == ref.objective
    assert sol[xs[2]] == 0 and sol[xs[3]] == 0


@pytest.mark.fast
def test_fix_many_infeasible_detected():
    m = cpsolver.CPModel("fix-bad")
    xs = [m.bool(f"x{i}") for i in range(2)]
    m.add_exactly_one(xs)
    m.fix_many({xs[0]: 0, xs[1]: 0})
    sol = cpsolver.solve(m, time_limit_s=1.0)
    assert not sol.feasible


@pytest.mark.fast
def test_cross_window_spill_cost_monotone():
    assert cross_window_spill_cost(CFG, 0) == 0
    a = cross_window_spill_cost(CFG, CFG.bank_bytes)
    b = cross_window_spill_cost(CFG, 8 * CFG.bank_bytes)
    assert 0 < a <= b
    one_way = cross_window_spill_cost(CFG, 8 * CFG.bank_bytes,
                                      round_trip=False)
    assert one_way <= b


# --------------------------------------------------------------------------
# disk-tier artifact GC
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_disk_cache_gc_evicts_oldest(tmp_path):
    import os
    import time
    d = str(tmp_path / "programs")
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(disk_dir=d, disk_max_bytes=None)
    try:
        paths = []
        for i in range(4):
            g, _ = _chain_graph(h=16 + 4 * i, c=4, n=1)
            compile_graph(g, CFG, CompilerOptions(), cache=True)
            fresh = [os.path.join(d, f) for f in os.listdir(d)
                     if f.endswith(".rpa")]
            new = sorted(set(fresh) - set(paths))
            paths.extend(new)
            time.sleep(0.05)         # distinct mtimes for LRU order
        assert len(paths) == 4
        sizes = {p: os.path.getsize(p) for p in paths}
        total = sum(sizes.values())
        # cap to just under the total: the single oldest file must go
        cap = total - 1
        program_cache_configure(disk_max_bytes=cap)
        info = program_cache_info()
        assert info["disk_max_bytes"] == cap
        assert info["disk_evictions"] >= 1
        assert info["disk_bytes"] <= cap
        assert not os.path.exists(paths[0])          # oldest evicted
        assert os.path.exists(paths[-1])             # newest kept
        # writes keep enforcing the cap
        g, _ = _chain_graph(h=36, c=4, n=1)
        compile_graph(g, CFG, CompilerOptions(), cache=True)
        assert program_cache_info()["disk_bytes"] <= cap
    finally:
        program_cache_configure(disk_dir=saved["disk_dir"],
                                disk_max_bytes=saved["disk_max_bytes"])
        program_cache_clear()


@pytest.mark.fast
def test_disk_cache_hit_refreshes_mtime(tmp_path):
    import os
    d = str(tmp_path / "programs")
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(disk_dir=d, disk_max_bytes=None)
    try:
        g, _ = _chain_graph(h=20, c=4, n=1)
        compile_graph(g, CFG, CompilerOptions(), cache=True)
        (path,) = [os.path.join(d, f) for f in os.listdir(d)
                   if f.endswith(".rpa")]
        old = os.stat(path).st_mtime
        os.utime(path, (old - 100, old - 100))       # age it
        program_cache_clear(stats=False)             # force disk lookup
        g2, _ = _chain_graph(h=20, c=4, n=1)
        res = compile_graph(g2, CFG, CompilerOptions(), cache=True)
        assert res.cache_tier == "disk"
        assert os.stat(path).st_mtime > old - 100    # touched on hit
    finally:
        program_cache_configure(disk_dir=saved["disk_dir"],
                                disk_max_bytes=saved["disk_max_bytes"])
        program_cache_clear()
