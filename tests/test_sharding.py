"""Sharding rules, the format planner, and the HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.models.sharding import (FormatPlanner, LayerShape, MeshSpec,
                                   enforce_divisible, param_spec,
                                   tree_partition_specs)


def test_param_spec_rules():
    assert param_spec("embed", (32000, 512)) == P("model", None)
    assert param_spec("layers/attn/wq", (8, 512, 1024),
                      stacked=True) == P(None, None, "model")
    assert param_spec("layers/attn/wo", (8, 1024, 512),
                      stacked=True) == P(None, "model", None)
    assert param_spec("layers/moe/experts/w_in",
                      (8, 64, 512, 128)) == P(None, "model", None, None)
    assert param_spec("layers/norm1", (8, 512)) == P(None, None)
    assert param_spec("layers/ssm/ssm_in", (8, 512, 2304)) == \
        P(None, None, "model")


def test_fsdp_axis():
    sp = param_spec("layers/mlp/w_in", (8, 512, 2048), fsdp_axis="data")
    assert sp == P(None, "data", "model")


def test_enforce_divisible_drops_odd_dims():
    sp = enforce_divisible(P("model", None), (50280, 512),
                           {"model": 16, "data": 16})
    assert sp == P(None, None)
    sp = enforce_divisible(P("model", None), (51200, 512),
                           {"model": 16, "data": 16})
    assert sp == P("model", None)
    sp = enforce_divisible(P(("pod", "data"), None), (24, 8),
                           {"pod": 2, "data": 16})
    assert sp == P(None, None)


def test_tree_specs_match_structure():
    from repro.models.registry import abstract_params, get_arch
    cfg = get_arch("granite-moe-1b-a400m")    # full config: 32 experts
    params = abstract_params(cfg)             # eval_shape, no allocation
    specs = tree_partition_specs(params)
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(specs)
    # experts are expert-parallel over model
    assert specs["layers"]["moe"]["experts"]["w_in"][1] == "model"


def test_format_planner_prefers_depth_for_wide_layers():
    mesh = MeshSpec(n_data=16, n_model=16)
    pl = FormatPlanner(mesh)
    # huge d_out, few tokens -> depth (TP); tiny weights, many tokens
    # -> line (token split: the all-gathered weight bytes are trivial)
    wide = pl.choose(LayerShape("wide", tokens=1024, d_in=8192,
                                d_out=32768))
    thin = pl.choose(LayerShape("thin", tokens=10 ** 6, d_in=64,
                                d_out=64))
    assert thin.fmt == "line"
    assert wide.t_depth <= wide.t_line * 2     # depth competitive


def test_hlo_analyzer_counts_loop_trips():
    def loss(params, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, params)
        return (h ** 2).mean()

    L, D = 6, 64
    params = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    c = jax.jit(jax.grad(loss)).lower(params, x).compile()
    cost = analyze_hlo(c.as_text())
    expect = 3 * 2 * 4 * D * D * L        # fwd + 2 bwd dots per layer
    assert cost.flops == pytest.approx(expect, rel=0.05)
    assert cost.max_trip == L


def test_hlo_analyzer_collectives():
    mesh = jax.make_mesh((jax.device_count(),), ("d",))

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(axis=0, keepdims=True), P(None, None))

    from repro.launch.mesh import named_shardings, use_mesh
    with use_mesh(mesh):
        c = jax.jit(f, in_shardings=named_shardings(mesh, P("d", None)),
                    out_shardings=named_shardings(
                        mesh, P(None, None))).lower(
            jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
    cost = analyze_hlo(c.as_text())
    if jax.device_count() > 1:
        assert cost.wire_bytes > 0
