"""Compiled replay engine: ExecPlan lowering parity vs the interpretive
executor (bit-exact float32 / one-quant-step int8 and int4, batched and
ragged), plan-cache keying, Session micro-batching, program-cache
pinning, and the mmap-friendly artifact layout."""
import os
import zipfile

import numpy as np
import pytest

import repro.api as api
from repro.core import (program_cache_clear, program_cache_configure,
                        program_cache_info, program_cache_pin,
                        program_cache_unpin)
from repro.core.execplan import assign_slots, lower_plan
from repro.core.executor import ExecutionError, execute
from repro.core.ir import GraphBuilder
from repro.core.serialize import ArtifactError


@pytest.fixture(autouse=True)
def _isolated_cache():
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(max_entries=64, max_bytes=None, disk_dir=None)
    yield
    program_cache_clear()
    program_cache_configure(max_entries=saved["max_entries"],
                            max_bytes=saved["max_bytes"],
                            disk_dir=saved["disk_dir"])


# --------------------------------------------------------------------------
# randomized graph generator (deterministic per seed)
# --------------------------------------------------------------------------


def random_graph(seed: int):
    """A random small conv net exercising conv/dwconv/add/mul/pool/
    resize/concat/split/scalar/act/fc on a deterministic draw."""
    rng = np.random.default_rng(seed)
    b = GraphBuilder(f"rand{seed}", seed=seed)
    h = int(rng.choice([12, 16, 20]))
    c = int(rng.choice([4, 8]))
    x = b.input((h, h, c))
    x = b.conv(x, int(rng.choice([8, 12])), k=3,
               act=str(rng.choice(["relu", "relu6", "none"])))
    for _ in range(int(rng.integers(2, 5))):
        kind = rng.choice(["conv", "dwconv", "add", "pool", "scalar",
                           "act", "split", "resize"])
        cur_c = b.g.tensors[x].hwc[2]
        if kind == "conv":
            x = b.conv(x, int(rng.choice([8, 12, 16])),
                       k=int(rng.choice([1, 3])),
                       s=int(rng.choice([1, 2])),
                       act=str(rng.choice(["relu", "silu", "none"])))
        elif kind == "dwconv":
            x = b.dwconv(x, k=3, act="relu6")
        elif kind == "add":
            y = b.dwconv(x, k=3)
            x = b.add(x, y, act=str(rng.choice(["relu", "none"])))
        elif kind == "pool" and b.g.tensors[x].hwc[0] >= 4:
            x = b.maxpool(x, k=2)
        elif kind == "scalar":
            x = b.scalar(x, str(rng.choice(["add", "mul"])), 0.5)
        elif kind == "act":
            x = b.activation(x, str(rng.choice(["hswish", "sigmoid"])))
        elif kind == "split" and cur_c % 2 == 0:
            lo, hi = b.split(x, 2)
            x = b.concat([lo, hi])
        elif kind == "resize" and b.g.tensors[x].hwc[0] <= 12:
            x = b.resize(x, 2)
    x = b.global_avgpool(x)
    x = b.fc(x, 7)
    b.mark_output(x)
    return b.build(), b


def _inputs(g, n, seed=0):
    rng = np.random.default_rng(seed + 1000)
    t = g.inputs[0]
    return [rng.normal(size=t.shape).astype(np.float32) for _ in range(n)]


def _interp_outputs(m, x):
    return m(x, engine="interp")


# --------------------------------------------------------------------------
# parity properties: plan replay vs the interpretive executor
# --------------------------------------------------------------------------


@pytest.mark.fast
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_plan_float32_bit_exact_randomized(seed):
    m = api.compile(random_graph(seed), cache=False)
    for batch in (1, 3, 8):
        xs = _inputs(m.graph, batch, seed)
        plan_outs = m.run_many(xs)
        for x, got in zip(xs, plan_outs):
            want = _interp_outputs(m, x)
            for name in want:
                assert np.array_equal(got[name], want[name]), \
                    f"seed {seed} batch {batch}: {name} not bit-exact"


@pytest.mark.fast
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("weight_dtype", ["int8", "int4"])
def test_plan_quant_one_step_exact_randomized(seed, weight_dtype):
    m = api.compile(random_graph(seed), precision="int8",
                    weight_dtype=weight_dtype, cache=False)
    for batch in (1, 3, 8):
        xs = _inputs(m.graph, batch, seed)
        plan_outs = m.run_many(xs)
        for x, got in zip(xs, plan_outs):
            want = _interp_outputs(m, x)
            for name in want:
                err = float(np.max(np.abs(got[name] - want[name])))
                tol = m.semantics.plan_parity_tol(name)
                assert err <= tol, (
                    f"seed {seed} batch {batch} [{weight_dtype}]: "
                    f"{name} err {err} > one quant step {tol}")


@pytest.mark.fast
def test_plan_ragged_final_batch():
    """5 requests through bucket-8 plans: the ragged tail must match
    per-sample interpretive replay exactly."""
    m = api.compile(random_graph(3), precision="int8", cache=False)
    xs = _inputs(m.graph, 5, 3)
    outs = m.run_many(xs)
    assert len(outs) == 5
    for x, got in zip(xs, outs):
        want = _interp_outputs(m, x)
        for name in want:
            err = float(np.max(np.abs(got[name] - want[name])))
            assert err <= m.semantics.plan_parity_tol(name)


@pytest.mark.fast
def test_plan_batched_call_matches_per_sample():
    m = api.compile(random_graph(1), cache=False)
    xs = np.stack(_inputs(m.graph, 3, 1))
    batched = m(xs)                       # plan engine, batch axis
    for i in range(3):
        want = _interp_outputs(m, xs[i])
        for name in want:
            assert np.array_equal(batched[name][i], want[name])


@pytest.mark.fast
def test_plan_arena_reuse_no_stale_state():
    """Back-to-back different requests through one plan instance —
    arena slot reuse must never leak values between requests."""
    m = api.compile(random_graph(2), precision="int8", cache=False)
    xs = _inputs(m.graph, 4, 2)
    first = [m(x) for x in xs]
    again = [m(x) for x in xs]            # same plan, reused arena
    for a, b in zip(first, again):
        for name in a:
            assert np.array_equal(a[name], b[name])


@pytest.mark.fast
def test_verify_exercises_both_paths():
    m = api.compile(random_graph(0), precision="int8", cache=False)
    x = _inputs(m.graph, 1, 0)[0]
    rep = m.verify(x)
    assert rep.ok and rep.engine == "interp"
    assert m.plan_cache_info()["builds"] >= 1   # plan path really ran
    # a poisoned plan kernel must be caught by verify's parity assert
    plan = m.plan_for(1)
    orig = plan.steps[-1].run

    def poisoned(bufs, n):
        orig(bufs, n)
        out_id = plan.ids[m.graph.outputs[0].name]
        bufs[out_id][:n] += 16            # > one quant step
    plan.steps[-1] = plan.steps[-1].__class__(
        plan.steps[-1].label, plan.steps[-1].reads,
        plan.steps[-1].writes, poisoned)
    with pytest.raises(ExecutionError):
        m.verify(x)


# --------------------------------------------------------------------------
# plan cache keying + DDR accounting
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_plan_cache_keys_dtype_bucket_fingerprint():
    mf = api.compile(random_graph(0), cache=False)
    mq = api.compile(random_graph(0), precision="int8", cache=False)
    xs = _inputs(mf.graph, 3, 0)
    mf(xs[0]); mf.run_many(xs)            # buckets 1 and 4
    mq(xs[0])
    f_keys = mf.plan_cache_info()["plans"]
    q_keys = mq.plan_cache_info()["plans"]
    assert {k[1] for k in f_keys} == {"float32"}
    assert {k[1] for k in q_keys} == {"int8"}
    assert {k[2] for k in f_keys} == {1, 4}   # batch-3 -> bucket 4
    # quantization changes the graph fingerprint -> different plan keys
    assert {k[0] for k in f_keys}.isdisjoint({k[0] for k in q_keys})
    # bucket reuse is a hit, not a rebuild
    before = mf.plan_cache_info()["builds"]
    mf.run_many(xs)
    assert mf.plan_cache_info()["builds"] == before


@pytest.mark.fast
def test_plan_report_ddr_is_per_request():
    m = api.compile(random_graph(1), precision="int8", cache=False)
    x = _inputs(m.graph, 1, 1)[0]
    interp_rep = execute(m.program, m.graph, m.tiling,
                         {m.graph.inputs[0].name: x}, m.weights,
                         check=False, semantics=m.semantics)
    plan = m.plan_for(8)
    rep = plan.execution_report({}, n=8)
    assert rep.batch == 8 and rep.engine == "plan"
    # batched plan reports the same per-request DDR as the interpreter
    assert rep.ddr_bytes == interp_rep.ddr_bytes
    assert rep.ticks == interp_rep.ticks


@pytest.mark.fast
def test_assign_slots_reuses_disjoint_lifetimes():
    sizes = [100, 100, 100]
    # 0 and 2 are disjoint in time -> may share; 1 overlaps both
    offsets, total = assign_slots(sizes, [(0, 2), (1, 5), (3, 6)])
    assert offsets[0] == offsets[2]
    assert offsets[1] != offsets[0]
    assert total < sum(128 for _ in sizes)
    # overlapping intervals never share bytes
    offsets, _ = assign_slots(sizes, [(0, 3), (1, 5), (2, 6)])
    assert len({offsets[0], offsets[1], offsets[2]}) == 3


# --------------------------------------------------------------------------
# Session: micro-batching queue + admission policy
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_session_run_many_and_queue():
    sess = api.Session(max_batch=4)
    sess.add(random_graph(0), name="m0", precision="int8")
    sess.add(random_graph(1), name="m1")
    xs = _inputs(sess["m0"].graph, 6, 0)
    outs = sess.run_many("m0", xs)
    assert len(outs) == 6
    st = sess.stats()["models"]["m0"]
    assert st["batches"] == 2 and st["batched_requests"] == 6
    assert st["max_batch_seen"] == 4

    t0 = sess.submit("m0", xs[0])
    t1 = sess.submit("m1", _inputs(sess["m1"].graph, 1, 1)[0])
    t2 = sess.submit("m0", xs[1])
    assert sess.queue_depth == 3 and not t0.done
    r0 = t0.result()                      # auto-flush of m0's queue ONLY
    assert t2.done and not t1.done        # per-model: m1 stays queued
    assert sess.queue_depth == 1
    sess.flush()                          # full drain picks up m1
    assert t1.done and sess.queue_depth == 0
    want = sess["m0"](xs[0], engine="interp")
    for name in want:
        err = float(np.max(np.abs(r0[name] - want[name])))
        assert err <= sess["m0"].semantics.plan_parity_tol(name)
    with pytest.raises(KeyError):
        sess.submit("nope", xs[0])


@pytest.mark.fast
def test_session_flush_failure_isolated_per_model():
    """A bad request failing one model's batch must fail only that
    model's tickets; other models' queued work still executes."""
    sess = api.Session(max_batch=4)
    sess.add(random_graph(0), name="good", precision="int8")
    sess.add(random_graph(1), name="bad")
    ok_x = _inputs(sess["good"].graph, 1, 0)[0]
    bad_x = np.zeros((3, 3, 1), dtype=np.float32)   # wrong shape
    t_bad = sess.submit("bad", bad_x)
    t_good = sess.submit("good", ok_x)
    with pytest.raises(Exception):
        sess.flush()
    # the failed batch's ticket re-raises; the good one still ran or
    # remains queued and resolves on its own flush
    with pytest.raises(Exception):
        t_bad.result()
    out = t_good.result()
    want = sess["good"](ok_x, engine="interp")
    for name in want:
        err = float(np.max(np.abs(out[name] - want[name])))
        assert err <= sess["good"].semantics.plan_parity_tol(name)
    assert sess.queue_depth == 0


@pytest.mark.fast
def test_plan_buckets_share_lowered_steps():
    """Step lowering (weight constants included) runs once per model;
    each batch bucket only adds its own arena."""
    m = api.compile(random_graph(0), precision="int8", cache=False)
    p1 = m.plan_for(1)
    p8 = m.plan_for(8)
    assert p1.steps is p8.steps          # shared, not re-lowered
    assert p1.capacity == 1 and p8.capacity == 8


@pytest.mark.fast
def test_session_pin_survives_eviction():
    program_cache_configure(max_entries=1)
    sess = api.Session()
    sess.add(random_graph(0), name="hot", precision="int8", pin=True)
    assert sess.pinned() == ["hot"]
    info = program_cache_info()
    assert info["pinned_entries"] == 1 and info["pinned_fps"] == 1
    # a second compile would evict the only entry — but it is pinned,
    # so the new entry is the one turned away at the cap instead
    sess.add(random_graph(1), name="cold")
    info = program_cache_info()
    assert info["pinned_entries"] == 1
    # pinned program still served from memory
    m = api.compile(random_graph(0), precision="int8")
    assert m.cache_tier == "memory"
    sess.unpin("hot")
    assert program_cache_info()["pinned_fps"] == 0
    program_cache_unpin("nonexistent")    # no-op, never raises


@pytest.mark.fast
def test_pin_unpin_eviction_order():
    program_cache_configure(max_entries=2)
    m0 = api.compile(random_graph(0))
    program_cache_pin(m0.fingerprint)
    api.compile(random_graph(1))
    api.compile(random_graph(2))          # evicts graph 1, not pinned 0
    assert api.compile(random_graph(0)).cache_tier == "memory"
    assert api.compile(random_graph(2)).cache_tier == "memory"
    program_cache_unpin(m0.fingerprint)


# --------------------------------------------------------------------------
# mmap-friendly artifact layout (version 2)
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_artifact_v2_mmap_round_trip(tmp_path):
    m = api.compile(random_graph(0), precision="int8", cache=False)
    p = str(tmp_path / "m.rpa")
    m.save(p)
    # weight members are STORED (uncompressed) .npy files
    with zipfile.ZipFile(p) as zf:
        members = [i for i in zf.infolist()
                   if i.filename.startswith("arrays/")]
        assert members and all(i.compress_type == zipfile.ZIP_STORED
                               for i in members)
    m2 = api.CompiledModel.load(p, mmap=True)
    assert any(isinstance(getattr(w, "base", None), np.memmap)
               for w in m2.weights.values())
    x = _inputs(m.graph, 1, 0)[0]
    a, b = m(x), m2(x)
    for name in a:
        assert np.array_equal(a[name], b[name])
    # interpretive replay works off mmapped weights too (copy-on-write)
    assert m2.verify(x).ok
    # non-mmap load still bit-exact
    m3 = api.CompiledModel.load(p)
    c = m3(x)
    for name in a:
        assert np.array_equal(a[name], c[name])


@pytest.mark.fast
def test_artifact_v2_corruption_still_rejected(tmp_path):
    m = api.compile(random_graph(1), cache=False)
    p = str(tmp_path / "m.rpa")
    m.save(p)
    # flip one byte inside a *stored* array member
    with zipfile.ZipFile(p) as zf:
        info = next(i for i in zf.infolist()
                    if i.filename.startswith("arrays/"))
        data_start = info.header_offset + 30 + len(info.filename)
    blob = bytearray(open(p, "rb").read())
    blob[data_start + 100] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p, mmap=True)
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p)


@pytest.mark.fast
def test_artifact_v1_backward_compatible(tmp_path):
    """A version-1 artifact (single deflated arrays.npz) still loads."""
    import hashlib
    import io
    import json as _json

    from repro.core import serialize

    m = api.compile(random_graph(2), cache=False)
    p2 = str(tmp_path / "v2.rpa")
    m.save(p2)
    # rewrite as a v1 container: same payloads, arrays bundled in npz
    key, payloads, arrays = serialize.read_artifact(p2)
    entries = {f"{n}.json": _json.dumps(
        pl, sort_keys=True, separators=(",", ":")).encode()
        for n, pl in payloads.items()}
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    entries["arrays.npz"] = buf.getvalue()
    meta = {"magic": serialize.ARTIFACT_MAGIC, "version": 1, "key": key,
            "manifest": {n: hashlib.sha256(b).hexdigest()
                         for n, b in sorted(entries.items())}}
    p1 = str(tmp_path / "v1.rpa")
    with zipfile.ZipFile(p1, "w", zipfile.ZIP_DEFLATED) as zf:
        zf.writestr("meta.json", _json.dumps(
            meta, sort_keys=True, separators=(",", ":")).encode())
        for n, b in sorted(entries.items()):
            zf.writestr(n, b)
    m1 = api.CompiledModel.load(p1)
    x = _inputs(m.graph, 1, 2)[0]
    a, b = m(x), m1(x)
    for name in a:
        assert np.array_equal(a[name], b[name])
    # unknown future versions are still rejected
    meta["version"] = 99
    with zipfile.ZipFile(p1, "w", zipfile.ZIP_DEFLATED) as zf:
        zf.writestr("meta.json", _json.dumps(
            meta, sort_keys=True, separators=(",", ":")).encode())
        for n, b in sorted(entries.items()):
            zf.writestr(n, b)
    with pytest.raises(ArtifactError):
        api.CompiledModel.load(p1)


@pytest.mark.fast
def test_session_load_mmap(tmp_path):
    m = api.compile(random_graph(0), precision="int8", cache=False)
    p = str(tmp_path / "m.rpa")
    m.save(p)
    sess = api.Session()
    m2 = sess.load(p, name="frommap", pin=True)
    assert any(isinstance(getattr(w, "base", None), np.memmap)
               for w in m2.weights.values())
    assert "frommap" in sess.pinned()
    x = _inputs(m.graph, 1, 0)[0]
    out = sess.run("frommap", x)
    want = m(x)
    for name in want:
        assert np.array_equal(out[name], want[name])
