"""Compiler hot-path regressions (no optional deps, all `fast`):

  * the incremental CP engine reaches the same optimum as the seed
    (reference) engine on fixed models, with and without MaxTerms;
  * solve_many returns the same solutions parallel and serial;
  * the compiled-program cache hits on identical (graph, config,
    options) and misses when any key component changes;
  * the memoized cost model matches the unmemoized one;
  * the parallel/incremental compiler still produces oracle-exact
    programs with scheduled latency no worse than the seed engine's.
"""
import random

import numpy as np
import pytest

from repro.core import NEUTRON_2TOPS, CompilerOptions, compile_graph
from repro.core import npu as npu_mod
from repro.core.cpsolver import (CPModel, MaxTerm, SolveTask, brute_force,
                                 solve, solve_many, solve_reference)
from repro.core.executor import execute
from repro.core.ir import GraphBuilder
from repro.core.npu import NPUConfig, compute_job_cost, dma_cost
from repro.core.pipeline import program_cache_clear

pytestmark = pytest.mark.fast


# --------------------------------------------------------------------------
# Solver engine parity
# --------------------------------------------------------------------------


def _random_model(seed: int, with_max_terms: bool = False) -> CPModel:
    rng = random.Random(seed)
    n = rng.randint(2, 12)
    m = CPModel(f"fixed{seed}")
    for i in range(n):
        m.bool(f"x{i}")
    for c in range(rng.randint(1, 7)):
        k = rng.randint(1, min(4, n))
        vs = rng.sample(range(n), k)
        coefs = [rng.randint(-3, 3) or 1 for _ in vs]
        m.add(list(zip(vs, coefs)), "<=", rng.randint(-2, 4), f"c{c}")
    obj = [(v, rng.randint(-5, 5)) for v in range(n) if rng.random() < 0.8]
    m.minimize(obj)
    if with_max_terms:
        k = rng.randint(1, n)
        vs = rng.sample(range(n), k)
        m.max_terms = [MaxTerm([
            (rng.randint(0, 3), [(v, rng.randint(0, 4)) for v in vs]),
            (rng.randint(0, 3), [(v, rng.randint(0, 4)) for v in vs])])]
    return m


@pytest.mark.parametrize("seed", list(range(0, 40)))
def test_incremental_matches_seed_solver(seed):
    m = _random_model(seed, with_max_terms=(seed % 2 == 0))
    got = solve(m, time_limit_s=10.0)
    ref = solve_reference(m, time_limit_s=10.0)
    assert got.feasible == ref.feasible
    if ref.feasible:
        assert got.optimal and ref.optimal
        assert got.objective == ref.objective
        vals = [got.values[v] for v in range(m.n_vars)]
        assert not m.check(vals)


@pytest.mark.parametrize("seed", [3, 11, 27])
def test_incremental_matches_brute_force(seed):
    m = _random_model(seed, with_max_terms=True)
    got = solve(m, time_limit_s=10.0)
    want = brute_force(m)
    assert got.feasible == want.feasible
    if want.feasible:
        assert got.objective == want.objective


def test_incremental_respects_warm_start_and_fixed():
    m = CPModel("ws")
    a, b = m.bool("a"), m.bool("b")
    m.add([(a, 1), (b, 1)], ">=", 1)
    m.minimize([(a, 1), (b, 2)])
    sol = solve(m, time_limit_s=5.0, warm_start={a: 0, b: 1})
    assert sol.feasible and sol.objective == 1
    m2 = CPModel("fix")
    c, d = m2.bool("c"), m2.bool("d")
    m2.fix(c, 1)
    m2.minimize([(c, 5), (d, 1)])
    s2 = solve(m2, time_limit_s=5.0)
    assert s2.feasible and s2[c] == 1 and s2[d] == 0


def test_solve_many_parallel_matches_serial():
    tasks = [SolveTask(_random_model(s), time_limit_s=10.0)
             for s in range(8)]
    par = solve_many(tasks, parallel=True)
    ser = solve_many(tasks, parallel=False)
    for p, s in zip(par, ser):
        assert p.feasible == s.feasible
        if s.feasible:
            assert p.objective == s.objective


# --------------------------------------------------------------------------
# Cost-model memoization
# --------------------------------------------------------------------------


def _tiny_graph(seed: int = 0):
    b = GraphBuilder("tiny", seed=seed)  # name is part of the fingerprint
    x = b.input((16, 16, 8))
    x = b.conv(x, 16, k=3, act="relu")
    x = b.dwconv(x, k=3, act="relu")
    x = b.maxpool(x, k=2)
    x = b.conv(x, 24, k=1, act="relu6")
    x = b.global_avgpool(x)
    x = b.fc(x, 10)
    b.mark_output(x)
    return b.build(), b


def test_cost_memo_matches_uncached():
    g, _ = _tiny_graph()
    cfg = NEUTRON_2TOPS
    try:
        for op in g.ops:
            npu_mod.set_cost_memo(True)
            H = g.tensors[op.output].shape[0] \
                if len(g.tensors[op.output].shape) == 3 else 1
            memo1 = compute_job_cost(cfg, g, op, H, "depth")
            memo2 = compute_job_cost(cfg, g, op, H, "depth")
            assert memo2 is memo1          # second call is a cache hit
            npu_mod.set_cost_memo(False)
            cold = compute_job_cost(cfg, g, op, H, "depth")
            assert (memo1.cycles, memo1.macs, memo1.bound) == \
                (cold.cycles, cold.macs, cold.bound)
        npu_mod.set_cost_memo(True)
        assert dma_cost(cfg, 12345) == cfg.dma_setup_cycles + \
            int(np.ceil(12345 / cfg.ddr_bytes_per_cycle))
    finally:
        npu_mod.set_cost_memo(True)


# --------------------------------------------------------------------------
# Compiled-program cache
# --------------------------------------------------------------------------


def test_program_cache_hits_and_keys():
    program_cache_clear()
    g, _ = _tiny_graph()
    a = compile_graph(g, NEUTRON_2TOPS, CompilerOptions())
    assert not a.cache_hit
    g2, _ = _tiny_graph()          # same structure, fresh objects
    b = compile_graph(g2, NEUTRON_2TOPS, CompilerOptions())
    assert b.cache_hit
    assert b.program is a.program  # identical cached NPUProgram
    assert b.cache_key == a.cache_key
    # a different NPUConfig must miss
    from dataclasses import replace
    other_cfg = replace(NEUTRON_2TOPS, tcm_banks=16,
                        tcm_bytes=NEUTRON_2TOPS.tcm_bytes // 2)
    c = compile_graph(g2, other_cfg, CompilerOptions())
    assert not c.cache_hit
    assert c.program is not a.program
    # different compile options must miss too
    d = compile_graph(g2, NEUTRON_2TOPS, CompilerOptions(fusion=False))
    assert not d.cache_hit
    # a structurally different graph must miss
    g3, _ = _tiny_graph(seed=1)    # same topology, same names -> same fp
    b3 = GraphBuilder("other", seed=0)
    x = b3.input((16, 16, 8))
    x = b3.conv(x, 16, k=3, act="relu")
    b3.mark_output(x)
    e = compile_graph(b3.build(), NEUTRON_2TOPS, CompilerOptions())
    assert not e.cache_hit
    assert g3.fingerprint() == g.fingerprint()


def test_program_cache_can_be_bypassed():
    program_cache_clear()
    g, _ = _tiny_graph()
    a = compile_graph(g, NEUTRON_2TOPS, CompilerOptions(), cache=False)
    b = compile_graph(g, NEUTRON_2TOPS, CompilerOptions(), cache=False)
    assert not a.cache_hit and not b.cache_hit
    assert a.program is not b.program


# --------------------------------------------------------------------------
# End-to-end: overhauled hot path stays oracle-exact and no slower on the
# model's own latency metric than the seed engine
# --------------------------------------------------------------------------


def test_overhauled_compiler_oracle_exact_and_latency_no_worse():
    g, b = _tiny_graph()
    new = compile_graph(g, NEUTRON_2TOPS, CompilerOptions(), cache=False)
    g2, b2 = _tiny_graph()
    npu_mod.set_cost_memo(False)
    try:
        seed = compile_graph(g2, NEUTRON_2TOPS,
                             CompilerOptions.seed_solver(), cache=False)
    finally:
        npu_mod.set_cost_memo(True)
    inp = {g.inputs[0].name: np.random.default_rng(0).normal(
        size=g.inputs[0].shape).astype(np.float32)}
    rep = execute(new.program, g, new.tiling, inp, b._weights)
    assert rep.ok
    rep2 = execute(seed.program, g2, seed.tiling, inp, b2._weights)
    assert rep2.ok
    for name in rep.outputs:
        np.testing.assert_array_equal(rep.outputs[name],
                                      rep2.outputs[name])
    assert new.program.latency_ms() <= seed.program.latency_ms() * 1.001
