"""Shared test fixtures.

``chaos``-marked tests exercise fault injection (worker stalls, plan
poisoning, clock skew) against live worker threads — the one class of
test that could genuinely hang if the robustness machinery regresses.
Each gets a *hard* per-test timeout via SIGALRM (no pytest-timeout
dependency): the alarm fires in the main thread and fails the test with
a diagnostic instead of wedging the suite.
"""
import signal

import pytest

CHAOS_TIMEOUT_S = 60


@pytest.fixture(autouse=True)
def _chaos_hard_timeout(request):
    if request.node.get_closest_marker("chaos") is None:
        yield
        return
    if not hasattr(signal, "SIGALRM"):      # non-POSIX: best effort
        yield
        return

    def _expired(signum, frame):
        pytest.fail(
            f"chaos test exceeded the hard {CHAOS_TIMEOUT_S}s timeout — "
            f"a worker/supervisor is likely hung", pytrace=False)

    prev = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(CHAOS_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)
