"""CP solver: correctness vs exhaustive search (property-based)."""
import random

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import cpsolver
from repro.core.cpsolver import CPModel, MaxTerm, brute_force, solve


def _random_model(rng: random.Random, n_vars: int, n_cons: int) -> CPModel:
    m = CPModel("rand")
    for i in range(n_vars):
        m.bool(f"x{i}")
    for c in range(n_cons):
        k = rng.randint(1, min(4, n_vars))
        vs = rng.sample(range(n_vars), k)
        coefs = [rng.randint(-3, 3) or 1 for _ in vs]
        rhs = rng.randint(-2, 4)
        m.add(list(zip(vs, coefs)), "<=", rhs, f"c{c}")
    obj = [(v, rng.randint(-5, 5)) for v in range(n_vars)
           if rng.random() < 0.8]
    m.minimize(obj)
    return m


@given(seed=st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_solver_matches_brute_force(seed):
    rng = random.Random(seed)
    n = rng.randint(2, 10)
    m = _random_model(rng, n, rng.randint(1, 6))
    got = solve(m, time_limit_s=5.0)
    want = brute_force(m)
    assert got.feasible == want.feasible
    if want.feasible:
        assert got.objective == want.objective, (seed, got, want)
        # returned assignment must itself be feasible
        vals = [got.values[v] for v in range(m.n_vars)]
        assert not m.check(vals)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_solver_with_max_terms(seed):
    rng = random.Random(seed)
    n = rng.randint(2, 8)
    m = _random_model(rng, n, rng.randint(0, 3))
    # add an Eq.(8)-shaped objective: max over two linear expressions
    k = rng.randint(1, n)
    vs = rng.sample(range(n), k)
    mt = MaxTerm([(rng.randint(0, 3),
                   [(v, rng.randint(0, 4)) for v in vs]),
                  (rng.randint(0, 3),
                   [(v, rng.randint(0, 4)) for v in vs])])
    m.max_terms = [mt]
    got = solve(m, time_limit_s=5.0)
    want = brute_force(m)
    assert got.feasible == want.feasible
    if want.feasible:
        assert got.objective == want.objective


def test_warm_start_is_used():
    m = CPModel("ws")
    a, b = m.bool("a"), m.bool("b")
    m.add([(a, 1), (b, 1)], ">=", 1)
    m.minimize([(a, 1), (b, 2)])
    sol = solve(m, time_limit_s=5.0, warm_start={a: 0, b: 1})
    assert sol.feasible and sol.objective == 1   # optimal a=1,b=0


def test_infeasible_detected():
    m = CPModel("inf")
    a = m.bool("a")
    m.add([(a, 1)], ">=", 1)
    m.add([(a, 1)], "<=", 0)
    sol = solve(m, time_limit_s=2.0)
    assert not sol.feasible


def test_fixed_vars_respected():
    m = CPModel("fix")
    a, b = m.bool("a"), m.bool("b")
    m.fix(a, 1)
    m.minimize([(a, 5), (b, 1)])
    sol = solve(m, time_limit_s=2.0)
    assert sol.feasible and sol[a] == 1 and sol[b] == 0
