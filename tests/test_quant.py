"""Quantization subsystem: PTQ parity with the float oracle, quantized
program replay vs the quantized functional oracle, qparams round-trip
through the graph fingerprint / program cache, int4 pack/unpack, and the
precision-aware cost model."""
import numpy as np
import pytest

from repro import quant
from repro.core import (NEUTRON_2TOPS, CompilerOptions, compile_graph,
                        graph_precision)
from repro.core.executor import execute
from repro.core.ir import GraphBuilder, reference_execute
from repro.core.npu import compute_job_cost, elem_bytes, mac_rate
from repro.core.pipeline import program_cache_clear


def _tiny_graph(seed: int = 0):
    b = GraphBuilder("qtiny", seed=seed)
    x = b.input((16, 16, 8))
    x = b.conv(x, 16, k=3, act="relu")
    x = b.dwconv(x, k=3, act="relu6")
    x = b.maxpool(x, k=2)
    x = b.conv(x, 24, k=1, act="silu")
    sk = x
    x = b.conv(x, 24, k=3, act="relu")
    x = b.add(x, sk)
    x = b.global_avgpool(x)
    x = b.fc(x, 10)
    b.mark_output(x)
    return b.build(), b


def _samples(g, n=3, seed=0):
    rng = np.random.default_rng(seed)
    t = g.inputs[0]
    return [{t.name: rng.normal(size=t.shape).astype(np.float32)}
            for _ in range(n)]


def _quantized_tiny(weight_dtype="int8", method="minmax"):
    g, b = _tiny_graph()
    cal = _samples(g)
    calib = quant.calibrate(g, b._weights, cal, method=method)
    qm = quant.quantize_graph(g, b._weights, calib,
                              weight_dtype=weight_dtype)
    quant.measure_quant_error(qm, cal)
    return g, b, qm, cal


# --------------------------------------------------------------------------
# fast smoke: PTQ -> compile -> replay parity (tier-1 sub-minute subset)
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_quant_smoke_compile_replay_parity():
    g, b, qm, cal = _quantized_tiny()
    assert graph_precision(g) == "int8"
    res = compile_graph(g, NEUTRON_2TOPS, CompilerOptions(precision="int8"),
                        cache=False)
    sem = quant.QuantSemantics(qm)
    rep = execute(res.program, g, res.tiling, cal[0], qm.weights_f,
                  semantics=sem)
    assert rep.ok  # replay matches the quantized oracle (1-step tol)
    # and the dequantized outputs sit within the calibrated tolerance of
    # the float oracle
    ref = reference_execute(g, cal[0], qm.weights_f)
    for t in g.outputs:
        err = float(np.max(np.abs(rep.outputs[t.name] - ref[t.name])))
        assert err <= sem.float_tolerance(t.name), (t.name, err)


@pytest.mark.fast
def test_quant_speedup_on_own_latency_model():
    g, b, qm, _ = _quantized_tiny()
    gf, bf = _tiny_graph()
    q = compile_graph(g, NEUTRON_2TOPS, cache=False)
    f = compile_graph(gf, NEUTRON_2TOPS, cache=False)
    assert q.program.latency_ms() < f.program.latency_ms()


# --------------------------------------------------------------------------
# fingerprint / program-cache round trip
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_qparams_round_trip_fingerprint_and_cache():
    program_cache_clear()
    gf, bf = _tiny_graph()
    fp_float = gf.fingerprint()
    a = compile_graph(gf, NEUTRON_2TOPS)
    assert not a.cache_hit

    g, b, qm, _ = _quantized_tiny()
    assert g.fingerprint() != fp_float  # dtype+qparams enter the hash
    q1 = compile_graph(g, NEUTRON_2TOPS)
    assert not q1.cache_hit, "quantized graph must MISS the float entry"
    assert q1.program is not a.program

    g2, b2, qm2, _ = _quantized_tiny()  # identical PTQ -> identical fp
    assert g2.fingerprint() == g.fingerprint()
    q2 = compile_graph(g2, NEUTRON_2TOPS)
    assert q2.cache_hit and q2.program is q1.program

    # different calibration method -> different qparams -> miss
    g3, b3, qm3, _ = _quantized_tiny(method="percentile")
    q3 = compile_graph(g3, NEUTRON_2TOPS)
    assert not q3.cache_hit


@pytest.mark.fast
def test_precision_option_guard():
    gf, _ = _tiny_graph()
    with pytest.raises(ValueError):
        compile_graph(gf, NEUTRON_2TOPS, CompilerOptions(precision="int8"))
    compile_graph(gf, NEUTRON_2TOPS,
                  CompilerOptions(precision="float32"), cache=False)
    g, b, qm, _ = _quantized_tiny()
    with pytest.raises(ValueError):
        compile_graph(g, NEUTRON_2TOPS,
                      CompilerOptions(precision="float32"))


# --------------------------------------------------------------------------
# int4 packing
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_int4_pack_unpack_fixed_vectors():
    q = np.array([-8, -1, 0, 7, 3, -4, 5], dtype=np.int8)
    p = quant.pack_int4(q)
    assert p.dtype == np.uint8 and p.size == 4  # 7 values -> 4 bytes
    back = quant.unpack_int4(p, q.size)
    np.testing.assert_array_equal(back, q)
    with pytest.raises(ValueError):
        quant.pack_int4(np.array([8], dtype=np.int8))


def test_int4_pack_unpack_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(data=st.lists(st.integers(-8, 7), min_size=0, max_size=257))
    @settings(max_examples=50, deadline=None)
    def roundtrip(data):
        q = np.array(data, dtype=np.int8)
        back = quant.unpack_int4(quant.pack_int4(q), q.size)
        np.testing.assert_array_equal(back, q)
        assert quant.pack_int4(q).size == (q.size + 1) // 2

    roundtrip()


@pytest.mark.fast
def test_int4_weights_end_to_end():
    g, b, qm, cal = _quantized_tiny(weight_dtype="int4")
    for t in g.tensors.values():
        if t.is_param and len(t.shape) == 4:
            assert t.dtype == "int4"
            assert t.bytes == -(-t.elems // 2)  # ceil(elems/2) packed
    res = compile_graph(g, NEUTRON_2TOPS, cache=False)
    rep = execute(res.program, g, res.tiling, cal[0], qm.weights_f,
                  semantics=quant.QuantSemantics(qm))
    assert rep.ok


# --------------------------------------------------------------------------
# precision-aware cost model
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_cost_model_precision_aware():
    assert elem_bytes("int8") == 1.0 and elem_bytes("float32") == 4.0
    assert elem_bytes("int4") == 0.5
    assert mac_rate("int8") == 1.0 and mac_rate("float32") == 0.5

    gf, _ = _tiny_graph()
    g, b, qm, _ = _quantized_tiny()
    cfg = NEUTRON_2TOPS
    for opf, opq in zip(gf.ops, g.ops):
        assert opf.kind == opq.kind
        H = gf.tensors[opf.output].shape[0] \
            if len(gf.tensors[opf.output].shape) == 3 else 1
        cf = compute_job_cost(cfg, gf, opf, H, "depth")
        cq = compute_job_cost(cfg, g, opq, H, "depth")
        assert cq.cycles <= cf.cycles, opf.kind
        assert cq.out_bytes <= cf.out_bytes
        if opf.kind in ("conv", "dwconv", "fc"):
            # int8 weights cut traffic ~4x (bias stays int32/4B)
            assert cq.w_bytes <= cf.w_bytes // 2

    # element-size-correct tiles: int8 tensors occupy 4x fewer bytes
    for name, tf in gf.tensors.items():
        assert g.tensors[name].bytes * 4 >= tf.bytes


# --------------------------------------------------------------------------
# benchmark vision graphs: quantized-vs-float executor parity
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["mobilenet_v1", "mobilenet_v2"])
def test_vision_quantized_parity(name):
    import repro.api as api
    model = api.compile(name, precision="int8", res_scale=0.25,
                        calib_samples=2, cache=False)
    g, qm, sem = model.graph, model.qm, model.semantics
    rng = np.random.default_rng(7)
    inp = {g.inputs[0].name: rng.normal(
        size=g.inputs[0].shape).astype(np.float32)}
    rep = model.verify(inp)
    assert rep.ok
    ref = reference_execute(g, inp, qm.weights_f)
    for t in g.outputs:
        err = float(np.max(np.abs(rep.outputs[t.name] - ref[t.name])))
        assert err <= sem.float_tolerance(t.name), (t.name, err)


def test_vision_quantized_latency_speedup():
    import repro.api as api
    name = "mobilenet_v2"
    f = api.compile(name, precision="float32", res_scale=0.25, cache=False)
    q = api.compile(name, precision="int8", res_scale=0.25,
                    calib_samples=2, cache=False)
    # the acceptance bar: >= 1.5x on the scheduled-latency model
    assert f.program.latency_ms() / q.program.latency_ms() >= 1.5


# --------------------------------------------------------------------------
# calibration / observers
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_observers():
    mm = quant.MinMaxObserver()
    mm.update(np.array([-1.0, 2.0]))
    mm.update(np.array([0.5, 3.0]))
    assert mm.range() == (-1.0, 3.0)

    pc = quant.PercentileObserver(99.0)
    rng = np.random.default_rng(0)
    x = rng.normal(size=10000)
    x[0] = 1e6  # outlier must be clipped
    pc.update(x)
    lo, hi = pc.range()
    assert hi < 100.0 and lo < 0 < hi

    ch = quant.PerChannelMinMaxObserver(axis=0)
    ch.update(np.array([[1.0, -2.0], [3.0, 4.0]]))
    lo, hi = ch.range()
    np.testing.assert_array_equal(lo, [-2.0, 3.0])
    np.testing.assert_array_equal(hi, [1.0, 4.0])


@pytest.mark.fast
def test_quantize_dequantize_roundtrip():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(5, 5, 4)).astype(np.float32)
    qp = quant.qparams_from_range(float(x.min()), float(x.max()))
    q = quant.quantize(x, qp)
    assert q.dtype == np.int8
    err = np.max(np.abs(quant.dequantize(q, qp) - x))
    assert err <= float(np.atleast_1d(qp.scale)[0]) * 0.5 + 1e-7
