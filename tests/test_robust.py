"""Fault-tolerant serving runtime: deadlines, backpressure, worker
pool, circuit breaker, chaos schedules.

The serving robustness contract under test: **every submitted ticket
terminates** — with a result or a typed error — under load shedding,
deadline expiry, plan poisoning, artifact corruption, worker stalls and
clock skew; a tripped model keeps serving *correct* outputs through the
interpretive oracle engine until its re-lower probe recovers.
"""
import threading
import time

import numpy as np
import pytest

import repro.api as api
import repro.runtime.chaos as chaos
from repro.api import DeadlineExceeded, FlushError, Overloaded, WorkerLost
from repro.api.compiled import CompiledModel
from repro.core import program_cache_clear, program_cache_configure, \
    program_cache_info
from repro.runtime.fault import FaultMonitor
from repro.runtime.serving import CircuitBreaker, \
    LatencyHistogram, ServerPool, Ticket

from test_execplan import random_graph, _inputs


@pytest.fixture(autouse=True)
def _isolated_cache():
    saved = program_cache_info()
    program_cache_clear()
    program_cache_configure(max_entries=64, max_bytes=None, disk_dir=None)
    yield
    program_cache_clear()
    program_cache_configure(max_entries=saved["max_entries"],
                            max_bytes=saved["max_bytes"],
                            disk_dir=saved["disk_dir"])


def _session(**kw):
    kw.setdefault("max_batch", 4)
    sess = api.Session(**kw)
    sess.add(random_graph(0), name="m0", precision="int8")
    return sess


def _feed(sess, name="m0", seed=0):
    return _inputs(sess[name].graph, 1, seed)[0]


def _check_output(sess, name, out, feed):
    want = sess[name](feed, engine="interp")
    for k in want:
        err = float(np.max(np.abs(out[k] - want[k])))
        assert err <= sess[name].semantics.plan_parity_tol(k), \
            f"{name}/{k}: served output diverged from oracle by {err}"


# --------------------------------------------------------------------------
# fault monitor fixes (heartbeat registry)
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_fault_monitor_dead_hosts_at_time_zero():
    """now=0.0 must be honoured, not silently replaced by wall time."""
    mon = FaultMonitor(n_hosts=2, timeout_s=1.0)
    assert mon.dead_hosts(now=0.0) == []


@pytest.mark.fast
def test_fault_monitor_beat_tolerates_unknown_host():
    mon = FaultMonitor(n_hosts=1, timeout_s=1.0)
    mon.beat(7, step=3, step_time_s=0.5)     # auto-registers
    assert 7 in mon.beats and mon.step_times[7] == [0.5]
    mon.retire(7)
    assert 7 not in mon.beats and 7 not in mon.step_times
    mon.retire(7)                            # idempotent


# --------------------------------------------------------------------------
# primitives: histogram + breaker
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for ms in range(1, 101):
        h.record(float(ms))
    snap = h.snapshot()
    assert snap["count"] == 100
    assert 45 <= snap["p50_ms"] <= 56       # log-bucket edge tolerance
    assert 90 <= snap["p99_ms"] <= 110
    assert snap["max_ms"] == 100.0
    assert abs(snap["mean_ms"] - 50.5) < 1e-6


@pytest.mark.fast
def test_circuit_breaker_state_machine():
    br = CircuitBreaker(threshold=3, cooldown_s=1.0)
    assert br.allow_plan()
    assert not br.record_failure(now=0.0)
    assert not br.record_failure(now=0.0)
    br.record_success()                      # success resets the streak
    assert not br.record_failure(now=0.0)
    assert not br.record_failure(now=0.0)
    assert br.record_failure(now=0.0)        # third consecutive: trips
    assert br.state == "open" and not br.allow_plan()
    assert not br.try_probe(now=0.5)         # cooldown not elapsed
    assert br.try_probe(now=1.5)             # claims the probe
    assert br.state == "half_open"
    assert not br.try_probe(now=1.5)         # only one winner
    br.probe_failed(now=1.5)
    assert br.state == "open"
    assert br.try_probe(now=3.0)
    br.probe_succeeded()
    assert br.state == "closed" and br.allow_plan()
    assert br.snapshot()["trips"] == 1 and br.snapshot()["recoveries"] == 1


# --------------------------------------------------------------------------
# admission control + deadlines (sync mode)
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_bounded_queue_sheds_with_retry_hint():
    sess = _session(max_queue=5)
    x = _feed(sess)
    for _ in range(5):
        sess.submit("m0", x)
    with pytest.raises(Overloaded) as ei:
        sess.submit("m0", x)
    assert ei.value.model == "m0"
    assert ei.value.queue_depth == 5
    assert ei.value.retry_after_ms >= 1.0
    assert sess.flush() == 5
    assert sess.stats()["models"]["m0"]["shed"] == 1
    sess.submit("m0", x)                     # capacity freed
    sess.flush()


@pytest.mark.fast
def test_deadline_expiry_ordering():
    """Expired tickets fail with DeadlineExceeded *without executing*;
    live tickets in the same queue still run."""
    sess = _session()
    x = _feed(sess)
    t_dead = sess.submit("m0", x, deadline_ms=0.0)    # expires instantly
    assert t_dead.done and isinstance(t_dead.error, DeadlineExceeded)
    before = sess.stats()["models"]["m0"]["requests"]

    with chaos.inject() as c:
        t_soon = sess.submit("m0", x, deadline_ms=1.0)
        t_late = sess.submit("m0", x, deadline_ms=10_000.0)
        t_none = sess.submit("m0", x)
        c.skew_clock(0.5)            # half a second passes "instantly"
        sess.flush("m0")
    with pytest.raises(DeadlineExceeded) as ei:
        t_soon.result()
    assert ei.value.late_ms > 0
    _check_output(sess, "m0", t_late.result(), x)
    _check_output(sess, "m0", t_none.result(), x)
    st = sess.stats()["models"]["m0"]
    assert st["deadline_misses"] == 2
    # the expired tickets consumed zero execution
    assert st["requests"] == before + 2


@pytest.mark.fast
def test_per_model_flush_does_not_drain_other_models():
    sess = _session()
    sess.add(random_graph(1), name="m1")
    t0 = sess.submit("m0", _feed(sess, "m0"))
    t1 = sess.submit("m1", _feed(sess, "m1"))
    assert sess.flush("m0") == 1
    assert t0.done and not t1.done
    t1.result()                              # resolves via its own model
    assert sess.queue_depth == 0


@pytest.mark.fast
def test_flush_aggregates_errors_and_drains_every_model():
    sess = _session()
    sess.add(random_graph(1), name="m1")
    sess.add(random_graph(2), name="m2")
    bad = np.zeros((3, 3, 1), dtype=np.float32)       # wrong shape
    t0 = sess.submit("m0", bad)
    t1 = sess.submit("m1", _feed(sess, "m1"))
    t2 = sess.submit("m2", bad)
    with pytest.raises(FlushError) as ei:
        sess.flush()
    assert set(ei.value.errors) == {"m0", "m2"}       # both recorded
    assert t1.done and t1.error is None               # m1 still executed
    assert isinstance(t0.error, ValueError)
    assert isinstance(t2.error, ValueError)
    assert sess.queue_depth == 0
    # client errors never count against the breaker
    assert sess.stats()["models"]["m0"]["breaker"]["state"] == "closed"
    assert sess.stats()["models"]["m0"]["plan_failures"] == 0


# --------------------------------------------------------------------------
# circuit breaker: trip -> degraded oracle serving -> recovery
# --------------------------------------------------------------------------


@pytest.mark.fast
@pytest.mark.chaos
def test_transient_fault_retried_once():
    sess = _session(retry_backoff_ms=1.0)
    x = _feed(sess)
    with chaos.inject() as c:
        c.poison_plan("m0", times=1)         # first attempt only
        t = sess.submit("m0", x)
        _check_output(sess, "m0", t.result(), x)
    st = sess.stats()["models"]["m0"]
    assert st["retries"] == 1 and st["plan_failures"] == 0
    assert st["breaker"]["state"] == "closed"


@pytest.mark.fast
@pytest.mark.chaos
def test_breaker_trips_then_serves_oracle_then_recovers():
    sess = _session(breaker_threshold=2, breaker_cooldown_s=0.1,
                    retry_backoff_ms=1.0)
    x = _feed(sess)
    with chaos.inject() as c:
        for _ in range(2):                   # 2 batches, both retries fail
            c.poison_plan("m0", times=2)
            t = sess.submit("m0", x)
            with pytest.raises(chaos.ChaosError):
                t.result()
        st = sess.stats()["models"]["m0"]
        assert st["breaker"]["state"] == "open"
        assert st["breaker_trips"] == 1 and st["plan_failures"] == 2

        # keep the plan poisoned through the first *background* probe:
        # it must fail, stay open and re-arm itself (recovery no longer
        # piggybacks on request batches)
        c.poison_plan("m0", times=1)

        # open: requests degrade to the interpretive oracle — correct
        t = sess.submit("m0", x)
        _check_output(sess, "m0", t.result(), x)
        assert sess.stats()["models"]["m0"]["degraded_requests"] >= 1

        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            st = sess.stats()["models"]["m0"]
            if st["failed_recoveries"] >= 1:
                break
            time.sleep(0.02)
        assert st["failed_recoveries"] == 1
        assert st["breaker"]["state"] == "open"

    # chaos gone: the re-armed probe heals the breaker with no request
    # traffic at all (an idle model recovers too)
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        st = sess.stats()["models"]["m0"]
        if st["breaker"]["state"] == "closed":
            break
        time.sleep(0.02)
    assert st["breaker"]["state"] == "closed" and st["recoveries"] == 1
    t = sess.submit("m0", x)
    _check_output(sess, "m0", t.result(), x)
    st = sess.stats()["models"]["m0"]
    assert st["latency"]["count"] > 0 and st["latency"]["p99_ms"] > 0


@pytest.mark.fast
@pytest.mark.chaos
def test_corrupt_artifact_takes_recompile_path():
    """A corrupted disk-tier artifact is rejected and recompiled, not
    served."""
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        program_cache_configure(disk_dir=d)
        m = api.compile(random_graph(5), precision="int8")
        program_cache_clear()                # memory tier gone; disk stays
        with chaos.inject() as c:
            c.corrupt_artifacts(times=1)
            m2 = api.compile(random_graph(5), precision="int8")
        assert c.injected["artifact_faults"] == 1
        info = program_cache_info()
        assert info["disk_rejects"] >= 1
        x = _inputs(m.graph, 1, 0)[0]
        got, want = m2(x), m(x, engine="interp")
        for k in want:
            err = float(np.max(np.abs(got[k] - want[k])))
            assert err <= m.semantics.plan_parity_tol(k)


# --------------------------------------------------------------------------
# worker pool
# --------------------------------------------------------------------------


@pytest.mark.chaos
def test_pool_serves_and_close_fails_leftovers():
    sess = _session(workers=2, linger_ms=1.0)
    x = _feed(sess)
    ts = [sess.submit("m0", x) for _ in range(8)]
    for t in ts:
        _check_output(sess, "m0", t.result(timeout=30), x)
    st = sess.stats()
    assert st["pool"]["dispatched_requests"] >= 8
    assert all(h["alive"] for h in st["workers"].values())
    sess.close()
    with pytest.raises(Exception):
        sess.submit("m0", x)


@pytest.mark.chaos
def test_pool_recycles_stalled_worker_zero_ticket_loss():
    """A worker that stops heartbeating mid-batch is detected, its
    in-flight batch re-dispatched, the worker recycled — and every
    ticket still terminates with a correct result."""
    sess = _session(workers=2, heartbeat_timeout_s=0.15, linger_ms=1.0)
    x = _feed(sess)
    with chaos.inject() as c:
        c.stall_worker(0, seconds=1.2)
        c.stall_worker(1, seconds=1.2)
        ts = [sess.submit("m0", _feed(sess, seed=i)) for i in range(10)]
        outs = [t.result(timeout=30) for t in ts]
    assert all(o is not None for o in outs)
    st = sess.stats()["pool"]
    assert st["recycled_workers"] >= 1
    assert st["redispatched_batches"] >= 1
    assert len(sess.stats()["workers"]) > 2  # replacements spawned
    sess.close()


@pytest.mark.chaos
def test_pool_deadline_auto_flush_is_latency_bounded():
    """With no other traffic, a deadline submission dispatches on its
    own — well before the deadline — rather than waiting for a full
    batch or a cooperative flush."""
    sess = _session(workers=1, linger_ms=500.0)   # linger alone too slow
    x = _feed(sess)
    t0 = time.monotonic()
    t = sess.submit("m0", x, deadline_ms=100.0)
    _check_output(sess, "m0", t.result(timeout=10), x)
    assert (time.monotonic() - t0) < 0.4          # NOT the 500 ms linger
    sess.close()


# --------------------------------------------------------------------------
# property: every ticket terminates under randomized fault schedules
# --------------------------------------------------------------------------


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_every_ticket_terminates_under_random_faults(seed):
    rng = np.random.default_rng(seed)
    sess = _session(workers=2, max_queue=32, heartbeat_timeout_s=0.15,
                    linger_ms=1.0, breaker_threshold=2,
                    breaker_cooldown_s=0.1, retry_backoff_ms=1.0)
    sess.add(random_graph(1), name="m1")
    names = ["m0", "m1"]
    tickets, shed = [], 0
    with chaos.inject() as c:
        for step in range(60):
            r = rng.random()
            if r < 0.08:
                c.poison_plan(str(rng.choice(names)),
                              times=int(rng.integers(1, 3)))
            elif r < 0.12:
                c.stall_worker(int(rng.integers(0, 6)),
                               seconds=float(rng.uniform(0.2, 0.6)))
            elif r < 0.15:
                c.skew_clock(float(rng.uniform(0.0, 0.05)))
            name = str(rng.choice(names))
            deadline = float(rng.uniform(5, 500)) \
                if rng.random() < 0.4 else None
            try:
                tickets.append(sess.submit(
                    name, _feed(sess, name, seed=step),
                    deadline_ms=deadline))
            except Overloaded:
                shed += 1
            if rng.random() < 0.2:
                time.sleep(0.01)
        # ZERO ticket loss: every accepted ticket terminates, each with
        # a value or a *typed* serving error
        for t in tickets:
            try:
                t.result(timeout=30)
            except (DeadlineExceeded, WorkerLost, chaos.ChaosError):
                pass
        assert all(t.done for t in tickets)
    assert len(tickets) + shed == 60
    sess.close()
    # post-mortem: the accounting adds up
    st = sess.stats()
    served = sum(m["latency"]["count"] for m in st["models"].values()
                 if "latency" in m)
    failed = sum(1 for t in tickets if t.error is not None)
    assert served + failed >= len(tickets)   # backups may double-serve


@pytest.mark.chaos
def test_sync_session_random_faults_single_thread():
    """The same termination property in synchronous (workers=0) mode."""
    rng = np.random.default_rng(7)
    sess = _session(max_queue=16, breaker_threshold=2,
                    breaker_cooldown_s=0.05, retry_backoff_ms=1.0)
    x = _feed(sess)
    tickets = []
    with chaos.inject() as c:
        for step in range(40):
            if rng.random() < 0.15:
                c.poison_plan("m0", times=int(rng.integers(1, 3)))
            if rng.random() < 0.1:
                c.skew_clock(float(rng.uniform(0, 0.02)))
            try:
                tickets.append(sess.submit(
                    "m0", x, deadline_ms=float(rng.uniform(5, 200))
                    if rng.random() < 0.5 else None))
            except Overloaded:
                pass
            if rng.random() < 0.3:
                try:
                    sess.flush("m0")
                except FlushError:
                    pass
        try:
            sess.flush()
        except FlushError:
            pass
    assert all(t.done for t in tickets)
    assert sess.queue_depth == 0


@pytest.mark.chaos
def test_concurrent_submitters_one_pool():
    """Many client threads hammering one pooled session: every ticket
    terminates, results are correct."""
    sess = _session(workers=2, max_queue=128, linger_ms=1.0)
    x = _feed(sess)
    want = sess["m0"](x, engine="interp")
    errs, done = [], []
    lock = threading.Lock()

    def client(n):
        for _ in range(n):
            try:
                t = sess.submit("m0", x)
                out = t.result(timeout=30)
                for k in want:
                    assert float(np.max(np.abs(out[k] - want[k]))) <= \
                        sess["m0"].semantics.plan_parity_tol(k)
                with lock:
                    done.append(1)
            except Overloaded:
                pass
            except Exception as e:       # pragma: no cover - diagnostics
                with lock:
                    errs.append(e)

    threads = [threading.Thread(target=client, args=(10,))
               for _ in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs, errs
    assert len(done) > 0
    sess.close()


# --------------------------------------------------------------------------
# fault monitor: retire tombstones
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_fault_monitor_retire_tombstone_drops_late_beats():
    """A recycled worker's id is tombstoned: its straggler beats are
    dropped (no zombie resurrection in the registry), and only an
    explicit register() — the replacement spawn — re-admits the id."""
    mon = FaultMonitor(n_hosts=0, timeout_s=1.0)
    mon.register(3)
    mon.beat(3, step=0, step_time_s=0.1)
    mon.retire(3)
    mon.beat(3, step=1, step_time_s=0.1)     # late beat from the corpse
    assert 3 not in mon.beats                # swallowed, not re-admitted
    assert mon.dead_hosts(now=99.0) == []    # and never reported dead
    mon.register(3)                          # replacement reuses the id
    mon.beat(3, step=2, step_time_s=0.1)
    assert 3 in mon.beats


# --------------------------------------------------------------------------
# EDF dispatch + priority classes (queue unit tests, workers=0)
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_edf_pop_order_within_model():
    """Within one model's queue, batches pop earliest-deadline-first;
    deadline-less work rides behind every dated entry."""
    pool = ServerPool(lambda name, entries: None, workers=0,
                      max_batch=4, linger_ms=0.0)
    try:
        now = chaos.now()
        for label, dl in (("A", now + 200.0), ("B", now + 50.0),
                          ("C", None), ("D", now + 100.0)):
            pool.submit("m0", label, Ticket(None, "m0", dl))
        with pool._cv:
            claim, _ = pool._claim_locked(chaos.now())
        assert claim is not None
        name, entries = claim
        assert name == "m0"
        assert [feed for feed, _ in entries] == ["B", "D", "A", "C"]
    finally:
        pool.close()


@pytest.mark.fast
def test_priority_class_dispatch_across_models():
    """Across models, the higher priority class dispatches first even
    when the lower-priority queue has waited longer."""
    pool = ServerPool(lambda name, entries: None, workers=0,
                      max_batch=4, linger_ms=0.0)
    try:
        pool.set_priority("hi", 1)
        for i in range(2):
            pool.submit("lo", f"lo{i}", Ticket(None, "lo"))
        for i in range(2):
            pool.submit("hi", f"hi{i}", Ticket(None, "hi"))
        time.sleep(0.002)                    # step past the zero linger
        with pool._cv:
            first, _ = pool._claim_locked(chaos.now())
            second, _ = pool._claim_locked(chaos.now())
        assert first is not None and first[0] == "hi"
        assert second is not None and second[0] == "lo"
    finally:
        pool.close()


@pytest.mark.fast
def test_pool_saturation_sheds_low_priority_first():
    """Pool-wide saturation evicts a lower-priority model's least
    urgent entry to admit high-priority work; a low-priority arrival
    with no victim below it is shed."""
    pool = ServerPool(lambda name, entries: None, workers=0,
                      max_batch=4, max_queue=8, max_queue_total=3,
                      linger_ms=1e6)
    try:
        pool.set_priority("hi", 1)
        lo = [Ticket(None, "lo") for _ in range(3)]
        for i, t in enumerate(lo):
            pool.submit("lo", f"lo{i}", t)
        t_hi = Ticket(None, "hi")
        pool.submit("hi", "hi0", t_hi)       # evicts one lo entry
        assert pool.counters["priority_evictions"] == 1
        assert sum(1 for t in lo
                   if isinstance(t.error, Overloaded)) == 1
        assert not t_hi.done                 # admitted, not shed
        with pytest.raises(Overloaded):      # no victim below priority 0
            pool.submit("lo", "lox", Ticket(None, "lo"))
        assert pool.queue_depth("hi") == 1
    finally:
        pool.close()


# --------------------------------------------------------------------------
# process pool: mmap'd worker processes, crash recovery
# --------------------------------------------------------------------------


def _proc_session(n=2):
    sess = api.Session(workers=("process", n), max_batch=4,
                       heartbeat_timeout_s=2.0)
    sess.add(random_graph(0), name="m0", precision="int8")
    return sess


@pytest.mark.chaos
def test_process_pool_parity():
    """workers=("process", n) serves through real child processes (own
    pids, mmap'd artifacts) with the same outputs as the in-process
    interpretive oracle."""
    import os
    sess = _proc_session()
    try:
        feeds = [_feed(sess, seed=i) for i in range(8)]
        ts = [sess.submit("m0", f) for f in feeds]
        for t, f in zip(ts, feeds):
            _check_output(sess, "m0", t.result(timeout=30), f)
        health = sess._pool.worker_health()
        pids = {h["pid"] for h in health.values() if h.get("pid")}
        assert pids and os.getpid() not in pids
        assert sess.stats()["pool"]["dispatched_requests"] >= 8
    finally:
        sess.close()


@pytest.mark.chaos
@pytest.mark.parametrize("mode", ["kill", "segv", "oom"])
def test_process_pool_crash_zero_ticket_loss(mode):
    """SIGKILL / SIGSEGV / simulated-OOM abort of a worker process with
    its batch in flight: the batch re-dispatches to survivors, every
    ticket resolves correctly, and the replacement worker spawns off
    the request path."""
    sess = _proc_session()
    try:
        feeds = [_feed(sess, seed=i) for i in range(10)]
        with chaos.inject() as c:
            c.kill_worker(-1, mode=mode)
            ts = [sess.submit("m0", f) for f in feeds]
            # zero ticket loss: every ticket resolves with parity,
            # served by the surviving worker — no respawn on this path
            for t, f in zip(ts, feeds):
                _check_output(sess, "m0", t.result(timeout=30), f)
            assert c.stats()["kills"] == 1
        assert sess.stats()["models"]["m0"]["crash_redispatches"] >= 1
        # ... and the supervisor respawns the replacement afterwards
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline:
            st = sess.stats()["pool"]
            ready = [h for h in sess._pool.worker_health().values()
                     if h.get("ready")]
            if st.get("recycled_workers", 0) >= 1 and len(ready) >= 2:
                break
            time.sleep(0.1)
        assert sess.stats()["pool"]["recycled_workers"] >= 1
        assert len([h for h in sess._pool.worker_health().values()
                    if h.get("ready")]) >= 2
    finally:
        sess.close()


# --------------------------------------------------------------------------
# artifact v3: persisted lowered-plan constants
# --------------------------------------------------------------------------


def _tamper_zip(src, dst, member, fn):
    """Rewrite a zip, transforming one member's bytes with fn (return
    None to drop the member)."""
    import zipfile
    with zipfile.ZipFile(src) as zin, \
            zipfile.ZipFile(dst, "w", zipfile.ZIP_STORED) as zout:
        for item in zin.infolist():
            blob = zin.read(item.filename)
            if item.filename == member:
                blob = fn(blob)
                if blob is None:
                    continue
            zout.writestr(item.filename, blob)


def test_v3_artifact_serves_plan_consts(tmp_path):
    """save() persists the lowered-plan kernel constants; a loading
    worker's first plan serves them (computed == 0) with exact parity."""
    m = api.compile(random_graph(3), precision="int8")
    x = _inputs(m.graph, 1, 0)[0]
    want = m(x, engine="plan")
    p = str(tmp_path / "m.rpa")
    m.save(p)
    assert m.plan_cache_info()["consts"] > 0
    m2 = CompiledModel.load(p, mmap=True)
    got = m2(x, engine="plan")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    info = m2.plan_cache_info()
    assert info["consts_computed"] == 0 and info["consts_served"] > 0
    # invalidation never trusts persisted consts again: fresh recompute
    m2.invalidate_plans()
    got = m2(x, engine="plan")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    assert m2.plan_cache_info()["consts_computed"] > 0


def test_consts_free_artifact_recomputes(tmp_path):
    """Back-compat: an artifact written without plan constants (the
    pre-v3 layout) loads fine and re-derives them on first plan."""
    from repro.api import artifact as artifact_mod
    m = api.compile(random_graph(4), precision="int8")
    x = _inputs(m.graph, 1, 0)[0]
    want = m(x, engine="plan")
    p = str(tmp_path / "old.rpa")
    artifact_mod.save_model(
        p, name=m.name, graph=m.graph, cfg=m.cfg, options=m.options,
        result=m.result, weights=m.weights, precision=m.precision,
        quant_meta=m.semantics.meta()
        if hasattr(m.semantics, "meta") else None,
        qweights=m.qm.qweights, packed=m.qm.packed,
        calib_error=m.qm.calib_error)        # no plan_consts=
    m2 = CompiledModel.load(p)
    got = m2(x, engine="plan")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    info = m2.plan_cache_info()
    assert info["consts_computed"] > 0 and info["consts_served"] == 0


def test_corrupt_plan_const_member_rejected(tmp_path):
    """A flipped byte inside a persisted constant fails the sha256
    manifest — the artifact is rejected, never served."""
    from repro.core.serialize import ArtifactError
    m = api.compile(random_graph(3), precision="int8")
    p = str(tmp_path / "m.rpa")
    m.save(p)
    bad = str(tmp_path / "bad.rpa")
    _tamper_zip(p, bad, "arrays/pl/0000.npy",
                lambda b: b[:-1] + bytes([b[-1] ^ 0xFF]))
    with pytest.raises(ArtifactError):
        CompiledModel.load(bad)


def test_missing_plan_const_member_rejected(tmp_path):
    """A planconsts index that references a missing array member is a
    typed ArtifactError, not a KeyError deep in lowering."""
    import json
    from repro.core.serialize import ArtifactError
    m = api.compile(random_graph(3), precision="int8")
    p = str(tmp_path / "m.rpa")
    m.save(p)

    def drop_from_manifest(blob):
        meta = json.loads(blob.decode("utf-8"))
        del meta["manifest"]["arrays/pl/0000.npy"]
        return json.dumps(meta).encode("utf-8")

    bad = str(tmp_path / "bad.rpa")
    _tamper_zip(p, bad, "arrays/pl/0000.npy", lambda b: None)
    _tamper_zip(bad, str(tmp_path / "bad2.rpa"), "meta.json",
                drop_from_manifest)
    with pytest.raises(ArtifactError, match="missing"):
        CompiledModel.load(str(tmp_path / "bad2.rpa"))


# --------------------------------------------------------------------------
# frame integrity: CRC32 on the pipe protocol
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_frame_crc_roundtrip_and_blob_flip():
    """Every frame carries a CRC32; a flipped payload byte surfaces as
    a typed FrameCorrupt that still carries the parsed header (so the
    fault is attributable to one request), while a header flip — the
    framing itself untrustworthy — stays a ProtocolError."""
    from repro.runtime import procpool
    from repro.runtime.procpool import ProtocolError, unpack_frame
    from repro.runtime.serving import FrameCorrupt

    arrs = {"y": np.arange(12, dtype=np.float32).reshape(3, 4)}
    buf = bytes(procpool.pack_frame({"type": "res", "req": 7}, arrs))
    header, out = unpack_frame(buf)
    assert header["req"] == 7
    np.testing.assert_array_equal(out["y"], arrs["y"])

    flipped = bytearray(buf)
    flipped[-3] ^= 0x40                    # inside the blob region
    with pytest.raises(FrameCorrupt) as ei:
        unpack_frame(bytes(flipped))
    assert ei.value.header["req"] == 7     # fault is attributable

    hdr_flip = bytearray(buf)
    hdr_flip[procpool._HDR_OFF] ^= 0x40    # breaks the JSON open-brace
    with pytest.raises(ProtocolError, match="unreadable header"):
        unpack_frame(bytes(hdr_flip))


@pytest.mark.fast
def test_chaos_frame_flip_targets_payload_frames():
    """The chaos bit-flip injector corrupts exactly one payload-bearing
    frame; headers-only frames (heartbeats) pass through with the arm
    unconsumed, so the fault always lands where a batch can feel it."""
    from repro.runtime import procpool
    from repro.runtime.serving import FrameCorrupt

    hb = bytes(procpool.pack_frame({"type": "hb", "w": 0, "seq": 1}))
    res = bytes(procpool.pack_frame({"type": "res", "req": 3},
                                    {"y": np.ones(4, np.float32)}))
    with chaos.inject() as c:
        c.corrupt_frames(1)
        assert c.maybe_flip_frame(hb) == hb          # passthrough
        assert c.stats()["frame_flips"] == 0         # arm unconsumed
        bad = c.maybe_flip_frame(res)
        assert bad != res and c.stats()["frame_flips"] == 1
        assert c.maybe_flip_frame(res) == res        # one-shot
    with pytest.raises(FrameCorrupt):
        procpool.unpack_frame(bad)
    procpool.unpack_frame(res)                       # original intact


@pytest.mark.chaos
def test_process_pool_frame_corruption_zero_ticket_loss():
    """A bit-flipped reply frame fails only its own batch — the batch
    re-dispatches and every ticket still resolves with parity, with no
    worker recycled (the stream is not poisoned: length-prefixed
    framing survives payload corruption)."""
    sess = _proc_session()
    try:
        feeds = [_feed(sess, seed=i) for i in range(8)]
        with chaos.inject() as c:
            c.corrupt_frames(1)
            ts = [sess.submit("m0", f) for f in feeds]
            for t, f in zip(ts, feeds):
                _check_output(sess, "m0", t.result(timeout=30), f)
            assert c.stats()["frame_flips"] == 1
        assert sess.stats()["models"]["m0"]["frame_corrupt"] >= 1
        assert sess.stats()["pool"]["recycled_workers"] == 0
    finally:
        sess.close()


# --------------------------------------------------------------------------
# client-side retry budgets and request cancellation
# --------------------------------------------------------------------------


@pytest.mark.fast
def test_submit_retries_absorb_shed_until_queue_drains():
    """submit(retries=N) retries an Overloaded shed with jittered
    exponential backoff seeded from the shed hint, succeeding once a
    drain frees the bounded queue."""
    sess = api.Session(max_queue=2)
    sess.add(random_graph(0), name="m0", precision="int8")
    try:
        x = _feed(sess)
        for _ in range(2):
            sess.submit("m0", x)                   # fill the queue
        with pytest.raises(Overloaded):
            sess.submit("m0", x)                   # retries=0: shed

        th = threading.Thread(
            target=lambda: (time.sleep(0.01), sess.flush("m0")))
        th.start()
        t = sess.submit("m0", x, retries=12, retry_cap_ms=100.0)
        th.join()
        _check_output(sess, "m0", t.result(timeout=30), x)
        assert sess.stats()["models"]["m0"]["submit_retries"] >= 1
    finally:
        sess.close()


@pytest.mark.fast
def test_submit_retries_respect_deadline():
    """The retry loop never sleeps past the request deadline: a queue
    that stays full sheds with Overloaded before the deadline burns."""
    sess = api.Session(max_queue=1)
    sess.add(random_graph(0), name="m0", precision="int8")
    try:
        x = _feed(sess)
        sess.submit("m0", x)
        t0 = time.monotonic()
        with pytest.raises(Overloaded):
            sess.submit("m0", x, deadline_ms=80.0, retries=50,
                        retry_cap_ms=1000.0)
        assert (time.monotonic() - t0) < 1.0
    finally:
        sess.close()


@pytest.mark.fast
def test_cancel_queued_drops_from_edf_queue():
    """Cancelling a ticket still queued settles it Cancelled and frees
    its EDF heap slot immediately; the pool keeps serving."""
    sess = _session(workers=1, linger_ms=500.0)   # linger: stays queued
    try:
        x = _feed(sess)
        t = sess.submit("m0", x)
        assert sess._pool.queue_depth("m0") == 1
        assert t.cancel() is True
        assert sess._pool.queue_depth("m0") == 0  # heap slot freed
        with pytest.raises(api.Cancelled):
            t.result(timeout=5)
        assert t.cancel() is False                # already settled
        t2 = sess.submit("m0", x)
        _check_output(sess, "m0", t2.result(timeout=30), x)
        assert sess.stats()["models"]["m0"]["cancelled"] == 1
    finally:
        sess.close()


@pytest.mark.chaos
def test_cancel_in_flight_first_settlement_wins():
    """Cancelling a ticket already executing races the real result:
    exactly one settlement wins (Cancelled or the value, never both,
    never neither) and the pool is undisturbed either way."""
    sess = _session(workers=1, linger_ms=1.0, heartbeat_timeout_s=30.0)
    try:
        x = _feed(sess)
        with chaos.inject() as c:
            c.stall_worker(0, seconds=0.4)
            t = sess.submit("m0", x)
            time.sleep(0.1)                       # claimed, stalled
            won = t.cancel()
        if won:
            with pytest.raises(api.Cancelled):
                t.result(timeout=30)
            assert sess.stats()["models"]["m0"]["cancelled"] == 1
        else:
            _check_output(sess, "m0", t.result(timeout=30), x)
        t2 = sess.submit("m0", x)
        _check_output(sess, "m0", t2.result(timeout=30), x)
    finally:
        sess.close()
