"""Substrate subsystems: data determinism, checkpoint atomicity/restore,
fault monitor + elastic re-mesh, gradient compression, microbatching."""
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, batch_for_step
from repro.runtime.fault import (BackupDispatcher, FaultMonitor,
                                 elastic_data_axis, plan_remesh)
from repro.runtime.overlap import (accumulate_grads, bucket_tree,
                                   split_microbatches)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------


def test_data_deterministic_per_step():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4)
    a = batch_for_step(cfg, 7)
    b = batch_for_step(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_shards_disjoint_and_deterministic():
    g = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                   host_id=0)
    h1 = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=1)
    a0 = batch_for_step(g, 3)
    a1 = batch_for_step(h1, 3)
    assert a0["tokens"].shape == (4, 16)
    assert not np.array_equal(a0["tokens"], a1["tokens"])


def test_pipeline_prefetch_resume():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    p = Pipeline(cfg, start_step=5)
    b5 = next(p)
    p.close()
    np.testing.assert_array_equal(b5["tokens"],
                                  batch_for_step(cfg, 5)["tokens"])


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": rng.normal(size=(4, 8)).astype(np.float32),
            "b": {"c": rng.normal(size=(3,)).astype(np.float32)}}


def test_checkpoint_roundtrip():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d)
        t = _tree(1)
        mgr.save(10, t, meta={"loss": 1.5})
        got, step, meta = mgr.restore(_tree(0))
        assert step == 10 and meta["loss"] == 1.5
        np.testing.assert_array_equal(got["a"], t["a"])
        np.testing.assert_array_equal(got["b"]["c"], t["b"]["c"])
    finally:
        shutil.rmtree(d)


def test_checkpoint_corruption_falls_back():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2))
        # corrupt the latest shard
        shard = os.path.join(d, "step_00000002", "shard_0.npz")
        with open(shard, "wb") as f:
            f.write(b"garbage")
        got, step, _ = mgr.restore(_tree(0))
        assert step == 1
        np.testing.assert_array_equal(got["a"], _tree(1)["a"])
    finally:
        shutil.rmtree(d)


def test_checkpoint_partial_write_invisible():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d)
        mgr.save(5, _tree(5))
        # a tmp dir without manifest must be ignored
        os.makedirs(os.path.join(d, "step_00000009.tmp0"), exist_ok=True)
        assert mgr.latest_step() == 5
    finally:
        shutil.rmtree(d)


def test_checkpoint_async_and_gc():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, _tree(s))
            mgr.wait()
        steps = mgr._valid_steps()
        assert steps == [3, 4]
    finally:
        shutil.rmtree(d)


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------


def test_fault_monitor_detects_dead_and_stragglers():
    mon = FaultMonitor(n_hosts=4, timeout_s=0.05, straggler_ratio=2.0)
    now = time.monotonic()
    for h in range(4):
        mon.beat(h, 0, 0.1 if h != 3 else 1.0)
    for _ in range(8):
        for h in range(3):
            mon.beat(h, 1, 0.1)
        mon.beat(3, 1, 1.0)
    assert mon.stragglers() == [3]
    # host 2 stops beating
    time.sleep(0.06)
    for h in (0, 1, 3):
        mon.beat(h, 2, 0.1)
    assert mon.dead_hosts() == [2]
    assert 2 not in mon.healthy_hosts()


def test_elastic_remesh_plan():
    n_data, dropped = elastic_data_axis(n_healthy_chips=208,
                                        model_axis=16)
    assert n_data == 8 and dropped == 208 - 8 * 16
    plan = plan_remesh(global_batch=256, old_data=16, model_axis=16,
                       n_healthy_chips=208)
    assert plan.new_shape == (8, 16)
    assert plan.batch_per_shard_new == 32
    assert plan.changed


def test_backup_dispatch():
    mon = FaultMonitor(n_hosts=3, straggler_ratio=1.5)
    for _ in range(8):
        mon.beat(0, 0, 0.1)
        mon.beat(1, 0, 0.1)
        mon.beat(2, 0, 2.0)
    disp = BackupDispatcher(mon)
    times = disp.maybe_backup(
        1, run_shard=lambda h, s: 2.0 if h == 2 else 0.1)
    assert disp.backups_issued and disp.backups_issued[0][1] == 2
    assert times[2] == pytest.approx(0.1)   # backup won


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_compression_error_feedback_converges(seed):
    """sum of decompressed grads + final residual == sum of true grads
    (error feedback keeps long-run bias at zero)."""
    rng = np.random.default_rng(seed)
    g_true = [rng.normal(size=(8, 8)).astype(np.float32)
              for _ in range(5)]
    err = optim.init_error({"w": g_true[0]})
    total_sent = np.zeros((8, 8), np.float32)
    total_true = np.zeros((8, 8), np.float32)
    for g in g_true:
        sent, err = optim.compress_grads({"w": g}, err)
        total_sent += np.asarray(sent["w"])
        total_true += g
    resid = np.asarray(err["w"])
    np.testing.assert_allclose(total_sent + resid, total_true,
                               atol=1e-4, rtol=1e-4)


def test_compression_int8_range():
    g = {"w": np.array([[1000.0, -1000.0, 0.5]], np.float32)}
    err = optim.init_error(g)
    sent, err2 = optim.compress_grads(g, err)
    # reconstruction error bounded by one quant step
    step = 1000.0 / 127
    assert np.all(np.abs(np.asarray(sent["w"]) - g["w"]) <= step + 1e-5)


# --------------------------------------------------------------------------
# optimizer + microbatching
# --------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optim.init_state(cfg, params)

    def loss(p):
        return (p["w"] ** 2).sum()

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = optim.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 0.3


def test_microbatch_accumulation_matches_full_batch():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))

    def loss(params, batch):
        pred = batch["x"] @ params
        return ((pred - batch["y"]) ** 2).mean()

    l1, g1 = jax.value_and_grad(loss)(W, {"x": X, "y": Y})
    l2, g2 = accumulate_grads(loss, W, {"x": X, "y": Y}, n_micro=4)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-6)


def test_bucket_tree_covers_all_leaves():
    tree = {"a": np.zeros((1000,), np.float32),
            "b": np.zeros((300000,), np.float32),
            "c": np.zeros((10,), np.float32)}
    buckets = bucket_tree(tree, bucket_bytes=1 << 20)
    idx = sorted(i for b in buckets for i, _ in b)
    assert idx == [0, 1, 2]


def test_moment_dtype_bf16():
    cfg = optim.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st_ = optim.init_state(cfg, params)
    assert st_.m["w"].dtype == jnp.bfloat16
