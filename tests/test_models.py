"""Model-stack unit tests: layers, rope, MoE invariants, head padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.config import ArchConfig
from repro.models.layers import (apply_mrope, apply_rope, cross_entropy,
                                 rms_norm)
from repro.models.moe import init_moe, moe_dense

RNG = np.random.default_rng(0)


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on m - n."""
    D = 16
    q = RNG.normal(size=(1, 1, 1, D)).astype(np.float32)
    k = RNG.normal(size=(1, 1, 1, D)).astype(np.float32)

    def score(m, n):
        qm = apply_rope(jnp.asarray(q), jnp.array([[m]]))
        kn = apply_rope(jnp.asarray(k), jnp.array([[n]]))
        return float((qm * kn).sum())

    assert score(5, 3) == pytest.approx(score(12, 10), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_mrope_text_only_equals_rope():
    """With identical t/h/w position ids M-RoPE == plain RoPE."""
    B, S, H, D = 2, 8, 2, 16
    x = RNG.normal(size=(B, S, H, D)).astype(np.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(pos[None], (3, B, S))
    a = apply_rope(jnp.asarray(x), pos)
    b = apply_mrope(jnp.asarray(x), pos3, sections=(4, 2, 2))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_rms_norm_scale_invariant_direction():
    x = RNG.normal(size=(2, 3, 8)).astype(np.float32)
    w = jnp.zeros((8,))
    y1 = rms_norm(w, jnp.asarray(x))
    y2 = rms_norm(w, jnp.asarray(4.0 * x))
    np.testing.assert_allclose(y1, y2, atol=1e-5)


def test_cross_entropy_perfect_prediction():
    logits = jnp.full((2, 4, 10), -30.0)
    labels = jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]])
    logits = logits.at[
        jnp.arange(2)[:, None], jnp.arange(4)[None], labels].set(30.0)
    assert float(cross_entropy(logits, labels)) < 1e-3


def _moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, n_experts=4,
                top_k=2, moe_d_ff=16, dtype="float32", remat=False)
    base.update(kw)
    return ArchConfig(**base)


def test_moe_outputs_finite_and_shaped():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 6, 32)).astype(np.float32))
    y = moe_dense(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_moe_capacity_drops_tokens():
    """With capacity_factor ~0 every token is dropped -> shared-expert
    only (or zero without shared experts)."""
    cfg = _moe_cfg(capacity_factor=1e-9)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(1, 8, 32)).astype(np.float32))
    y = moe_dense(p, x, cfg)
    # cap=1 -> at most 1 token per expert survives; most output rows ~0
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(norms.min()) < float(norms.max())


def test_moe_dense_vs_a2a_single_device():
    """The shard_map all_to_all EP path must equal the dense-dispatch
    path on a 1-device mesh (n_model=1 -> a2a degenerates)."""
    from repro.models.moe import moe_a2a
    cfg = _moe_cfg(capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 4, 32)).astype(np.float32))
    want = moe_dense(p, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = moe_a2a(p, x, cfg, mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_head_padding_exact():
    """tp_pad > 1 must not change the math (zero-masked heads)."""
    base = dict(n_layers=2, d_model=64, n_heads=6, n_kv_heads=2,
                d_ff=128, vocab=128, dtype="float32", remat=False)
    cfg_p = ArchConfig("pad", "dense", tp_pad=16, **base)
    cfg_u = ArchConfig("nopad", "dense", tp_pad=1, **base)
    pp = lm.init_params(cfg_p, jax.random.PRNGKey(0))
    pu = lm.init_params(cfg_u, jax.random.PRNGKey(1))
    hd, H = cfg_p.head_dim, 6
    pu["embed"] = pp["embed"]
    pu["final_norm"] = pp["final_norm"]
    pu["lm_head"] = pp["lm_head"]
    for k in ("norm1", "norm2"):
        pu["layers"][k] = pp["layers"][k]
    pu["layers"]["mlp"] = pp["layers"]["mlp"]
    ap, au = pp["layers"]["attn"], pu["layers"]["attn"]
    au["wq"] = ap["wq"][:, :, :H * hd]
    au["wk"], au["wv"] = ap["wk"], ap["wv"]
    au["wo"] = ap["wo"][:, :H * hd, :]
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}
    lp = lm.forward(cfg_p, pp, batch)
    lu = lm.forward(cfg_u, pu, batch)
    assert float(jnp.abs(lp - lu).max()) < 1e-4


def test_padded_heads_get_zero_grads():
    base = dict(n_layers=1, d_model=32, n_heads=3, n_kv_heads=1,
                d_ff=64, vocab=64, dtype="float32", remat=False)
    cfg = ArchConfig("pad", "dense", tp_pad=4, **base)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    batch = {"tokens": tokens, "labels": tokens}
    g = jax.grad(lambda p: lm.loss_fn(cfg, p, batch))(params)
    hd, H, Hp = cfg.head_dim, 3, 4
    gwo = np.asarray(g["layers"]["attn"]["wo"])   # (1, Hp*hd, d)
    assert np.abs(gwo[:, H * hd:, :]).max() == 0.0
    gwq = np.asarray(g["layers"]["attn"]["wq"])   # (1, d, Hp*hd)
    assert np.abs(gwq[:, :, H * hd:]).max() == 0.0
