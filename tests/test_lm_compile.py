"""Causal-operator subsystem: the LM decoder's compile/serve path.

Parity chain: the IR-level attention reference is checked against the
seed Pallas kernels (``flash_attention`` for prefill, ``flash_decode``
for single-token decode at several sequence positions); the interpretive
executor and the compiled ExecPlan are then checked against each other
through ``CompiledModel.verify`` (bit-exact for float32, within one
output quantization step for int8).  Serving state: KV caches are
per-request (interleaved requests reproduce their solo runs), the
decode-step plan is built once and only hit afterwards, and weights are
shared across sequence/KV buckets by construction.
"""
import numpy as np
import pytest

from repro.api import DecodeSession
from repro.core.ir import _attention_ref, _kvappend_ref
from repro.frontends import lm

SPEC = lm.tiny_spec()


def _heads(x, heads, hd):
    """(S, 1, d) -> (1, heads, S, hd) kernel layout."""
    s = x.shape[0]
    return x.reshape(s, heads, hd).transpose(1, 0, 2)[None]


# --------------------------------------------------------------------------
# IR attention reference vs the seed Pallas kernels
# --------------------------------------------------------------------------


@pytest.mark.parametrize("S", [4, 8, 16])
def test_attention_ref_matches_flash_attention_prefill(S):
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention

    rng = np.random.default_rng(S)
    heads, hd = 4, 8
    d = heads * hd
    q = rng.normal(size=(S, 1, d)).astype(np.float32)
    k = rng.normal(size=(S, 1, d)).astype(np.float32)
    v = rng.normal(size=(S, 1, d)).astype(np.float32)
    scale = 1.0 / np.sqrt(hd)
    attrs = {"heads": heads, "head_dim": hd, "scale": float(scale),
             "causal": True, "kv_len": S}
    got = _attention_ref(q, k, v, np.zeros((1, 1, 1), np.float32), attrs)
    want = flash_attention(jnp.asarray(_heads(q, heads, hd)),
                           jnp.asarray(_heads(k, heads, hd)),
                           jnp.asarray(_heads(v, heads, hd)),
                           causal=True, sm_scale=float(scale),
                           interpret=True)
    want = np.asarray(want)[0].transpose(1, 0, 2).reshape(S, 1, d)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("pos", [0, 3, 7, 14])
def test_attention_ref_matches_flash_decode_positions(pos):
    import jax.numpy as jnp

    from repro.kernels.flash_decode import flash_decode

    rng = np.random.default_rng(100 + pos)
    heads, hd, kv = 4, 8, 16
    d = heads * hd
    q = rng.normal(size=(1, 1, d)).astype(np.float32)
    kc = np.zeros((kv, 1, d), np.float32)
    vc = np.zeros((kv, 1, d), np.float32)
    kc[:pos] = rng.normal(size=(pos, 1, d))
    vc[:pos] = rng.normal(size=(pos, 1, d))
    p = np.full((1, 1, 1), float(pos), np.float32)
    # decode step: append this token's K/V at row ``pos``, then attend
    kc = _kvappend_ref(kc, rng.normal(size=(1, 1, d)).astype(np.float32), p)
    vc = _kvappend_ref(vc, rng.normal(size=(1, 1, d)).astype(np.float32), p)
    scale = 1.0 / np.sqrt(hd)
    attrs = {"heads": heads, "head_dim": hd, "scale": float(scale),
             "causal": True, "kv_len": kv}
    got = _attention_ref(q, kc, vc, p, attrs)
    want = flash_decode(jnp.asarray(q.reshape(heads, hd)[None]),
                        jnp.asarray(_heads(kc, heads, hd)),
                        jnp.asarray(_heads(vc, heads, hd)),
                        kv_len=jnp.asarray([pos + 1], jnp.int32),
                        sm_scale=float(scale), interpret=True)
    want = np.asarray(want).reshape(1, 1, d)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# interpretive executor vs compiled ExecPlan on full decoder graphs
# --------------------------------------------------------------------------


def _feed(g, pos, seed=0):
    rng = np.random.default_rng(seed)
    feed = {}
    for t in g.inputs:
        if t.name == "pos":
            feed[t.name] = np.full((1, 1, 1), float(pos), np.float32)
        else:
            feed[t.name] = rng.normal(size=t.shape).astype(np.float32)
    return feed


@pytest.mark.parametrize("seq,kv,pos", [(8, 16, 0), (1, 8, 0),
                                        (1, 16, 5), (1, 16, 15)])
def test_float32_engines_bit_exact(seq, kv, pos):
    m = lm.compile_decoder(SPEC, seq, kv, cache=False)
    # verify() raises unless plan parity is bit-exact for float32
    rep = m.verify(_feed(m.graph, pos))
    assert rep.ok


def test_int8_decode_verifies_and_pos_stays_float():
    m = lm.compile_decoder(SPEC, 1, 16, precision="int8", cache=False)
    g = m.graph
    # the position input is exempt from quantization (its runtime range
    # is the whole bucket, not what calibration happened to see)
    assert g.tensors["pos"].dtype == "float32"
    assert g.tensors["pos"].qparams is None
    rep = m.verify(_feed(g, 7))
    assert rep.ok
    # tied cache qparams: every kvappend's cache input/output quantize
    # identically, so pass-through rows survive the decode loop exactly
    for op in g.ops:
        if op.kind == "kvappend":
            qi = g.tensors[op.inputs[0]].qparams
            qo = g.tensors[op.outputs[0]].qparams
            assert qi is not None and qi == qo


# --------------------------------------------------------------------------
# serving state: isolation, plan-cache reuse, bucket weight sharing
# --------------------------------------------------------------------------


def test_kv_cache_isolation_across_concurrent_requests():
    prompt_a, prompt_b = [3, 17, 42, 5], [9, 1, 88]
    solo = DecodeSession()
    a_solo = solo.generate(prompt_a, max_new_tokens=4)
    b_solo = solo.generate(prompt_b, max_new_tokens=4)

    sess = DecodeSession()
    ra, ta = sess.prefill(prompt_a)
    rb, tb = sess.prefill(prompt_b)
    a, b = [ta], [tb]
    for _ in range(3):          # interleave the two decode loops
        a.append(sess.step(ra))
        b.append(sess.step(rb))
    assert a == a_solo
    assert b == b_solo
    assert sorted(sess.active_requests()) == sorted([ra, rb])
    sess.finish(ra)
    sess.finish(rb)
    assert sess.active_requests() == []


def test_decode_plan_built_once_then_hit():
    sess = DecodeSession()
    sess.generate([2, 4, 6], max_new_tokens=4)   # prefill + 3 steps
    st = sess.stats()
    assert set(st) == {"s8/kv8", "s1/kv8"}
    for s in st.values():                        # zero re-lowering
        assert s["plan"]["builds"] == 1
    dec = st["s1/kv8"]["plan"]
    assert dec["hits"] == 2                      # steps after the first


def test_weights_shared_across_buckets():
    _, b1 = lm.build_decoder(SPEC, 1, 8)
    _, b2 = lm.build_decoder(SPEC, 8, 16)
    _, b3 = lm.build_decoder(SPEC, 1, 128)
    assert set(b1._weights) == set(b2._weights) == set(b3._weights)
    for name, w in b1._weights.items():
        np.testing.assert_array_equal(w, b2._weights[name])
        np.testing.assert_array_equal(w, b3._weights[name])


def test_bucket_growth_mid_generation():
    sess = DecodeSession(buckets=(8, 16))
    rid, _ = sess.prefill([1, 2, 3, 4, 5, 6])    # pos 6 in kv8
    toks = [sess.step(rid) for _ in range(4)]    # crosses 8 -> 16
    assert len(toks) == 4
    r = sess._requests[rid]
    assert r.bucket == 16 and r.pos == 10
    assert {"s8/kv8", "s1/kv8", "s1/kv16"} <= set(sess.stats())
