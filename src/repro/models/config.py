"""Architecture configuration — one frozen dataclass drives every family.

The ten assigned architectures (plus smoke-test reductions) are expressed
as instances of :class:`ArchConfig`; family-specific switches select the
attention variant (GQA / MQA / MLA / sliding-window mix), the FFN variant
(gated-SiLU / squared-ReLU / MoE) and the backbone (transformer / SSD /
hybrid / encoder-decoder).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int

    d_head: int = 0                   # 0 -> d_model // n_heads
    act: str = "silu"                 # mlp activation
    gated_mlp: bool = True            # SwiGLU-style vs plain 2-layer
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # attention pattern
    sliding_window: int = 0           # 0 = full attention
    local_global_ratio: int = 0       # gemma3: N local per 1 global
    mrope: bool = False               # qwen2-vl M-RoPE (3 sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    capacity_factor: float = 1.25
    moe_dispatch: str = "sort"        # "sort" (O(T·k·d) scatter/gather)
    #                                 # or "onehot" (Mesh-TF einsums,
    #                                 # O(T·E·cap·d) — the §Perf baseline)

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    d_nope: int = 0
    d_rope: int = 0
    d_v: int = 0
    mtp: bool = False                 # multi-token-prediction head
    moe_layer_start: int = 0          # dense layers before MoE begins

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (zamba2): shared attention block every k layers
    shared_attn_every: int = 0
    lora_rank: int = 0

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_audio_frames: int = 1500

    # vlm (qwen2-vl)
    n_vision_tokens: int = 0

    dtype: str = "bfloat16"
    remat: bool = True                # activation checkpoint per layer
    use_pallas: bool = False          # kernels impl ("auto" when True)
    fsdp: bool = False                # shard params over the data axis too
    fused_attn_vjp: bool = True       # FlashAttention-2 custom backward
    attn_block_k: int = 512           # KV streaming block size
    fused_ce_loss: bool = True        # chunked LM-head+CE custom VJP
    ce_chunk: int = 512               # sequence positions per CE chunk
    seq_parallel: bool = False        # sequence-shard the residual
    #                                 # stream over `model` (§Perf)
    tp_pad: int = 1                   # pad Q heads to a multiple of this
    #   (Megatron-style: 24 heads on a 16-way model axis -> 32 padded
    #   heads, zero-masked so the math is exactly the 24-head model;
    #   fractional-head GSPMD sharding otherwise costs per-block
    #   all-reduces or full attention replication — see DESIGN.md)

    # ---- derived ----
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_heads(self) -> int:
        """Q heads padded up to a tp_pad multiple (zero-masked)."""
        if not self.n_heads:
            return 0
        return -(-self.n_heads // self.tp_pad) * self.tp_pad

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode cell?  SSM/hybrid have
        O(1) state; gemma3's 5:1 local layers are windowed and its sparse
        global layers shard KV by sequence."""
        return self.family in ("ssm", "hybrid") or \
            self.local_global_ratio > 0

    @property
    def kernel_impl(self) -> str:
        return "auto" if self.use_pallas else "ref"

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        emb = V * d
        head = 0 if self.tie_embeddings else d * V
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            if self.mla:
                attn = (d * self.q_lora_rank
                        + self.q_lora_rank * self.n_heads
                        * (self.d_nope + self.d_rope)
                        + d * (self.kv_lora_rank + self.d_rope)
                        + self.kv_lora_rank * self.n_heads
                        * (self.d_nope + self.d_v)
                        + self.n_heads * self.d_v * d)
            else:
                hd = self.head_dim
                attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            if self.n_experts:
                fe = self.moe_d_ff or f
                mult = 3 if self.gated_mlp else 2
                ffn = self.n_experts * mult * d * fe \
                    + self.n_shared_experts * mult * d * fe + d * self.n_experts
            else:
                ffn = (3 if self.gated_mlp else 2) * d * f
            per_layer = attn + ffn + 2 * d
        elif self.family in ("ssm", "hybrid"):
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * N + H)
            per_layer = in_proj + di * d + self.ssm_conv * (di + 2 * N) \
                + 2 * d + 2 * H + di
            if self.family == "hybrid" and self.shared_attn_every:
                hd = self.head_dim
                shared = (d * self.n_heads * hd
                          + 2 * d * self.n_kv_heads * hd
                          + self.n_heads * hd * d
                          + 3 * d * self.d_ff + 2 * d)
                n_uses = self.n_layers // self.shared_attn_every
                lora = n_uses * self.lora_rank * 2 * d * 4
                return emb + head + per_layer * self.n_layers + shared + lora
        total = emb + head + per_layer * self.n_layers
        if self.enc_dec:
            hd = self.head_dim
            enc_layer = (2 * (d * self.n_heads * hd
                              + 2 * d * self.n_kv_heads * hd
                              + self.n_heads * hd * d) // 2
                         + 2 * d * f + 3 * d)
            total += self.n_enc_layers * enc_layer
        return total

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test reduction: same family/topology, tiny dims."""
        base = dict(
            n_layers=min(self.n_layers, 2 if not self.shared_attn_every
                         else 2 * self.shared_attn_every),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            name=self.name + "-smoke",
            remat=False,
            fsdp=False,
        )
        if self.mla:
            base.update(q_lora_rank=64, kv_lora_rank=32, d_nope=16,
                        d_rope=16, d_v=16, d_head=0)
        elif self.d_head:
            base.update(d_head=32)
        if self.n_experts:
            base.update(n_experts=min(self.n_experts, 8),
                        top_k=min(self.top_k, 2),
                        moe_d_ff=min(self.moe_d_ff or self.d_ff, 64),
                        moe_layer_start=min(self.moe_layer_start, 1))
        if self.ssm_state:
            base.update(ssm_state=min(self.ssm_state, 16),
                        ssm_head_dim=32, ssm_chunk=16)
        if self.shared_attn_every:
            base.update(shared_attn_every=2, lora_rank=4)
        if self.enc_dec:
            base.update(n_enc_layers=2, n_audio_frames=32)
        if self.n_vision_tokens:
            base.update(n_vision_tokens=8)
        if self.mrope:
            half = (overrides.get("d_head") or 32) // 2
            base.update(mrope_sections=(half // 2, half // 4, half // 4))
        if self.local_global_ratio:
            # one full (ratio+1)-layer group so the grouped scan is
            # non-empty
            base.update(sliding_window=16, local_global_ratio=2,
                        n_layers=3)
        base.setdefault("tp_pad", 1)      # no head padding in smoke tests
        base.update(overrides)
        return replace(self, **base)
