"""Shared neural-net layers: norms, rotary embeddings, MLPs, embeddings.

Functional style: parameters are plain dicts of jnp arrays; every layer is
``fn(params, x, ...) -> y``.  Initializers take an explicit PRNG key so
``jax.eval_shape`` can derive abstract parameter trees for the dry-run
without allocating a single byte.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rms_norm(w: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x (..., S, H, D) or (..., S, D); positions (..., S)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                              # head axis
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                sections: Sequence[int], theta: float = 1e4
                ) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  x (B, S, H, D); positions3 (3, B, S) —
    temporal/height/width position ids.  `sections` split D/2 into the
    three axes' frequency bands."""
    D = x.shape[-1]
    half = D // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(D, theta)                            # (half,)
    # per-frequency axis selector: which of t/h/w drives this band
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)           # (half,)
    pos = positions3.astype(jnp.float32)                    # (3, B, S)
    ang = jnp.einsum("abs,f->absf", pos, freqs)             # (3,B,S,half)
    onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)   # (half, 3)
    ang = jnp.einsum("absf,fa->bsf", ang, onehot)           # (B,S,half)
    ang = ang[..., None, :]                                 # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype, gated: bool = True) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d, f), dtype),
         "w_out": dense_init(ks[1], (f, d), dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, f), dtype)
    return p


def mlp(p: Dict, x: jnp.ndarray, act: str = "silu",
        gated: bool = True) -> jnp.ndarray:
    h = x @ p["w_in"]
    if gated:
        h = ops.apply_activation(x @ p["w_gate"], act) * h
    else:
        h = ops.apply_activation(h, act)
    return h @ p["w_out"]


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return embed_init(key, (vocab, d), dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def lm_logits(head: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """head (d, V) or the tied embedding table (V, d)."""
    if head.shape[0] < head.shape[1]:        # (d, V)
        return h @ head
    return h @ head.T


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Stable sharded-safe CE.  logits (..., V); labels (...,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


# --------------------------------------------------------------------------
# Fused LM-head + cross-entropy (chunked over tokens, custom VJP)
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_ce(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
             chunk_s: int = 512) -> jnp.ndarray:
    """mean softmax-CE of (h @ w) vs labels WITHOUT materializing the
    (B, S, V) float32 logits (+their cotangent): the scan walks the
    SEQUENCE axis in `chunk_s`-position blocks, keeping the batch axis
    intact so data-parallel sharding survives — per chip one
    (B_loc, chunk_s, V_loc) block of logits exists at a time, forward
    and backward (recomputation).  For 256k-vocab models this removes
    the dominant HBM-traffic term of the training step.
    h (B, S, d); w (d, V); labels (B, S) with -1 = ignore."""
    return _fused_ce_fwd(h, w, labels, chunk_s)[0]


def _ce_chunks(h, labels, chunk_s):
    B, S, d = h.shape
    cs = min(chunk_s, S)
    nc = max(1, math.ceil(S / cs))
    pad = nc * cs - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    # (nc, B, cs, ...) so scan slices along the sequence axis only
    hc = hp.reshape(B, nc, cs, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(B, nc, cs).transpose(1, 0, 2)
    return hc, lc, pad


def _fused_ce_fwd(h, w, labels, chunk_s):
    hc, lc, pad = _ce_chunks(h, labels, chunk_s)
    n_valid = jnp.maximum((labels >= 0).sum(), 1).astype(jnp.float32)

    def body(acc, xs):
        hb, lb = xs                       # (B, cs, d), (B, cs)
        logits = hb.astype(jnp.float32) @ w.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        nll = jnp.where(lb >= 0, lse - gold, 0.0)
        return acc + nll.sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / n_valid, (h, w, labels)


def _fused_ce_bwd(chunk_s, res, g):
    h, w, labels = res
    hc, lc, pad = _ce_chunks(h, labels, chunk_s)
    wf = w.astype(jnp.float32)
    n_valid = jnp.maximum((labels >= 0).sum(), 1).astype(jnp.float32)
    scale = g / n_valid

    def body(dw, xs):
        hb, lb = xs
        B, cs, d = hb.shape
        logits = hb.astype(jnp.float32) @ wf
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(lb, 0), p.shape[-1],
                                dtype=jnp.float32)
        dl = (p - onehot) * (lb >= 0)[..., None] * scale
        dh = dl @ wf.T
        dw = dw + jnp.einsum("bcd,bcv->dv", hb.astype(jnp.float32), dl)
        return dw, dh

    dw0 = jnp.zeros(w.shape, jnp.float32)
    dw, dhc = jax.lax.scan(body, dw0, (hc, lc))
    dh = dhc.transpose(1, 0, 2, 3).reshape(
        h.shape[0], -1, h.shape[2])
    if pad:
        dh = dh[:, :-pad]
    return (dh.astype(h.dtype), dw.astype(w.dtype), None)


fused_ce.defvjp(lambda h, w, l, c: _fused_ce_fwd(h, w, l, c),
                _fused_ce_bwd)
