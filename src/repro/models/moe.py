"""Mixture-of-Experts with expert parallelism.

Two functionally-identical dispatch implementations:

  * ``dense`` (default) — Switch/Mesh-TF style one-hot dispatch einsums
    with capacity bounding.  Under pjit + the expert-parallel parameter
    specs (experts sharded over ``model``), GSPMD slices the expert
    einsums per shard; tokens stay replicated across the model axis and
    the combine is a single cross-shard reduction.  Robust everywhere
    (CPU single-device tests included).
  * ``a2a`` — shard_map all_to_all dispatch (tokens re-shuffled to the
    devices owning their experts and back) — the production EP schedule;
    selected by the perf pass where it wins on collective bytes.

Router: softmax top-k with normalized gates (DeepSeek-V3 style sigmoid
gating optional), plus optional shared experts always active.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ArchConfig
from .layers import dense_init
from .sharding import maybe_shard


def init_moe(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    fe = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "experts": {
            "w_in": dense_init(ks[1], (E, d, fe), dtype),
            "w_gate": dense_init(ks[2], (E, d, fe), dtype),
            "w_out": dense_init(ks[3], (E, fe, d), dtype),
        },
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_in": dense_init(ks2[0], (d, fs), dtype),
            "w_gate": dense_init(ks2[1], (d, fs), dtype),
            "w_out": dense_init(ks2[2], (fs, d), dtype),
        }
    return p


def _router_probs(p: Dict, x2d: jnp.ndarray, cfg: ArchConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k gates (T, k) and expert ids (T, k)."""
    logits = x2d.astype(jnp.float32) @ p["router"]          # (T, E)
    gates, idx = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx


def _dispatch_onehot(x2d, gates, idx, E: int, cap: int, dtype):
    """Mesh-TF one-hot dispatch/combine einsums.  O(T·E·cap·d) FLOPs —
    quadratic in tokens; kept as the recorded §Perf baseline."""
    T, k = idx.shape
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = (pos.reshape(T, k, E) * onehot).sum(-1)           # (T, k)
    keep = pos < cap
    gates = gates * keep
    disp = (jax.nn.one_hot(idx, E, dtype=dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=dtype)[..., None, :])[..., :cap] \
        .sum(axis=1)                                        # (T, E, cap)
    comb = (jax.nn.one_hot(idx, E, dtype=jnp.float32)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=jnp.float32)[..., None, :]
            * gates[..., None, None])[..., :cap].sum(axis=1)
    xe = jnp.einsum("td,tec->ecd", x2d, disp)

    def combine(ye):
        return jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), comb)

    return xe, combine


def _dispatch_sort(x2d, gates, idx, E: int, cap: int, dtype):
    """Sort-based dispatch: stable-sort assignments by expert, derive the
    within-expert slot from segment offsets, scatter tokens into the
    (E, cap, d) buffers and gather back — O(T·k·d) data movement instead
    of O(T·E·cap·d) FLOPs.  Token-drop semantics identical to the
    one-hot path (token-major order within each expert)."""
    T, k = idx.shape
    Tk = T * k
    flat_e = idx.reshape(Tk)
    order = jnp.argsort(flat_e, stable=True)                # (Tk,)
    sorted_e = flat_e[order]
    seg_first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    slot = jnp.arange(Tk) - seg_first                       # pos in expert
    keep = slot < cap
    token = order // k
    addr = jnp.where(keep, sorted_e * cap + slot, E * cap)  # OOB drops
    xe = jnp.zeros((E * cap, x2d.shape[1]), x2d.dtype)
    xe = xe.at[addr].set(x2d[token], mode="drop",
                         unique_indices=True)
    xe = xe.reshape(E, cap, x2d.shape[1])
    gate_sorted = gates.reshape(Tk)[order]

    def combine(ye):
        ye_flat = ye.reshape(E * cap, -1).astype(jnp.float32)
        picked = ye_flat[jnp.minimum(addr, E * cap - 1)]
        picked = picked * (keep * gate_sorted)[:, None]
        y = jnp.zeros((T, ye_flat.shape[1]), jnp.float32)
        return y.at[token].add(picked)

    return xe, combine


def _dispatch(x2d, gates, idx, E, cap, dtype, method: str):
    if method == "sort":
        return _dispatch_sort(x2d, gates, idx, E, cap, dtype)
    return _dispatch_onehot(x2d, gates, idx, E, cap, dtype)


def moe_dense(p: Dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Capacity-bounded dispatch (method per cfg.moe_dispatch).
    x (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    x2d = x.reshape(T, d)
    gates, idx = _router_probs(p, x2d, cfg)
    cap = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    xe, combine = _dispatch(x2d, gates, idx, E, cap, x.dtype,
                            cfg.moe_dispatch)
    xe = maybe_shard(xe, "model", None, None)
    we = p["experts"]
    h = jnp.einsum("ecd,edf->ecf", xe, we["w_in"])
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", xe, we["w_gate"])
        h = ops.apply_activation(g, cfg.act) * h
    else:
        h = ops.apply_activation(h, cfg.act)
    ye = jnp.einsum("ecf,efd->ecd", h, we["w_out"])
    ye = maybe_shard(ye, "model", None, None)
    y = combine(ye).astype(x.dtype)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = x2d @ sh["w_in"]
        hs = ops.apply_activation(x2d @ sh["w_gate"], cfg.act) * hs
        y = y + hs @ sh["w_out"]
    return y.reshape(B, S, d)


def moe_a2a(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
            mesh: Optional[jax.sharding.Mesh] = None,
            model_axis: str = "model",
            data_axis: str = "data") -> jnp.ndarray:
    """shard_map EP: per-shard local dispatch (scatter/gather stay local,
    avoiding GSPMD's sharded-scatter collectives) + all_to_all of the
    (E, cap, d) buffers to the shards owning each expert and back.
    Requires E % n_model == 0.  Uses the ambient mesh when `mesh` is
    None (inside pjit/dry-run)."""
    from jax.sharding import PartitionSpec as P
    from .sharding import active_mesh_axes, mesh_axis_size

    E = cfg.n_experts
    if mesh is not None:
        n_model = mesh.shape[model_axis]
        from jax.experimental.shard_map import shard_map as _sm

        def shard_map(f, in_specs, out_specs):
            return _sm(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    else:
        n_model = mesh_axis_size(model_axis)

        def shard_map(f, in_specs, out_specs):
            return jax.shard_map(f, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)

    assert E % n_model == 0, (E, n_model)
    e_loc = E // n_model
    B, S, d = x.shape
    axes = active_mesh_axes() or ((data_axis, model_axis)
                                  if mesh is None else tuple(
                                      mesh.axis_names))
    data_spec = tuple(a for a in ("pod", data_axis) if a in axes) \
        or data_axis

    def local(x_blk, router, w_in, w_gate, w_out):
        # x_blk: (B_loc, S_loc, d) — tokens split over BOTH axes (the
        # sequence slice over `model` is the line format: every token is
        # dispatched exactly once fleet-wide)
        Bl, Sl = x_blk.shape[:2]
        T = Bl * Sl
        x2d = x_blk.reshape(T, d)
        logits = x2d.astype(jnp.float32) @ router
        gates, idx = jax.lax.top_k(logits, cfg.top_k)
        gates = jax.nn.softmax(gates, axis=-1)
        cap = max(1, int(math.ceil(T * cfg.top_k / E
                                   * cfg.capacity_factor)))
        xe, combine = _dispatch(x2d, gates, idx, E, cap, x.dtype,
                                cfg.moe_dispatch)
        # re-shuffle: each shard keeps its e_loc experts' buffers from all
        # shards -> (e_loc, n_model * cap, d)
        xe = xe.reshape(n_model, e_loc, cap, d)
        xe = jax.lax.all_to_all(xe, model_axis, 0, 0, tiled=False)
        xe = xe.transpose(1, 0, 2, 3).reshape(e_loc, n_model * cap, d)
        h = jnp.einsum("ecd,edf->ecf", xe, w_in)
        g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        h = ops.apply_activation(g, cfg.act) * h
        ye = jnp.einsum("ecf,efd->ecd", h, w_out)
        ye = ye.reshape(e_loc, n_model, cap, d).transpose(1, 0, 2, 3)
        ye = jax.lax.all_to_all(ye, model_axis, 0, 0, tiled=False)
        ye = ye.reshape(E, cap, d)
        y = combine(ye)
        return y.reshape(Bl, Sl, d).astype(x.dtype)

    fn = shard_map(
        local,
        in_specs=(P(data_spec, model_axis, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=P(data_spec, model_axis, None))
    # (output replication over `model` is by math — round-trip
    # all_to_all — hence replication checking is disabled)
    y = fn(x, p["router"], p["experts"]["w_in"], p["experts"]["w_gate"],
           p["experts"]["w_out"])
    if cfg.n_shared_experts:
        sh = p["shared"]
        x2d = x.reshape(-1, x.shape[-1])
        hs = x2d @ sh["w_in"]
        hs = ops.apply_activation(x2d @ sh["w_gate"], cfg.act) * hs
        y = y + (hs @ sh["w_out"]).reshape(x.shape)
    return y


def moe(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        impl: str = "auto") -> jnp.ndarray:
    """auto: shard_map a2a EP whenever a model axis is active and the
    expert count divides it (local dispatch, explicit collectives);
    dense GSPMD dispatch otherwise (single-device tests, odd counts)."""
    from .sharding import mesh_axis_size
    if impl == "a2a" and mesh is not None:
        return moe_a2a(p, x, cfg, mesh)
    if impl in ("auto", "a2a"):
        n_model = mesh_axis_size("model")
        if n_model > 1 and cfg.n_experts % n_model == 0 \
                and x.shape[1] % n_model == 0:
            return moe_a2a(p, x, cfg)
        return moe_dense(p, x, cfg)
    return moe_dense(p, x, cfg)
