"""Unified language-model stack for the assigned architectures.

One functional API across families:

    init_params(cfg, key)                 -> params pytree
    forward(cfg, params, batch)           -> logits (B, S, V)
    loss_fn(cfg, params, batch)           -> scalar CE (+ MTP aux)
    init_cache(cfg, batch, max_len)       -> decode cache pytree
    prefill(cfg, params, batch, cache)    -> (last logits, cache)
    decode_step(cfg, params, cache, tok, pos) -> (logits, cache)

Backbones:
  * ``decoder``  — dense / MoE / VLM / enc-free archs; layers stacked and
    scanned (`jax.lax.scan`), per-layer window pattern traced (gemma3 runs
    through the grouped variant below);
  * ``grouped``  — gemma3-style 5-local:1-global blocks: scan over groups
    with an inner scan over the local layers (local layers keep O(window)
    ring caches at decode — the reason gemma3 runs the 500k cell);
  * ``ssm``      — mamba2: scan over SSD blocks, O(1) decode state;
  * ``hybrid``   — zamba2: groups of SSD blocks + one *shared* attention
    block (shared weights, per-group LoRA deltas).

All parameter trees are layer-stacked so 96-layer models compile as one
rolled loop; ``remat`` wraps the per-layer body.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .attention import attention, init_attention, init_mla, mla_attention
from .config import ArchConfig
from .layers import (cross_entropy, dense_init, dtype_of, embed, embed_init,
                     fused_ce, init_embed, init_mlp, init_rms_norm,
                     lm_logits, mlp, rms_norm)
from .moe import init_moe, moe
from .sharding import maybe_shard
from .ssm import SSMState, init_ssm, init_ssm_state, ssm_block


# ==========================================================================
# Per-layer init / apply
# ==========================================================================


def _init_decoder_layer(key, cfg: ArchConfig, dtype, use_moe: bool) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
    }
    if cfg.mla:
        p["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = init_attention(ks[0], cfg, dtype)
    if use_moe:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.gated_mlp)
    return p


def _decoder_layer(p: Dict, h: jnp.ndarray, cfg: ArchConfig,
                   positions: jnp.ndarray,
                   window: Optional[Any] = None,
                   mrope_positions: Optional[jnp.ndarray] = None,
                   kv_cache=None, cache_pos=None, use_moe: bool = False,
                   mesh=None, moe_impl: str = "auto"):
    hn = rms_norm(p["norm1"], h, cfg.norm_eps)
    if cfg.mla:
        a, new_cache = mla_attention(p["attn"], hn, cfg, positions,
                                     kv_cache=kv_cache,
                                     cache_pos=cache_pos)
    else:
        a, new_cache = attention(p["attn"], hn, cfg, positions,
                                 window=window,
                                 mrope_positions=mrope_positions,
                                 kv_cache=kv_cache, cache_pos=cache_pos)
    h = h + a
    hn = rms_norm(p["norm2"], h, cfg.norm_eps)
    h = _residual_shard(h, cfg)
    if use_moe:
        f = moe(p["moe"], hn, cfg, mesh=mesh, impl=moe_impl)
    else:
        f = mlp(p["mlp"], hn, act=cfg.act, gated=cfg.gated_mlp)
    return h + f, new_cache


def _residual_shard(h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Residual-stream sharding between blocks.  With sequence
    parallelism the stream lives sequence-sharded over `model` (norms,
    residual adds and the remat-saved layer stack all shrink n_model x;
    GSPMD turns the block-boundary all-reduces into reduce-scatter +
    all-gather pairs).  Falls back to replicated-over-model when the
    sequence doesn't divide the axis (decode)."""
    from .sharding import mesh_axis_size
    nm = mesh_axis_size("model")
    if cfg.seq_parallel and h.ndim == 3 and nm > 1 \
            and h.shape[1] % nm == 0:
        return maybe_shard(h, "data", "model", None)
    return maybe_shard(h, "data", None, None)


# ==========================================================================
# Pattern helpers
# ==========================================================================


def _layer_windows(cfg: ArchConfig) -> Optional[jnp.ndarray]:
    """Per-layer window (0 = full attention) for plain-decoder archs that
    mix windowed and full layers without the grouped structure."""
    if cfg.sliding_window and not cfg.local_global_ratio:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    return None


def _moe_flags(cfg: ArchConfig) -> Tuple[int, int]:
    """(n dense prefix layers, n moe layers)."""
    if not cfg.n_experts:
        return cfg.n_layers, 0
    return cfg.moe_layer_start, cfg.n_layers - cfg.moe_layer_start


def _grouped_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(groups, locals-per-group, tail local layers) for gemma pattern."""
    R = cfg.local_global_ratio
    G = cfg.n_layers // (R + 1)
    tail = cfg.n_layers - G * (R + 1)
    return G, R, tail


# ==========================================================================
# Init
# ==========================================================================


def _stack_init(key, n: int, init_fn):
    """vmap an init over a leading layer axis (n may be 0 -> None)."""
    if n == 0:
        return None
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg: ArchConfig, key) -> Dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 12)
    p: Dict[str, Any] = {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dtype)

    fam = cfg.family
    if fam == "ssm":
        p["layers"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: {"norm": init_rms_norm(cfg.d_model, dtype),
                       "ssm": init_ssm(k, cfg, dtype)})
    elif fam == "hybrid":
        R = cfg.shared_attn_every
        G = cfg.n_layers // R
        p["groups"] = {
            "ssm": _stack_init(
                ks[2], G, lambda k: _stack_init(
                    k, R, lambda k2: {
                        "norm": init_rms_norm(cfg.d_model, dtype),
                        "ssm": init_ssm(k2, cfg, dtype)})),
            "lora": _stack_init(
                ks[3], G, lambda k: _init_lora(k, cfg, dtype)),
        }
        p["shared"] = _init_decoder_layer(ks[4], cfg, dtype, use_moe=False)
    elif cfg.local_global_ratio:
        G, R, tail = _grouped_dims(cfg)
        p["groups"] = {
            "local": _stack_init(
                ks[2], G, lambda k: _stack_init(
                    k, R, lambda k2: _init_decoder_layer(
                        k2, cfg, dtype, use_moe=False))),
            "global": _stack_init(
                ks[3], G, lambda k: _init_decoder_layer(
                    k, cfg, dtype, use_moe=False)),
        }
        if tail:
            p["tail"] = _stack_init(
                ks[5], tail, lambda k: _init_decoder_layer(
                    k, cfg, dtype, use_moe=False))
    elif cfg.enc_dec:
        p["enc_pos"] = embed_init(ks[6], (cfg.n_audio_frames, cfg.d_model),
                                  dtype)
        p["enc_layers"] = _stack_init(
            ks[2], cfg.n_enc_layers,
            lambda k: _init_decoder_layer(k, cfg, dtype, use_moe=False))
        p["enc_norm"] = init_rms_norm(cfg.d_model, dtype)
        p["dec_layers"] = _stack_init(
            ks[3], cfg.n_layers,
            lambda k: _init_encdec_dec_layer(k, cfg, dtype))
    else:
        n_dense, n_moe = _moe_flags(cfg)
        if n_dense:
            p["dense_layers"] = _stack_init(
                ks[2], n_dense, lambda k: _init_decoder_layer(
                    k, cfg, dtype, use_moe=False))
        if n_moe:
            p["layers"] = _stack_init(
                ks[3], n_moe, lambda k: _init_decoder_layer(
                    k, cfg, dtype, use_moe=True))
        else:
            p["layers"] = p.pop("dense_layers")
        if cfg.mtp:
            p["mtp"] = {
                "proj": dense_init(ks[7], (2 * cfg.d_model, cfg.d_model),
                                   dtype),
                "layer": _init_decoder_layer(ks[8], cfg, dtype,
                                             use_moe=bool(cfg.n_experts)),
                "norm": init_rms_norm(cfg.d_model, dtype),
            }
    return p


def _init_lora(key, cfg: ArchConfig, dtype) -> Dict:
    """Per-group LoRA deltas for the zamba2 shared block (q and mlp-in)."""
    d, r = cfg.d_model, cfg.lora_rank
    hd = cfg.padded_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "q_a": dense_init(ks[0], (d, r), dtype),
        "q_b": jnp.zeros((r, hd), dtype),
        "in_a": dense_init(ks[1], (d, r), dtype),
        "in_b": jnp.zeros((r, cfg.d_ff), dtype),
    }


def _init_encdec_dec_layer(key, cfg: ArchConfig, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    p = _init_decoder_layer(ks[0], cfg, dtype, use_moe=False)
    p["xattn"] = init_attention(ks[1], cfg, dtype)
    p["norm3"] = init_rms_norm(cfg.d_model, dtype)
    return p


# ==========================================================================
# Forward (full sequence: training / prefill body)
# ==========================================================================


def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _embed_inputs(cfg: ArchConfig, params: Dict, batch: Dict
                  ) -> jnp.ndarray:
    h = embed(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "vision_embed" in batch:
        ve = batch["vision_embed"].astype(h.dtype)
        h = jax.lax.dynamic_update_slice(h, ve, (0, 0, 0))
    return h


def _mrope_pos(cfg: ArchConfig, positions: jnp.ndarray
               ) -> Optional[jnp.ndarray]:
    if not cfg.mrope:
        return None
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


def forward_hidden(cfg: ArchConfig, params: Dict, batch: Dict
                   ) -> jnp.ndarray:
    """Full-sequence forward -> final-norm hidden states (B, S, d)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed_inputs(cfg, params, batch)
    h = maybe_shard(h, ("pod", "data"), None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mropep = _mrope_pos(cfg, positions)
    fam = cfg.family

    if fam == "ssm":
        def body(hc, lp):
            y, _ = ssm_block(lp["ssm"],
                             rms_norm(lp["norm"], hc, cfg.norm_eps), cfg)
            return hc + y, None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["layers"])
    elif fam == "hybrid":
        h = _zamba_forward(cfg, params, h, positions)
    elif cfg.local_global_ratio:
        h = _gemma_forward(cfg, params, h, positions)
    elif cfg.enc_dec:
        h = _encdec_forward(cfg, params, h, positions, batch)
    else:
        n_dense, n_moe = _moe_flags(cfg)
        if "dense_layers" in params and n_moe:
            def body_d(hc, lp):
                hn, _ = _decoder_layer(lp, hc, cfg, positions,
                                       mrope_positions=mropep,
                                       use_moe=False)
                return hn, None
            h, _ = jax.lax.scan(_maybe_remat(body_d, cfg), h,
                                params["dense_layers"])

        def body(hc, lp):
            hn, _ = _decoder_layer(lp, hc, cfg, positions,
                                   mrope_positions=mropep,
                                   use_moe=bool(n_moe))
            return hn, None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["layers"])

    return rms_norm(params["final_norm"], h, cfg.norm_eps)


def forward(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, V)."""
    h = forward_hidden(cfg, params, batch)
    head = params.get("lm_head", params["embed"])
    logits = lm_logits(head, h)
    return maybe_shard(logits, ("pod", "data"), None, "model")


def _gemma_forward(cfg: ArchConfig, params: Dict, h, positions):
    W = cfg.sliding_window

    def local_body(hc, lp):
        hn, _ = _decoder_layer(lp, hc, cfg, positions, window=W)
        return hn, None

    def group_body(hc, gp):
        hc, _ = jax.lax.scan(_maybe_remat(local_body, cfg), hc,
                             gp["local"])
        hn, _ = _decoder_layer(gp["global"], hc, cfg, positions,
                               window=0)      # 0 sentinel: full attention
        return hn, None

    h, _ = jax.lax.scan(group_body, h, params["groups"])
    if "tail" in params:
        def tail_body(hc, lp):
            hn, _ = _decoder_layer(lp, hc, cfg, positions, window=W)
            return hn, None
        h, _ = jax.lax.scan(_maybe_remat(tail_body, cfg), h,
                            params["tail"])
    return h


def _lora_apply(shared: Dict, lora: Dict) -> Dict:
    """Shared block weights + this group's LoRA deltas."""
    p = dict(shared)
    attn = dict(shared["attn"])
    attn["wq"] = attn["wq"] + lora["q_a"] @ lora["q_b"]
    p["attn"] = attn
    mlpp = dict(shared["mlp"])
    mlpp["w_in"] = mlpp["w_in"] + lora["in_a"] @ lora["in_b"]
    p["mlp"] = mlpp
    return p


def _zamba_forward(cfg: ArchConfig, params: Dict, h, positions):
    h0 = h  # original embeddings feed the shared block (zamba concat ~ add)

    def ssm_body(hc, lp):
        y, _ = ssm_block(lp["ssm"],
                         rms_norm(lp["norm"], hc, cfg.norm_eps), cfg)
        return hc + y, None

    def group_body(hc, gp):
        hc, _ = jax.lax.scan(_maybe_remat(ssm_body, cfg), hc, gp["ssm"])
        sp = _lora_apply(params["shared"], gp["lora"])
        hn, _ = _decoder_layer(sp, hc + h0, cfg, positions)
        return hn, None

    h, _ = jax.lax.scan(group_body, h, params["groups"])
    return h


def _encdec_forward(cfg: ArchConfig, params: Dict, h, positions, batch):
    enc = batch["audio_embed"].astype(h.dtype) + params["enc_pos"]
    Be, Se = enc.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se)[None], (Be, Se))

    def enc_body(hc, lp):
        hn = rms_norm(lp["norm1"], hc, cfg.norm_eps)
        a = _bidir_attention(lp["attn"], hn, cfg, enc_pos)
        hc = hc + a
        hn = rms_norm(lp["norm2"], hc, cfg.norm_eps)
        return hc + mlp(lp["mlp"], hn, act=cfg.act,
                        gated=cfg.gated_mlp), None

    enc, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), enc,
                          params["enc_layers"])
    enc = rms_norm(params["enc_norm"], enc, cfg.norm_eps)

    def dec_body(hc, lp):
        hn = rms_norm(lp["norm1"], hc, cfg.norm_eps)
        a, _ = attention(lp["attn"], hn, cfg, positions)
        hc = hc + a
        hn = rms_norm(lp["norm3"], hc, cfg.norm_eps)
        x = _cross_attention(lp["xattn"], hn, enc, cfg)
        hc = hc + x
        hn = rms_norm(lp["norm2"], hc, cfg.norm_eps)
        return hc + mlp(lp["mlp"], hn, act=cfg.act,
                        gated=cfg.gated_mlp), None

    h, _ = jax.lax.scan(_maybe_remat(dec_body, cfg), h,
                        params["dec_layers"])
    return h


def _bidir_attention(p: Dict, x, cfg: ArchConfig, positions):
    from .attention import _expand_kv, _mask_padded
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hp = cfg.padded_heads
    q = (x @ p["wq"]).reshape(B, S, Hp, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if Hp != H:
        k = _expand_kv(k, H, Hkv, Hp)
        v = _expand_kv(v, H, Hkv, Hp)
    o = ops.flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3),
                            causal=False, impl=cfg.kernel_impl,
                            fused_vjp=cfg.fused_attn_vjp,
                            block_k=cfg.attn_block_k)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, Hp * hd)
    return _mask_padded(o, H, Hp, hd) @ p["wo"]


def _cross_attention(p: Dict, x, enc, cfg: ArchConfig,
                     kv: Optional[Tuple] = None):
    from .attention import _expand_kv, _mask_padded
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hp = cfg.padded_heads
    q = (x @ p["wq"]).reshape(B, S, Hp, hd).transpose(0, 2, 1, 3)
    if kv is None:
        Se = enc.shape[1]
        k = (enc @ p["wk"]).reshape(B, Se, Hkv, hd).transpose(0, 2, 1, 3)
        v = (enc @ p["wv"]).reshape(B, Se, Hkv, hd).transpose(0, 2, 1, 3)
    else:
        k, v = kv
    if Hp != H:
        kx = _expand_kv(k.transpose(0, 2, 1, 3), H, Hkv, Hp)
        vx = _expand_kv(v.transpose(0, 2, 1, 3), H, Hkv, Hp)
        k, v = kx.transpose(0, 2, 1, 3), vx.transpose(0, 2, 1, 3)
    o = ops.flash_attention(q, k, v, causal=False, impl=cfg.kernel_impl,
                            fused_vjp=cfg.fused_attn_vjp,
                            block_k=cfg.attn_block_k)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, Hp * hd)
    return _mask_padded(o, H, Hp, hd) @ p["wo"]


# ==========================================================================
# Loss
# ==========================================================================


def _head_matrix(cfg: ArchConfig, params: Dict) -> jnp.ndarray:
    head = params.get("lm_head", params["embed"])
    return head if head.shape[0] == cfg.d_model else head.T


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    if cfg.fused_ce_loss:
        h = forward_hidden(cfg, params, batch)
        w = _head_matrix(cfg, params)
        loss = fused_ce(h[:, :-1], w, batch["labels"][:, 1:],
                        cfg.ce_chunk)
    else:
        logits = forward(cfg, params, batch)
        loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    if cfg.mtp and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(cfg, params, batch)
    return loss


def _mtp_loss(cfg: ArchConfig, params: Dict, batch: Dict
              ) -> jnp.ndarray:
    """DeepSeek-V3 multi-token prediction: one extra block predicting
    token t+2 from [h_t ; emb(tok_{t+1})]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed(params["embed"], tokens)
    nxt = embed(params["embed"], jnp.roll(tokens, -1, axis=1))
    hh = jnp.concatenate([h, nxt], axis=-1) @ params["mtp"]["proj"]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    hh, _ = _decoder_layer(params["mtp"]["layer"], hh, cfg, positions,
                           use_moe=bool(cfg.n_experts))
    hh = rms_norm(params["mtp"]["norm"], hh, cfg.norm_eps)
    if cfg.fused_ce_loss:
        w = _head_matrix(cfg, params)
        return fused_ce(hh[:, :-2], w, batch["labels"][:, 2:],
                        cfg.ce_chunk)
    lg = lm_logits(params.get("lm_head", params["embed"]), hh)
    return cross_entropy(lg[:, :-2], batch["labels"][:, 2:])


# ==========================================================================
# Decode caches
# ==========================================================================


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    dtype = dtype_of(cfg.dtype)
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    fam = cfg.family

    def kv(n, S):
        return {"k": jnp.zeros((n, batch, Hkv, S, hd), dtype),
                "v": jnp.zeros((n, batch, Hkv, S, hd), dtype)}

    if fam == "ssm":
        st = init_ssm_state(cfg, batch, dtype)
        return {"ssm": jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_layers,) + x.shape), st)}
    if fam == "hybrid":
        R = cfg.shared_attn_every
        G = cfg.n_layers // R
        st = init_ssm_state(cfg, batch, dtype)
        return {
            "ssm": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None, None],
                                           (G, R) + x.shape), st),
            "shared": kv(G, max_len),
        }
    if cfg.local_global_ratio:
        G, R, tail = _grouped_dims(cfg)
        W = min(cfg.sliding_window, max_len)
        c = {"local": kv(G * R, W), "global": kv(G, max_len)}
        c["local"] = jax.tree_util.tree_map(
            lambda x: x.reshape((G, R) + x.shape[1:]), c["local"])
        if tail:
            c["tail"] = kv(tail, W)
        return c
    if cfg.enc_dec:
        return {"self": kv(cfg.n_layers, max_len), "cross": None}
    if cfg.mla:
        width = cfg.kv_lora_rank + cfg.d_rope
        n_dense, n_moe = _moe_flags(cfg)
        c = {"latent": jnp.zeros((n_moe or cfg.n_layers, batch, max_len,
                                  width), dtype)}
        if n_dense and n_moe:
            c["latent_dense"] = jnp.zeros((n_dense, batch, max_len, width),
                                          dtype)
        return c
    n_dense, n_moe = _moe_flags(cfg)
    c = {"kv": kv(n_moe or cfg.n_layers, max_len)}
    if n_dense and n_moe:
        c["kv_dense"] = kv(n_dense, max_len)
    return c


# ==========================================================================
# Decode step
# ==========================================================================


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict,
                token: jnp.ndarray, pos: jnp.ndarray,
                aux: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """token (B,) int32; pos scalar int32.  Returns (logits (B,V), cache).
    `aux` carries encoder states (whisper) / vision embeds when needed."""
    B = token.shape[0]
    h = embed(params["embed"], token[:, None])
    if cfg.family == "vlm" and aux is not None and \
            "vision_embed" in aux:
        ve = aux["vision_embed"]                  # (B, Nv, d)
        idx = jnp.minimum(pos, ve.shape[1] - 1)
        vis = jax.lax.dynamic_slice_in_dim(ve, idx, 1, axis=1)
        h = jnp.where(pos < ve.shape[1], vis.astype(h.dtype), h)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    fam = cfg.family
    new_cache = dict(cache)

    if fam == "ssm":
        def body(hc, xs):
            lp, st = xs
            y, st2 = ssm_block(lp["ssm"],
                               rms_norm(lp["norm"], hc, cfg.norm_eps),
                               cfg, state=st)
            return hc + y, st2
        h, new_ssm = jax.lax.scan(body, h, (params["layers"],
                                            cache["ssm"]))
        new_cache["ssm"] = new_ssm
    elif fam == "hybrid":
        h, new_cache = _zamba_decode(cfg, params, cache, h, positions,
                                     pos, token)
    elif cfg.local_global_ratio:
        h, new_cache = _gemma_decode(cfg, params, cache, h, positions,
                                     pos)
    elif cfg.enc_dec:
        h, new_cache = _encdec_decode(cfg, params, cache, h, positions,
                                      pos, aux)
    elif cfg.mla:
        def body(hc, xs):
            lp, lat = xs
            hn, lat2 = _decoder_layer(lp, hc, cfg, positions,
                                      kv_cache=lat, cache_pos=pos,
                                      use_moe=bool(cfg.n_experts))
            return hn, lat2
        if "latent_dense" in cache:
            def body_d(hc, xs):
                lp, lat = xs
                hn, lat2 = _decoder_layer(lp, hc, cfg, positions,
                                          kv_cache=lat, cache_pos=pos,
                                          use_moe=False)
                return hn, lat2
            h, nd = jax.lax.scan(body_d, h, (params["dense_layers"],
                                             cache["latent_dense"]))
            new_cache["latent_dense"] = nd
        h, nl = jax.lax.scan(body, h, (params["layers"], cache["latent"]))
        new_cache["latent"] = nl
    else:
        n_dense, n_moe = _moe_flags(cfg)

        if "kv_dense" in cache:
            def body_d(hc, xs):
                lp, ck, cv = xs
                hn, kv2 = _decoder_layer(lp, hc, cfg, positions,
                                         kv_cache=(ck, cv),
                                         cache_pos=pos, use_moe=False)
                return hn, kv2
            h, (nk, nv) = jax.lax.scan(
                body_d, h, (params["dense_layers"],
                            cache["kv_dense"]["k"],
                            cache["kv_dense"]["v"]))
            new_cache["kv_dense"] = {"k": nk, "v": nv}

        def body(hc, xs):
            lp, ck, cv = xs
            hn, kv2 = _decoder_layer(lp, hc, cfg, positions,
                                     kv_cache=(ck, cv), cache_pos=pos,
                                     use_moe=bool(n_moe))
            return hn, kv2
        h, (nk, nv) = jax.lax.scan(body, h, (params["layers"],
                                             cache["kv"]["k"],
                                             cache["kv"]["v"]))
        new_cache["kv"] = {"k": nk, "v": nv}

    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = lm_logits(head, h)[:, 0]
    return logits, new_cache


def _gemma_decode(cfg: ArchConfig, params, cache, h, positions, pos):
    from .attention import decode_windowed
    W = cfg.sliding_window
    new_cache = dict(cache)

    def local_body(hc, xs):
        lp, ck, cv = xs
        hn, kv2 = decode_windowed(lp["attn"],
                                  rms_norm(lp["norm1"], hc, cfg.norm_eps),
                                  cfg, (ck, cv), pos, W)
        hc = hc + hn
        hn2 = rms_norm(lp["norm2"], hc, cfg.norm_eps)
        return hc + mlp(lp["mlp"], hn2, act=cfg.act,
                        gated=cfg.gated_mlp), kv2

    def group_body(hc, xs):
        gp, lk, lv, gk, gv = xs
        hc, lkv = jax.lax.scan(local_body, hc, (gp["local"], lk, lv))
        hn, gkv = _decoder_layer(gp["global"], hc, cfg, positions,
                                 kv_cache=(gk, gv), cache_pos=pos)
        return hn, (lkv, gkv)

    h, (lkv, gkv) = jax.lax.scan(
        group_body, h,
        (params["groups"], cache["local"]["k"], cache["local"]["v"],
         cache["global"]["k"], cache["global"]["v"]))
    new_cache["local"] = {"k": lkv[0], "v": lkv[1]}
    new_cache["global"] = {"k": gkv[0], "v": gkv[1]}
    if "tail" in params:
        def tail_body(hc, xs):
            return local_body(hc, xs)
        h, tkv = jax.lax.scan(tail_body, h,
                              (params["tail"], cache["tail"]["k"],
                               cache["tail"]["v"]))
        new_cache["tail"] = {"k": tkv[0], "v": tkv[1]}
    return h, new_cache


def _zamba_decode(cfg: ArchConfig, params, cache, h, positions, pos,
                  token):
    h0 = h
    new_cache = dict(cache)

    def ssm_body(hc, xs):
        lp, st = xs
        y, st2 = ssm_block(lp["ssm"],
                           rms_norm(lp["norm"], hc, cfg.norm_eps),
                           cfg, state=st)
        return hc + y, st2

    def group_body(hc, xs):
        gp, st, ck, cv = xs
        hc, st2 = jax.lax.scan(ssm_body, hc, (gp["ssm"], st))
        sp = _lora_apply(params["shared"], gp["lora"])
        hn, kv2 = _decoder_layer(sp, hc + h0, cfg, positions,
                                 kv_cache=(ck, cv), cache_pos=pos)
        return hn, (st2, kv2)

    h, (st2, kv2) = jax.lax.scan(
        group_body, h,
        (params["groups"], cache["ssm"], cache["shared"]["k"],
         cache["shared"]["v"]))
    new_cache["ssm"] = st2
    new_cache["shared"] = {"k": kv2[0], "v": kv2[1]}
    return h, new_cache


def _encdec_decode(cfg: ArchConfig, params, cache, h, positions, pos,
                   aux):
    enc = aux["enc_states"]
    cross_kv = aux.get("cross_kv")
    new_cache = dict(cache)

    def body(hc, xs):
        lp, ck, cv, xk, xv = xs
        hn = rms_norm(lp["norm1"], hc, cfg.norm_eps)
        a, kv2 = attention(lp["attn"], hn, cfg, positions,
                           kv_cache=(ck, cv), cache_pos=pos)
        hc = hc + a
        hn = rms_norm(lp["norm3"], hc, cfg.norm_eps)
        x = _cross_attention(lp["xattn"], hn, enc, cfg, kv=(xk, xv))
        hc = hc + x
        hn = rms_norm(lp["norm2"], hc, cfg.norm_eps)
        return hc + mlp(lp["mlp"], hn, act=cfg.act,
                        gated=cfg.gated_mlp), kv2

    h, kv2 = jax.lax.scan(body, h, (params["dec_layers"],
                                    cache["self"]["k"],
                                    cache["self"]["v"],
                                    cross_kv["k"], cross_kv["v"]))
    new_cache["self"] = {"k": kv2[0], "v": kv2[1]}
    return h, new_cache


# ==========================================================================
# Prefill (fill the cache from a full prompt; returns last-token logits)
# ==========================================================================


def prefill(cfg: ArchConfig, params: Dict, batch: Dict
            ) -> jnp.ndarray:
    """Prompt processing: full-sequence forward returning last-position
    logits.  (Cache population on TPU reuses the same compute — the
    roofline of the prefill cell is this lowering.)"""
    logits = forward(cfg, params, batch)
    return logits[:, -1]


def encode_audio(cfg: ArchConfig, params: Dict, audio_embed: jnp.ndarray
                 ) -> jnp.ndarray:
    """Whisper encoder only (for decode aux)."""
    enc = audio_embed.astype(dtype_of(cfg.dtype)) + params["enc_pos"]
    Be, Se = enc.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se)[None], (Be, Se))

    def enc_body(hc, lp):
        hn = rms_norm(lp["norm1"], hc, cfg.norm_eps)
        a = _bidir_attention(lp["attn"], hn, cfg, enc_pos)
        hc = hc + a
        hn = rms_norm(lp["norm2"], hc, cfg.norm_eps)
        return hc + mlp(lp["mlp"], hn, act=cfg.act,
                        gated=cfg.gated_mlp), None

    enc, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])
    return rms_norm(params["enc_norm"], enc, cfg.norm_eps)


def cross_kv(cfg: ArchConfig, params: Dict, enc: jnp.ndarray) -> Dict:
    """Per-decoder-layer cross-attention K/V from encoder states."""
    B, Se, _ = enc.shape
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def one(lp):
        k = (enc @ lp["xattn"]["wk"]).reshape(B, Se, Hkv, hd)
        v = (enc @ lp["xattn"]["wv"]).reshape(B, Se, Hkv, hd)
        return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}

    return jax.vmap(one)(params["dec_layers"])
