"""Training step: loss -> grads -> AdamW, with microbatching and optional
cross-pod int8 gradient compression.

`make_train_step(cfg, ...)` returns a pure function
    train_step(state, batch) -> (state, metrics)
suitable for `jax.jit` with in/out shardings from
:func:`repro.models.registry.shardings_for`.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.runtime.overlap import accumulate_grads
from .config import ArchConfig
from . import lm


class TrainState(NamedTuple):
    params: Any
    opt: optim.AdamWState
    error_fb: Any = None          # int8-compression error feedback


@dataclass(frozen=True)
class TrainOptions:
    n_micro: int = 1
    compress_grads: bool = False  # cross-pod int8 EF compression
    lr_schedule: str = "cosine"
    warmup: int = 100
    total_steps: int = 10000


def init_train_state(cfg: ArchConfig, key,
                     opt_cfg: Optional[optim.AdamWConfig] = None,
                     opts: Optional[TrainOptions] = None) -> TrainState:
    opt_cfg = opt_cfg or default_opt_config(cfg)
    opts = opts or TrainOptions()
    params = lm.init_params(cfg, key)
    state = optim.init_state(opt_cfg, params)
    err = optim.init_error(params) if opts.compress_grads else None
    return TrainState(params, state, err)


def default_opt_config(cfg: ArchConfig) -> optim.AdamWConfig:
    # bf16 moments for >=100B-parameter configs (fit the dry-run HBM)
    big = cfg.n_params() > 50e9
    return optim.AdamWConfig(
        moment_dtype="bfloat16" if big else "float32")


def make_train_step(cfg: ArchConfig,
                    opt_cfg: Optional[optim.AdamWConfig] = None,
                    opts: Optional[TrainOptions] = None) -> Callable:
    opt_cfg = opt_cfg or default_opt_config(cfg)
    opts = opts or TrainOptions()

    def lsf(params, batch):
        return lm.loss_fn(cfg, params, batch)

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict]:
        loss, grads = accumulate_grads(lsf, state.params, batch,
                                       opts.n_micro)
        err = state.error_fb
        if opts.compress_grads and err is not None:
            grads, err = optim.compress_grads(grads, err)
        if opts.lr_schedule == "cosine":
            lr_scale = optim.warmup_cosine(state.opt.step + 1,
                                           opts.warmup, opts.total_steps)
        else:
            lr_scale = 1.0
        gnorm = optim.global_norm(grads)
        params, opt_state = optim.apply_updates(
            opt_cfg, state.params, grads, state.opt, lr_scale)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm,
                   "lr_scale": jnp.asarray(lr_scale, jnp.float32),
                   "step": opt_state.step}
        return TrainState(params, opt_state, err), metrics

    return train_step
