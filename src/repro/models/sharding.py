"""Sharding rules — the paper's *format selection* at pod scale.

The Neutron compiler picks per-layer between depth parallelism (split
output channels; share activations) and line parallelism (split lines;
share parameters) by estimated latency (§IV-A).  On a TPU mesh the same
two formats are tensor parallelism over the ``model`` axis (split
heads/features; activations broadcast) and data/sequence parallelism over
the ``data`` axis (split batch/tokens; parameters broadcast).  This module
holds

  * the partitioning rule set mapping every parameter in the tree to a
    PartitionSpec (depth-format on features, Megatron col/row pairing so
    consecutive matmuls need no reshard — the paper's "rotating fragment
    addressing avoids rearrangement"),
  * activation constraint helpers safe on un-meshed CPU,
  * :class:`FormatPlanner` — the latency-model-driven chooser used by the
    perf pass (depth vs line per block, switch cost = collective bytes).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def active_mesh_axes() -> Tuple[str, ...]:
    """Axis names of the mesh active in the current jit/pjit context."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return ()
        return tuple(m.axis_names)
    except Exception:  # pragma: no cover
        return ()


def mesh_axis_size(name: str) -> int:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names or name not in m.axis_names:
            return 1
        return int(m.shape[name])
    except Exception:  # pragma: no cover
        return 1


def maybe_shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint that degrades to identity when no mesh is
    active or when a referenced axis is absent (CPU unit tests)."""
    axes = active_mesh_axes()
    if not axes:
        return x

    def keep(s):
        if s is None:
            return None
        if isinstance(s, tuple):
            kept = tuple(a for a in s if a in axes)
            return kept if kept else None
        return s if s in axes else None

    clean = tuple(keep(s) for s in spec)
    try:
        return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:  # pragma: no cover
        return x


# --------------------------------------------------------------------------
# Parameter partition rules
# --------------------------------------------------------------------------

#: rule table: regex on the param path -> spec builder(shape) -> tuple.
#: 'M' = model axis, 'F' = fsdp (data) axis, None = replicated.
_RULES = [
    # MoE experts: expert-parallel over model axis (must precede the
    # generic w_in/w_gate/w_out rules)
    (r"experts/w_(in|gate|out)$", lambda sh: ("M", "F", None)),
    (r"router$", lambda sh: (None, None)),
    # embeddings / lm head: vocab on model axis
    (r"embed$", lambda sh: ("M", "F")),
    (r"lm_head$", lambda sh: ("F", "M")),
    (r"mtp_head$", lambda sh: ("F", "M")),
    # attention: column-parallel qkv, row-parallel out
    (r"wq$|wk$|wv$|w_uq$|w_uk$|w_uv$", lambda sh: ("F", "M")),
    (r"wo$", lambda sh: ("M", "F")),
    (r"w_dq$|w_dkv$", lambda sh: ("F", None)),
    # mlp: column-parallel in/gate, row-parallel out
    (r"w_in$|w_gate$", lambda sh: ("F", "M")),
    (r"w_out$", lambda sh: ("M", "F")),
    # mamba: split the inner dim (heads) over model
    (r"ssm_in$", lambda sh: ("F", "M")),
    (r"ssm_out$", lambda sh: ("M", "F")),
    (r"conv_w$", lambda sh: (None, "M")),
    (r"(A_log|D|dt_bias)$", lambda sh: ("M",)),
    # norms / small vectors replicated
    (r".*", lambda sh: tuple(None for _ in sh)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(path: str, shape: Tuple[int, ...],
               model_axis: str = "model",
               fsdp_axis: Optional[str] = None,
               stacked: bool = False) -> P:
    """Spec for one parameter.  The rule's spec is RIGHT-aligned onto the
    shape so any number of leading stack axes (layer scans, grouped
    G x R stacks) are replicated automatically."""
    base: Tuple = ()
    for pat, fn in _RULES:
        if re.search(pat, path):
            base = fn(shape)
            break
    subst = {"M": model_axis, "F": fsdp_axis, None: None}
    spec = tuple(subst.get(s, None) for s in base)
    rank = len(shape)
    if len(spec) > rank:
        spec = spec[len(spec) - rank:]
    spec = tuple(None for _ in range(rank - len(spec))) + spec
    return P(*spec)


#: default mesh axis sizes for divisibility checks (the production mesh)
DEFAULT_AXIS_SIZES = {"model": 16, "data": 16, "pod": 2}


def enforce_divisible(spec: P, shape: Tuple[int, ...],
                      axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """Drop axis names from dims the mesh axis doesn't divide — pjit
    rejects explicit arg shardings with uneven dims (odd vocab sizes
    like 50280 stay replicated; head/vocab padding is the opt-in fix)."""
    sizes = axis_sizes or DEFAULT_AXIS_SIZES
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * len(shape)):
        if s is None:
            out.append(None)
            continue
        names = s if isinstance(s, tuple) else (s,)
        total = 1
        for nm in names:
            total *= sizes.get(nm, 1)
        out.append(s if dim % total == 0 else None)
    return P(*out)


def tree_partition_specs(params: Any, model_axis: str = "model",
                         fsdp_axis: Optional[str] = None,
                         replicate_kv: bool = False,
                         replicate_q: bool = False) -> Any:
    """PartitionSpec pytree matching `params` (a pytree of arrays or
    ShapeDtypeStructs).  Anything under a 'layers'/'groups' subtree is
    treated as layer-stacked (leading scan axis).

    ``replicate_kv`` keeps wk/wv (and MQA/GQA KV caches) replicated over
    the model axis — the Neutron *broadcast-operand* format, required
    when n_kv_heads doesn't divide the TP degree (fractional-head
    sharding otherwise costs an all-reduce per attention block).
    ``replicate_q`` does the same for wq/wo when n_heads doesn't divide
    the TP degree."""

    def spec_of(path, leaf):
        ps = _path_str(path)
        stacked = bool(re.search(r"(layers|groups|tail|enc_layers|"
                                 r"dec_layers)/", ps))
        if replicate_kv and re.search(r"(wk|wv)$", ps):
            n = len(leaf.shape)
            return P(*((None,) * n))
        if replicate_q and re.search(r"(wq|wo)$", ps):
            n = len(leaf.shape)
            return P(*((None,) * n))
        spec = param_spec(ps, tuple(leaf.shape), model_axis, fsdp_axis,
                          stacked)
        return enforce_divisible(spec, tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(spec_of, params)


# --------------------------------------------------------------------------
# Format planner (depth vs line) — TPU analogue of §IV-A
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshSpec:
    n_data: int
    n_model: int
    n_pod: int = 1
    flops_per_chip: float = 197e12      # bf16 TPU v5e
    hbm_gbps: float = 819e9
    ici_gbps: float = 50e9              # per link


@dataclass
class LayerShape:
    """One matmul-ish block: (tokens, d_in, d_out), bytes/elt."""
    name: str
    tokens: int
    d_in: int
    d_out: int
    bytes_per_elt: int = 2


@dataclass
class FormatChoice:
    name: str
    fmt: str                            # "depth" (TP) | "line" (SP/DP)
    t_depth: float
    t_line: float


class FormatPlanner:
    """Pick per-block depth (shard d_out over model, all-reduce partials)
    vs line (shard tokens, all-gather params) by modeled latency —
    the paper's format-selection criterion with collective bytes playing
    the role of the TCM-copy bytes."""

    def __init__(self, mesh: MeshSpec):
        self.mesh = mesh

    def block_latency(self, ls: LayerShape, fmt: str) -> float:
        m = self.mesh
        flops = 2.0 * ls.tokens * ls.d_in * ls.d_out
        if fmt == "depth":
            # TP: weights split n_model ways; activations replicated;
            # row-parallel partner needs one all-reduce of the output.
            t_compute = flops / m.n_model / m.flops_per_chip
            coll = 2.0 * ls.tokens * ls.d_out * ls.bytes_per_elt \
                * (m.n_model - 1) / m.n_model
            t_coll = coll / m.ici_gbps
        else:
            # line/SP: tokens split; params broadcast (all-gather weights)
            t_compute = flops / m.n_model / m.flops_per_chip
            coll = ls.d_in * ls.d_out * ls.bytes_per_elt \
                * (m.n_model - 1) / m.n_model
            t_coll = coll / m.ici_gbps
        w_bytes = ls.d_in * ls.d_out * ls.bytes_per_elt / m.n_model
        a_bytes = ls.tokens * (ls.d_in + ls.d_out) * ls.bytes_per_elt
        if fmt == "line":
            a_bytes /= m.n_model
        t_mem = (w_bytes + a_bytes) / m.hbm_gbps
        return max(t_compute, t_mem) + t_coll

    def choose(self, ls: LayerShape) -> FormatChoice:
        td = self.block_latency(ls, "depth")
        tl = self.block_latency(ls, "line")
        return FormatChoice(ls.name, "depth" if td <= tl else "line",
                            td, tl)

    def plan(self, blocks) -> Dict[str, FormatChoice]:
        return {b.name: self.choose(b) for b in blocks}
