"""Attention variants: GQA / MQA, sliding-window, MLA, with KV caches.

Three entry modes per variant:
  * ``full``   — training / prefill over a whole sequence (flash kernel);
  * ``decode`` — one new token against a cached KV prefix (flash-decode);
the cache layout is (B, Hkv, S, D) so the sequence axis can be sharded
across the ``data`` mesh axis for 500k-token decode (the per-shard
partials are exact thanks to the kernel's log-sum-exp output).

MLA (DeepSeek-V3) caches only the compressed KV latent + decoupled RoPE
key — the paper's "operand that stays resident" applied to the KV cache:
per token 512+64 floats instead of 128 heads x 2 x 128.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ArchConfig
from .layers import apply_mrope, apply_rope, dense_init, init_rms_norm, \
    rms_norm
from .sharding import maybe_shard, mesh_axis_size


# --------------------------------------------------------------------------
# GQA / MQA
# --------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype) -> Dict:
    """wq/wo are allocated at `padded_heads` (a tp_pad multiple) so the
    head axis reshapes cleanly under 16-way tensor parallelism; the
    padded head outputs are zero-masked in the forward so the math is
    exactly the nominal-head model (padded weights receive zero grad)."""
    d, Hkv, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    Hp = cfg.padded_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, Hp * hd), dtype),
        "wk": dense_init(ks[1], (d, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, Hkv * hd), dtype),
        "wo": dense_init(ks[3], (Hp * hd, d), dtype),
    }


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    B, S, _ = x.shape
    return x.reshape(B, S, n, -1)


def _expand_kv(k: jnp.ndarray, H: int, Hkv: int, Hp: int) -> jnp.ndarray:
    """(B,S,Hkv,hd) -> (B,S,Hp,hd) with the ORIGINAL H//Hkv group map
    (padded q heads clamp to the last kv head; their outputs are masked
    away).  Used when flash's uniform Hp//Hkv grouping would misroute."""
    group = max(H // max(Hkv, 1), 1)
    idx = jnp.minimum(jnp.arange(Hp) // group, Hkv - 1)
    return jnp.take(k, idx, axis=2)


def _mask_padded(o2d: jnp.ndarray, H: int, Hp: int, hd: int
                 ) -> jnp.ndarray:
    """Zero the padded-head columns of the flattened attention output
    (B, S, Hp*hd) so wo's padded rows contribute (and learn) nothing."""
    if Hp == H:
        return o2d
    keep = (jnp.arange(Hp * hd) < H * hd).astype(o2d.dtype)
    return o2d * keep


def attention(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
              positions: jnp.ndarray,
              window: Optional[jnp.ndarray] = None,
              mrope_positions: Optional[jnp.ndarray] = None,
              kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray,
                         Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """x (B, S, d).  Full mode when kv_cache is None; decode mode (S == 1)
    updates the cache at `cache_pos` and attends to the valid prefix.
    `window` is a traced per-layer scalar (0 => full attention)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hp = cfg.padded_heads
    q = _split_heads(x @ p["wq"], Hp)
    k = _split_heads(x @ p["wk"], Hkv)
    v = _split_heads(x @ p["wv"], Hkv)
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections,
                        cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections,
                        cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        # window: None -> arch default; 0 -> explicitly full; int -> window
        if window is None:
            w = cfg.sliding_window or None
        elif isinstance(window, int) and window <= 0:
            w = None
        else:
            w = window
        q = maybe_shard(q, "data", None, "model", None)
        # KV format selection: head-sharded when the kv heads divide the
        # model axis; otherwise computed sharded (flat) and ALL-GATHERED
        # here to replicated — the broadcast-operand format.  Gathering
        # the small KV beats replicating its projection FLOPs.
        kv_ok = Hkv % max(mesh_axis_size("model"), 1) == 0
        k = maybe_shard(k, "data", None, "model" if kv_ok else None, None)
        v = maybe_shard(v, "data", None, "model" if kv_ok else None, None)
        if Hp != H:
            # padded TP: expand kv to the padded layout (original group
            # map); the expansion of replicated kv is a free local slice
            k = _expand_kv(k, H, Hkv, Hp)
            v = _expand_kv(v, H, Hkv, Hp)
            k = maybe_shard(k, "data", None, "model", None)
            v = maybe_shard(v, "data", None, "model", None)
        o = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=w,
            impl=cfg.kernel_impl, fused_vjp=cfg.fused_attn_vjp,
            block_k=cfg.attn_block_k)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, Hp * hd)
        o = _mask_padded(o, H, Hp, hd)
        return o @ p["wo"], None

    # ---- decode: S == 1 (cache stays at the nominal Hkv heads) ----
    ck, cv = kv_cache                           # (B, Hkv, Smax, hd)
    qd = q[:, 0][:, :H].reshape(B, H, hd)        # drop padded heads
    if _use_seq_sharded_decode(cfg, B, ck.shape[2]):
        o, ck, cv = _decode_seq_sharded(
            qd, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            ck, cv, cache_pos, cfg)
    else:
        ck = jax.lax.dynamic_update_slice(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype),
            (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype),
            (0, 0, cache_pos, 0))
        kv_len = jnp.full((B,), cache_pos + 1, dtype=jnp.int32)
        o = ops.flash_decode(qd, ck, cv, kv_len=kv_len,
                             impl=cfg.kernel_impl)
    o = o.reshape(B, H * hd)
    if Hp != H:
        o = jnp.pad(o, ((0, 0), (0, (Hp - H) * hd)))
    return (o @ p["wo"])[:, None, :], (ck, cv)


def _decode_seq_sharded(q3, k_new, v_new, ck, cv, pos, cfg: ArchConfig):
    """Decode against a KV cache whose SEQUENCE axis is sharded over the
    `model` mesh axis (broadcast-operand archs: kv heads don't divide the
    axis).  Each shard updates only the slice owning `pos`, computes a
    partial flash-decode over its local positions, and the shards merge
    exactly via the log-sum-exp identity.  Avoids GSPMD's involuntary
    full rematerialization of the cache on the dynamic-position write
    (nemotron-340b decode: 368 GB/step of all-gather otherwise).

    q3 (B,H,hd); k_new/v_new (B,Hkv,1,hd); ck/cv (B,Hkv,S,hd)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.kernels.ref import combine_decode_shards

    def local(q3, kn, vn, ck, cv):
        i = jax.lax.axis_index("model")
        S_loc = ck.shape[2]
        start = (i * S_loc).astype(jnp.int32)
        off = jnp.clip(pos - start, 0, S_loc - 1)
        write = jnp.logical_and(pos >= start, pos < start + S_loc)

        def upd(c, n):
            return jax.lax.cond(
                write,
                lambda: jax.lax.dynamic_update_slice(
                    c, n.astype(c.dtype), (0, 0, off, 0)),
                lambda: c)

        ck2 = upd(ck, kn)
        cv2 = upd(cv, vn)
        kv_len = jnp.clip(pos + 1 - start, 0, S_loc)
        o, lse = ops.flash_decode(
            q3, ck2, cv2,
            kv_len=jnp.full((q3.shape[0],), kv_len, jnp.int32),
            return_lse=True, impl=cfg.kernel_impl)
        outs = jax.lax.all_gather(o, "model")
        lses = jax.lax.all_gather(lse, "model")
        return combine_decode_shards(outs, lses), ck2, cv2

    fn = jax.shard_map(
        local,
        in_specs=(P("data", None, None), P("data", None, None, None),
                  P("data", None, None, None),
                  P("data", None, "model", None),
                  P("data", None, "model", None)),
        out_specs=(P("data", None, None),
                   P("data", None, "model", None),
                   P("data", None, "model", None)),
        check_vma=False)
    return fn(q3, k_new, v_new, ck, cv)


def _use_seq_sharded_decode(cfg: ArchConfig, B: int, S: int) -> bool:
    nm = mesh_axis_size("model")
    nd = mesh_axis_size("data")
    return (nm > 1 and cfg.n_kv_heads and cfg.n_kv_heads % nm != 0
            and S % nm == 0 and B % max(nd, 1) == 0 and B >= nd)


# --------------------------------------------------------------------------
# Sliding-window KV cache decode (ring buffer)
# --------------------------------------------------------------------------


def decode_windowed(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
                    kv_cache: Tuple[jnp.ndarray, jnp.ndarray],
                    cache_pos: jnp.ndarray, window: int
                    ) -> Tuple[jnp.ndarray,
                               Tuple[jnp.ndarray, jnp.ndarray]]:
    """Decode against a ring-buffer cache of size `window` (local layers
    of gemma3 at 500k context: KV stays O(window), not O(S))."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hp = cfg.padded_heads
    q = _split_heads(x @ p["wq"], Hp)
    k = _split_heads(x @ p["wk"], Hkv)
    v = _split_heads(x @ p["wv"], Hkv)
    pos = jnp.full((B, 1), cache_pos, dtype=jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    ck, cv = kv_cache                            # (B, Hkv, window, hd)
    slot = jnp.mod(cache_pos, window)
    ck = jax.lax.dynamic_update_slice(
        ck, k.transpose(0, 2, 1, 3).astype(ck.dtype), (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(
        cv, v.transpose(0, 2, 1, 3).astype(cv.dtype), (0, 0, slot, 0))
    kv_len = jnp.full((B,), jnp.minimum(cache_pos + 1, window),
                      dtype=jnp.int32)
    o = ops.flash_decode(q[:, 0][:, :H].reshape(B, H, hd), ck, cv,
                         kv_len=kv_len, impl=cfg.kernel_impl)
    o = o.reshape(B, H * hd)
    if Hp != H:
        o = jnp.pad(o, ((0, 0), (0, (Hp - H) * hd)))
    return (o @ p["wo"])[:, None, :], (ck, cv)


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# --------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig, dtype) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.d_nope, cfg.d_rope, cfg.d_v
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, qr), dtype),
        "q_norm": init_rms_norm(qr, dtype),
        "w_uq": dense_init(ks[1], (qr, H * (dn + dr)), dtype),
        "w_dkv": dense_init(ks[2], (d, kvr + dr), dtype),
        "kv_norm": init_rms_norm(kvr, dtype),
        "w_uk": dense_init(ks[3], (kvr, H * dn), dtype),
        "w_uv": dense_init(ks[4], (kvr, H * dv), dtype),
        "wo": dense_init(ks[5], (H * dv, d), dtype),
    }


def mla_attention(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
                  positions: jnp.ndarray,
                  kv_cache: Optional[jnp.ndarray] = None,
                  cache_pos: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """MLA.  Cache holds only (latent || rope-key): (B, Smax, kvr + dr)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv, kvr = cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank
    cq = rms_norm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ p["w_dkv"]                    # (B, S, kvr + dr)
    latent, k_rope = ckv_full[..., :kvr], ckv_full[..., kvr:]
    latent = rms_norm(p["kv_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    packed = jnp.concatenate([latent, k_rope], axis=-1)

    if kv_cache is not None:
        kv_cache = jax.lax.dynamic_update_slice(
            kv_cache, packed.astype(kv_cache.dtype), (0, cache_pos, 0))
        packed_all = kv_cache
        S_kv = kv_cache.shape[1]
        kv_len = cache_pos + 1
    else:
        packed_all = packed
        S_kv = S
        kv_len = None

    latent_all = packed_all[..., :kvr].astype(x.dtype)
    k_rope_all = packed_all[..., kvr:].astype(x.dtype)
    k_nope = (latent_all @ p["w_uk"]).reshape(B, S_kv, H, dn)
    v_all = (latent_all @ p["w_uv"]).reshape(B, S_kv, H, dv)
    k_all = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :],
                                  (B, S_kv, H, dr))], axis=-1)
    q_all = jnp.concatenate([q_nope, q_rope], axis=-1)
    sm = 1.0 / math.sqrt(dn + dr)

    if kv_cache is None:
        o = ops.flash_attention(q_all.transpose(0, 2, 1, 3),
                                k_all.transpose(0, 2, 1, 3),
                                v_all.transpose(0, 2, 1, 3),
                                causal=True, sm_scale=sm,
                                impl=cfg.kernel_impl,
                                fused_vjp=cfg.fused_attn_vjp,
                                block_k=cfg.attn_block_k)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
        return o @ p["wo"], None
    o = ops.flash_decode(q_all[:, 0].reshape(B, H, dn + dr),
                         k_all.transpose(0, 2, 1, 3),
                         v_all.transpose(0, 2, 1, 3),
                         kv_len=jnp.full((B,), kv_len, dtype=jnp.int32),
                         sm_scale=sm, impl=cfg.kernel_impl)
    return (o.reshape(B, H * dv) @ p["wo"])[:, None, :], kv_cache
