"""Architecture × shape registry: input specs, step functions, shardings.

This is the single source of truth the dry-run, benchmarks and tests all
consume:

    get_arch(name)              -> ArchConfig (from repro.configs)
    SHAPES                      -> the four assigned input-shape cells
    cells(cfg)                  -> the valid (arch, shape) cells
    input_specs(cfg, shape)     -> dict of ShapeDtypeStruct model inputs
    abstract_state(cfg, shape)  -> eval_shape'd state/cache trees
    build_step(cfg, shape)      -> (step_fn, arg structs, in/out specs)

Decode shapes lower ``serve_step`` (one token against a full cache);
``long_500k`` exists only for sub-quadratic archs; encoder-only models
have no decode cells (none assigned); the modality frontends are stubs —
``input_specs`` emits precomputed frame/patch embeddings as inputs.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import lm
from .config import ArchConfig
from .sharding import tree_partition_specs
from .train import TrainState, init_train_state, make_train_step

ARCH_IDS = [
    "zamba2-2.7b", "whisper-tiny", "granite-moe-1b-a400m",
    "deepseek-v3-671b", "mamba2-370m", "minitron-4b", "gemma3-27b",
    "nemotron-4-340b", "granite-20b", "qwen2-vl-2b",
]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def cells(cfg: ArchConfig) -> List[str]:
    """Valid shape cells for this arch (long_500k only sub-quadratic)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, zero allocation)
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, Any]:
    ss = SHAPES[shape]
    B, S = ss.global_batch, ss.seq_len
    if ss.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            batch["audio_embed"] = _sds(
                (B, cfg.n_audio_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["vision_embed"] = _sds(
                (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
        return batch
    # decode: one token + position
    return {"token": _sds((B,), jnp.int32),
            "pos": _sds((), jnp.int32)}


def decode_aux_specs(cfg: ArchConfig, shape: str) -> Optional[Dict]:
    if not cfg.enc_dec:
        return None
    ss = SHAPES[shape]
    B = ss.global_batch
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    Se = cfg.n_audio_frames
    return {
        "enc_states": _sds((B, Se, cfg.d_model), jnp.float32),
        "cross_kv": {
            "k": _sds((cfg.n_layers, B, Hkv, Se, hd), jnp.bfloat16
                      if cfg.dtype == "bfloat16" else jnp.float32),
            "v": _sds((cfg.n_layers, B, Hkv, Se, hd), jnp.bfloat16
                      if cfg.dtype == "bfloat16" else jnp.float32),
        },
    }


# --------------------------------------------------------------------------
# Abstract state trees (params / optimizer / caches) via eval_shape
# --------------------------------------------------------------------------


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(partial(lm.init_params, cfg),
                          jax.random.PRNGKey(0))


def abstract_train_state(cfg: ArchConfig):
    return jax.eval_shape(partial(init_train_state, cfg),
                          jax.random.PRNGKey(0))


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(partial(lm.init_cache, cfg, batch, max_len))


# --------------------------------------------------------------------------
# Sharding specs
# --------------------------------------------------------------------------


def batch_spec(kind: str, with_pod: bool) -> Any:
    data = ("pod", "data") if with_pod else "data"
    if kind == "decode":
        return {"token": P(data), "pos": P()}
    return P(data, None)


def state_specs(cfg: ArchConfig, state_like, with_pod: bool = False,
                n_model: int = 16):
    fsdp = "data" if cfg.fsdp else None
    # Q heads are padded to a tp_pad multiple (clean head sharding);
    # wk/wv stay column-sharded — the activation constraint in
    # attention() gathers the small kv tensor to replicated when the kv
    # heads don't divide the model axis (broadcast-operand format).
    return tree_partition_specs(state_like, model_axis="model",
                                fsdp_axis=fsdp)


def cache_specs(cfg: ArchConfig, cache_like, shape: str,
                with_pod: bool = False, n_model: int = 16):
    """KV caches: batch over data (decode_32k) or sequence over data
    (long_500k, B=1); heads over model only when the nominal kv-head
    count divides the model axis (else replicated — broadcast operand)."""
    from .sharding import _path_str, enforce_divisible
    ss = SHAPES[shape]
    seq_shard = ss.global_batch < 8          # long-context single stream
    kv_model = "model" if (cfg.n_kv_heads
                           and cfg.n_kv_heads % n_model == 0) else None

    def spec_of(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        if "conv" in ps:          # SSM conv state (..., B, K-1, C)
            names = [None] * nd
            names[nd - 3] = "data"
            names[nd - 1] = "model"
            out = P(*names)
        elif "ssd" in ps:         # SSD state (..., B, H, P, N)
            names = [None] * nd
            names[nd - 4] = "data"
            names[nd - 3] = "model"
            out = P(*names)
        elif "latent" in ps:      # MLA latent (..., B, S, w)
            names = [None] * nd
            if seq_shard:
                names[nd - 2] = "data"
            else:
                names[nd - 3] = "data"
            out = P(*names)
        else:                     # KV (..., B, Hkv, S, hd)
            names = [None] * nd
            if seq_shard:
                names[nd - 2] = "data"
            else:
                names[nd - 4] = "data"
            names[nd - 3] = kv_model
            if kv_model is None and not seq_shard:
                # broadcast-operand KV heads: shard the SEQUENCE over
                # `model` instead — decode attention reduces over S, so
                # each shard computes a partial softmax (combined via
                # the log-sum-exp identity by GSPMD); without this the
                # cache is replicated 16x (nemotron-340b: 467 GB/chip)
                names[nd - 2] = "model"
            out = P(*names)
        return enforce_divisible(out, tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(spec_of, cache_like)


# --------------------------------------------------------------------------
# Step builders
# --------------------------------------------------------------------------


@dataclass
class StepBundle:
    fn: Callable
    args: Tuple                   # abstract arg structs, in call order
    in_specs: Tuple
    out_specs: Any
    donate: Tuple = ()


def build_step(cfg: ArchConfig, shape: str,
               with_pod: bool = False, n_micro: int = 1,
               compress: bool = False) -> StepBundle:
    ss = SHAPES[shape]
    if ss.kind == "train":
        from .train import TrainOptions
        opts = TrainOptions(n_micro=n_micro, compress_grads=compress)
        step = make_train_step(cfg, opts=opts)
        state = jax.eval_shape(
            partial(init_train_state, cfg, opts=opts),
            jax.random.PRNGKey(0))
        batch = input_specs(cfg, shape)
        sspec = state_specs(cfg, state, with_pod)
        bspec = jax.tree_util.tree_map(
            lambda _: batch_spec("train", with_pod), batch)
        mspec = {"loss": P(), "grad_norm": P(), "lr_scale": P(),
                 "step": P()}
        return StepBundle(step, (state, batch), (sspec, bspec),
                          (sspec, mspec), donate=(0,))

    if ss.kind == "prefill":

        def prefill_fn(params, batch):
            return lm.prefill(cfg, params, batch)

        params = abstract_params(cfg)
        batch = input_specs(cfg, shape)
        pspec = state_specs(cfg, params, with_pod)
        bspec = jax.tree_util.tree_map(
            lambda _: batch_spec("prefill", with_pod), batch)
        vocab_ok = cfg.vocab % 16 == 0
        out = P(("pod", "data") if with_pod else "data",
                "model" if vocab_ok else None)
        return StepBundle(prefill_fn, (params, batch), (pspec, bspec),
                          out)

    # decode
    aux = decode_aux_specs(cfg, shape)

    def serve_step(params, cache, token, pos, aux_in=None):
        return lm.decode_step(cfg, params, cache, token, pos, aux=aux_in)

    params = abstract_params(cfg)
    cache = abstract_cache(cfg, ss.global_batch, ss.seq_len)
    ins = input_specs(cfg, shape)
    pspec = state_specs(cfg, params, with_pod)
    cspec = cache_specs(cfg, cache, shape, with_pod)
    tok_spec = P("data") if ss.global_batch >= 8 else P()
    vocab_ok = cfg.vocab % 16 == 0
    logits_spec = P("data" if ss.global_batch % 16 == 0 else None,
                    "model" if vocab_ok else None)
    args = [params, cache, ins["token"], ins["pos"]]
    in_specs = [pspec, cspec, tok_spec, P()]
    if aux is not None:
        args.append(aux)
        in_specs.append(jax.tree_util.tree_map(
            lambda _: P(), aux))
    return StepBundle(serve_step, tuple(args), tuple(in_specs),
                      (logits_spec, cspec), donate=(1,))
