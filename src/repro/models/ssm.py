"""Mamba2 block (SSD) — chunked-scan training, O(1)-state decode.

Block layout follows arXiv:2405.21060: a single input projection yields
(z, x, B, C, dt); x/B/C pass through a short causal depthwise conv; the
SSD scan mixes sequence information; a gated RMSNorm and output
projection close the block.  Decode carries (conv_state, ssd_state) —
constant in sequence length, which is why the SSM/hybrid archs run the
500k-token cell.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ArchConfig
from .layers import dense_init, init_rms_norm, rms_norm


class SSMState(NamedTuple):
    conv: jnp.ndarray        # (B, conv_w - 1, d_conv_in)
    ssd: jnp.ndarray         # (B, H, P, N)


def init_ssm(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H           # z, x, B, C, dt
    d_conv_in = di + 2 * N                   # conv over x, B, C
    return {
        "ssm_in": dense_init(ks[0], (d, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, d_conv_in), dtype,
                             scale=0.5),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gnorm": init_rms_norm(di, dtype),
        "ssm_out": dense_init(ks[2], (di, d), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x (B, S, C); w (K, C).  Returns (y, new
    state of the last K-1 inputs)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x, shape=x.shape)
    for i in range(K):
        y = y + xp[:, i:i + x.shape[1], :] * w[i]
    new_state = xp[:, -(K - 1):, :] if K > 1 else xp[:, :0, :]
    return jax.nn.silu(y), new_state


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    return z, xBC, dt


def ssm_block(p: Dict, h: jnp.ndarray, cfg: ArchConfig,
              state: Optional[SSMState] = None
              ) -> Tuple[jnp.ndarray, Optional[SSMState]]:
    """h (B, S, d) full-sequence (state=None) or (B, 1, d) decode."""
    B, S, d = h.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    proj = h @ p["ssm_in"]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                    # (B, S, H)
    A = -jnp.exp(p["A_log"])                                # (H,)

    if state is None:
        xBC, _ = _causal_conv(xBC, p["conv_w"])
        xs = xBC[..., :di].reshape(B, S, H, P)
        Bm = xBC[..., di:di + N]
        Cm = xBC[..., di + N:]
        y, _ = ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                            impl=cfg.kernel_impl)
        y = (y + xs * p["D"][None, None, :, None]).astype(h.dtype)
        y = y.reshape(B, S, di)
        y = rms_norm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
        return (y @ p["ssm_out"]).astype(h.dtype), None

    # ---- decode step ----
    xBC_t, conv_state = _causal_conv(xBC, p["conv_w"], state.conv)
    xs = xBC_t[:, 0, :di].reshape(B, H, P)
    Bm = xBC_t[:, 0, di:di + N]
    Cm = xBC_t[:, 0, di + N:]
    y, ssd_state = ops.ssd_step(state.ssd, xs, dt[:, 0], A, Bm, Cm)
    y = (y + xs * p["D"][None, :, None]).astype(h.dtype)
    y = y.reshape(B, 1, di)
    y = rms_norm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    return (y @ p["ssm_out"]).astype(h.dtype), \
        SSMState(conv_state.astype(state.conv.dtype),
                 ssd_state.astype(state.ssd.dtype))


def init_ssm_state(cfg: ArchConfig, batch: int, dtype) -> SSMState:
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    d_conv_in = di + 2 * N
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_conv_in), dtype),
        ssd=jnp.zeros((batch, H, P, N), dtype),
    )
