"""Fault-tolerant checkpointing: atomic, process-sharded, async.

Layout:
    <dir>/step_<N>/shard_<host>.npz     flattened param/opt arrays
    <dir>/step_<N>/MANIFEST.json        step, tree structure, shard count,
                                        per-shard checksums  (written LAST)

The manifest is the commit record — a step directory without a valid
manifest is garbage-collected on restore, so a job killed mid-save can
always restart from the last complete step (restart-safety for node
failures).  ``save_async`` snapshots to host memory synchronously (cheap)
and writes on a daemon thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any, List[str]]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [f"leaf_{i}" for i in range(len(leaves))]
    return [np.asarray(x) for x in leaves], treedef, paths


class CheckpointManager:
    def __init__(self, directory: str, host_id: int = 0, n_hosts: int = 1,
                 keep: int = 3):
        self.dir = directory
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None
             ) -> str:
        leaves, _, _ = _flatten(tree)
        # numpy can't natively round-trip ml_dtypes (bfloat16 comes back
        # as void16) — store a byte view + the true dtype in the manifest
        dtypes = [str(a.dtype) for a in leaves]
        stored = [a.view(np.uint8) if a.dtype.kind not in "biufc"
                  or str(a.dtype) == "bfloat16" else a for a in leaves]
        sd = self._step_dir(step)
        tmp = sd + f".tmp{self.host_id}"
        os.makedirs(tmp, exist_ok=True)
        shard_path = os.path.join(tmp, f"shard_{self.host_id}.npz")
        np.savez(shard_path, **{f"leaf_{i}": a
                                for i, a in enumerate(stored)})
        digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "n_hosts": self.n_hosts,
            "checksums": {str(self.host_id): digest},
            "dtypes": dtypes,
            "time": time.time(),
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        # atomic commit: rename tmp -> final (last writer wins per host)
        if os.path.isdir(sd):
            shutil.rmtree(sd)
        os.replace(tmp, sd)
        self._gc()
        return sd

    def save_async(self, step: int, tree: Any,
                   meta: Optional[Dict] = None) -> None:
        # snapshot to host memory now; write later
        leaves = [np.array(x) for x in jax.tree_util.tree_leaves(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        snap = jax.tree_util.tree_unflatten(treedef, leaves)
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, snap, meta), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def _valid_steps(self) -> List[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            if not d.startswith("step_") or d.endswith(tuple(
                    f".tmp{i}" for i in range(64))):
                continue
            man = os.path.join(self.dir, d, "MANIFEST.json")
            shard = os.path.join(self.dir, d, f"shard_{self.host_id}.npz")
            if os.path.isfile(man) and os.path.isfile(shard):
                try:
                    with open(man) as f:
                        m = json.load(f)
                    out.append(int(m["step"]))
                except Exception:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._valid_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None
                ) -> Tuple[Any, int, Dict]:
        """Restore into the structure of `tree_like` (arrays or shape
        structs).  Returns (tree, step, meta).  Verifies the checksum —
        a corrupt shard falls back to the previous valid step."""
        steps = self._valid_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        for s in reversed(steps):
            sd = self._step_dir(s)
            try:
                with open(os.path.join(sd, "MANIFEST.json")) as f:
                    man = json.load(f)
                shard_path = os.path.join(sd, f"shard_{self.host_id}.npz")
                blob = open(shard_path, "rb").read()
                want = man["checksums"].get(str(self.host_id))
                if want and hashlib.sha256(blob).hexdigest() != want:
                    raise IOError(f"checksum mismatch at step {s}")
                data = np.load(shard_path)
                leaves, treedef = jax.tree_util.tree_flatten(tree_like)
                assert len(leaves) == man["n_leaves"], \
                    (len(leaves), man["n_leaves"])
                dtypes = man.get("dtypes")
                new_leaves = []
                for i in range(len(leaves)):
                    a = data[f"leaf_{i}"]
                    if dtypes and str(a.dtype) != dtypes[i]:
                        import ml_dtypes  # noqa: F401 (registers dtypes)
                        a = a.view(np.dtype(dtypes[i]))
                    new_leaves.append(a)
                tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
                return tree, s, man.get("meta", {})
            except Exception:
                continue
        raise FileNotFoundError(f"no valid checkpoint in {self.dir}")

    def _gc(self) -> None:
        steps = self._valid_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
