"""Process-level fault isolation for the serving runtime.

:class:`ProcPool` is :class:`~repro.runtime.serving.ServerPool` with the
execution fault domain moved out of the parent: each worker id owns a
real OS *process* that opens every registered model's ``.rpa`` artifact
with ``mmap=True`` (weights map copy-on-write out of the page cache —
one physical copy shared by all workers, zero-copy), lowers its own
``ExecPlan`` arena, and serves batches over a length-prefixed pipe
protocol.  A segfault-class fault, an OOM kill or a runaway kernel in
one worker leaves every other worker — and the parent — serving.

Wire protocol (parent <-> child, one duplex pipe per worker)
------------------------------------------------------------

Every message is one *frame*::

    b"rpa2" | u32 header_len | u32 crc32 | header JSON | raw blobs

where ``crc32`` covers everything after itself (header + blobs).  The
pipe transport is length-prefixed, so a flipped bit in transit can
never desynchronize framing — it corrupts one frame's *payload*.  The
CRC turns that into a typed, attributable fault:
:func:`unpack_frame` raises :class:`~repro.runtime.serving.
FrameCorrupt` carrying the frame's header (headers that still parse
identify the pending request), the reader fails *only that batch*, and
the executor re-dispatches it to a healthy worker.  Only a frame whose
header is itself unreadable degrades to :class:`ProtocolError` and a
worker recycle.

The header carries the frame type plus an ``arrays`` manifest
(name/dtype/shape per blob, in blob order); request frames thread the
batch's ticket **trace ids** through so child-side spans attribute to
the originating requests.  Frame types:

== =========================================================
``ready``  child finished loading its models (pid, model list)
``hb``     child idle heartbeat (the *only* idle liveness signal)
``run``    parent -> child: one stacked batch (+ trace ids)
``res``    child -> parent: stacked outputs for a ``run``
``err``    child -> parent: typed execution error for a ``run``
``load``   parent -> child: register one more model artifact
``crash``  parent -> child: die *now* (chaos trampoline: segv/oom)
``spans``  round-trip: child exports its tracer ring for merging
``close``  parent -> child: drain and exit; child answers ``bye``
== =========================================================

Crash-fault supervision
-----------------------

The parent extends the pool's heartbeat supervision with *real* process
liveness: a worker is dead when its pipe EOFs or its exitcode is set
(``_extra_dead_locked``), not only when beats go stale — and idle beats
come exclusively from child ``hb`` frames (``_idle_beat`` is a no-op
here), so a hung-but-alive child goes heartbeat-stale even while the
parent-side dispatcher thread is healthy.  On death the dispatcher's
in-flight ``remote_run`` fails with :class:`~repro.runtime.serving.
WorkerCrashed`; the executor re-dispatches the batch to the survivors
(never failing tickets — first-fulfillment-wins settles duplicates) and
the supervisor respawns a replacement process *off the request path* (a
launcher thread; dispatch gates on ``_worker_ready`` until the child
reports ready).  Zero ticket loss under worker murder is pinned by
``tests/test_robust.py`` and the ``proc_kill`` scenario of
``benchmarks/robust_bench.py``.
"""
from __future__ import annotations

import itertools
import json
import multiprocessing as mp
import os
import signal
import struct
import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..obs import trace as _trace
from . import chaos as _chaos
from .serving import FrameCorrupt, ServerPool, ServingError, WorkerCrashed

FRAME_MAGIC = b"rpa2"
_U32 = struct.Struct("<I")
#: magic(4) | header_len u32 | crc32 u32
_HDR_OFF = 12


class ProtocolError(ServingError):
    """A pipe frame failed to parse (bad magic / truncated / unreadable
    header): the endpoints have desynchronized and the worker must be
    recycled.  A frame that *parses* but fails its CRC raises
    :class:`~repro.runtime.serving.FrameCorrupt` instead — an
    attributable single-batch fault, not a stream fault."""


def _frame_shell(header: dict, metas: List[dict],
                 payload: int) -> Tuple[bytearray, int]:
    """Allocate a frame buffer with magic + JSON header written; returns
    ``(frame, offset_of_first_blob)``.  The CRC field is zero until
    :func:`_seal_frame` stamps it (after the blobs are written)."""
    h = dict(header)
    if metas:
        h["arrays"] = metas
    hb = json.dumps(h, separators=(",", ":")).encode()
    frame = bytearray(_HDR_OFF + len(hb) + payload)
    frame[0:4] = FRAME_MAGIC
    _U32.pack_into(frame, 4, len(hb))
    frame[_HDR_OFF:_HDR_OFF + len(hb)] = hb
    return frame, _HDR_OFF + len(hb)


def _seal_frame(frame: bytearray) -> bytearray:
    """Stamp the frame's CRC32 over header + blobs (everything after
    the CRC field itself)."""
    crc = zlib.crc32(memoryview(frame)[_HDR_OFF:]) & 0xFFFFFFFF
    _U32.pack_into(frame, 8, crc)
    return frame


def pack_frame(header: dict,
               arrays: Optional[Dict[str, np.ndarray]] = None
               ) -> bytearray:
    """Serialize one frame: magic, u32 length-prefixed JSON header,
    then each array's raw bytes (C-contiguous) in manifest order —
    written straight into one preallocated buffer (per-array
    ``tobytes`` + join would copy every payload twice; the saturated
    1-core serving path feels that)."""
    metas: List[dict] = []
    blobs: List[np.ndarray] = []
    total = 0
    for name, arr in (arrays or {}).items():
        a = np.asarray(arr)
        if a.ndim and not a.flags.c_contiguous:
            a = np.ascontiguousarray(a)   # would promote 0-d to (1,)
        metas.append({"name": name, "dtype": str(a.dtype),
                      "shape": list(a.shape)})
        blobs.append(a)
        total += a.nbytes
    frame, off = _frame_shell(header, metas, total)
    mv = memoryview(frame)
    for a in blobs:
        n = a.nbytes
        if n:
            mv[off:off + n] = a.data.cast("B") if a.ndim else a.tobytes()
        off += n
    return _seal_frame(frame)


def pack_run_frame(header: dict, feeds: List[Dict[str, np.ndarray]]
                   ) -> bytearray:
    """Serialize a batch of per-request feeds as one stacked run frame,
    stacking each input *directly into the wire buffer* (a separate
    ``np.stack`` + ``pack_frame`` pass would copy the batch three
    times).  The child unpacks it as ordinary stacked arrays."""
    keys = list(feeds[0])
    metas: List[dict] = []
    rows: Dict[str, List[np.ndarray]] = {}
    total = 0
    for k in keys:
        rs = []
        for f in feeds:
            a = np.asarray(f[k])
            if a.ndim and not a.flags.c_contiguous:
                a = np.ascontiguousarray(a)
            rs.append(a)
        rows[k] = rs
        metas.append({"name": k, "dtype": str(rs[0].dtype),
                      "shape": [len(rs)] + list(rs[0].shape)})
        total += rs[0].nbytes * len(rs)
    frame, off = _frame_shell(header, metas, total)
    for k in keys:
        for r in rows[k]:
            n = r.nbytes
            if n:
                stacked = np.frombuffer(frame, r.dtype.base, r.size, off)
                np.copyto(stacked, r.reshape(-1), casting="no")
            off += n
    return _seal_frame(frame)


def unpack_frame(buf: bytes, copy: bool = True
                 ) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Parse one frame back into (header, arrays); raises
    :class:`ProtocolError` on any structural mismatch.

    ``copy=False`` returns read-only views into ``buf`` (the views keep
    it alive) — right for the parent's result path, where rows are
    sliced per ticket anyway; the child copies so kernels get aligned,
    writable activations.

    Integrity: the frame's CRC32 is verified first.  A mismatch raises
    :class:`~repro.runtime.serving.FrameCorrupt` carrying the parsed
    header when the corruption spared it (the caller fails just that
    frame's batch); only an unreadable header — framing itself
    untrustworthy — raises :class:`ProtocolError`."""
    mv = memoryview(buf)
    if len(mv) < _HDR_OFF or bytes(mv[:4]) != FRAME_MAGIC:
        raise ProtocolError("bad frame magic")
    (hlen,) = _U32.unpack_from(mv, 4)
    (want_crc,) = _U32.unpack_from(mv, 8)
    if _HDR_OFF + hlen > len(mv):
        raise ProtocolError(f"truncated header ({hlen} declared, "
                            f"{len(mv) - _HDR_OFF} available)")
    crc_ok = (zlib.crc32(mv[_HDR_OFF:]) & 0xFFFFFFFF) == want_crc
    try:
        header = json.loads(bytes(mv[_HDR_OFF:_HDR_OFF + hlen]).decode())
    except ValueError as e:
        if not crc_ok:
            raise ProtocolError(
                "corrupt frame with unreadable header (crc mismatch)"
            ) from None
        raise ProtocolError(f"unparseable header: {e}") from None
    if not crc_ok:
        raise FrameCorrupt(
            detail=f"crc mismatch on {header.get('type')!r} frame",
            header=header)
    off = _HDR_OFF + hlen
    arrays: Dict[str, np.ndarray] = {}
    for m in header.pop("arrays", ()):
        dt = np.dtype(m["dtype"])
        shape = tuple(int(s) for s in m["shape"])
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        if off + n > len(mv):
            raise ProtocolError(f"truncated blob {m['name']!r}")
        arr = np.frombuffer(mv[off:off + n], dtype=dt).reshape(shape)
        arrays[m["name"]] = arr.copy() if copy else arr
        off += n
    if off != len(mv):
        raise ProtocolError(f"{len(mv) - off} trailing bytes")
    return header, arrays


# --------------------------------------------------------------------------
# Child process
# --------------------------------------------------------------------------


def _worker_main(conn, wid: int, model_paths: Dict[str, str],
                 hb_every: float, trace_capacity: int) -> None:
    """Worker process entry: mmap-load the artifacts, report ready,
    then serve ``run`` frames until ``close`` (heartbeating while
    idle — a batch in progress is *silent*, which is exactly the
    staleness signature the parent supervises)."""
    from repro.api.compiled import CompiledModel

    tracer = _trace.enable(capacity=trace_capacity) \
        if trace_capacity else None
    models: Dict[str, object] = {}
    load_errors: Dict[str, str] = {}

    def _load(name: str, path: str) -> None:
        try:
            models[name] = CompiledModel.load(path, mmap=True)
            load_errors.pop(name, None)
        except Exception as e:
            load_errors[name] = f"{type(e).__name__}: {e}"

    for name, path in model_paths.items():
        _load(name, path)
    conn.send_bytes(pack_frame({
        "type": "ready", "wid": wid, "pid": os.getpid(),
        "models": sorted(models), "errors": dict(load_errors)}))

    seq = 0
    while True:
        try:
            if not conn.poll(hb_every):
                conn.send_bytes(pack_frame({"type": "hb", "seq": seq}))
                continue
            buf = conn.recv_bytes()
        except (EOFError, OSError):
            return
        try:
            header, arrays = unpack_frame(buf)
        except FrameCorrupt as e:
            # a run frame arrived with flipped payload bits: refuse to
            # execute untrusted inputs, answer a typed error so the
            # parent fails (and re-dispatches) only this batch
            req = (e.header or {}).get("req")
            if req is not None:
                conn.send_bytes(pack_frame(
                    {"type": "err", "req": req,
                     "cls": "FrameCorrupt", "msg": str(e)}))
            continue
        kind = header.get("type")
        if kind == "close":
            try:
                conn.send_bytes(pack_frame({"type": "bye"}))
            except (BrokenPipeError, OSError):
                pass
            return
        if kind == "crash":
            # chaos trampoline: die the way real faults do, not via a
            # Python exception the frame loop could catch
            mode = header.get("mode", "oom")
            if mode == "segv":
                signal.signal(signal.SIGSEGV, signal.SIG_DFL)
                os.kill(os.getpid(), signal.SIGSEGV)
            os._exit(137)          # OOM-killed exit status
        if kind == "load":
            _load(header["model"], header["path"])
            conn.send_bytes(pack_frame(
                {"type": "loaded", "model": header["model"],
                 "error": load_errors.get(header["model"])}))
            continue
        if kind == "spans":
            doc = tracer.chrome_trace() if tracer is not None \
                else {"traceEvents": []}
            conn.send_bytes(pack_frame(
                {"type": "spans", "req": header["req"],
                 "epoch": tracer.epoch if tracer is not None else 0.0,
                 "pid": os.getpid(), "doc": doc}))
            continue
        if kind != "run":
            continue               # unknown frame: ignore, stay alive
        req = header["req"]
        name = header["model"]
        n = int(header["n"])
        ids = header.get("trace_ids") or []
        seq += 1
        t0 = time.monotonic()
        try:
            model = models.get(name)
            if model is None:
                raise RuntimeError(
                    f"worker {wid}: model {name!r} unavailable"
                    + (f" ({load_errors[name]})"
                       if name in load_errors else ""))
            out = model._run_plan_batch(arrays, n)
            if tracer is not None:
                tracer.complete(
                    "proc_batch", "serving", t0,
                    trace_id=(ids[0] if ids else None),
                    args={"model": name, "n": n, "worker": wid,
                          "trace_ids": ids})
            conn.send_bytes(pack_frame(
                {"type": "res", "req": req, "seq": seq}, out))
        except Exception as e:
            conn.send_bytes(pack_frame(
                {"type": "err", "req": req, "seq": seq,
                 "cls": type(e).__name__, "msg": str(e)}))


def _rebuild_error(cls: str, msg: str) -> Exception:
    """Reconstruct a child-side execution error as the closest typed
    parent-side error (the session's retry/breaker ladder discriminates
    on type: client errors are never retried, ``PlanError`` counts
    against the breaker)."""
    from repro.core.execplan import PlanError
    if cls == "FrameCorrupt":      # child refused a corrupt run frame
        return FrameCorrupt(detail=msg)
    table = {"PlanError": PlanError, "ValueError": ValueError,
             "TypeError": TypeError, "KeyError": KeyError,
             "RuntimeError": RuntimeError,
             "ChaosError": _chaos.ChaosError,
             "TransientChaosError": _chaos.TransientChaosError}
    return table.get(cls, ServingError)(msg)


# --------------------------------------------------------------------------
# Parent side
# --------------------------------------------------------------------------


class _Proc:
    """Parent-side handle for one worker process."""

    __slots__ = ("wid", "proc", "conn", "reader", "ready", "dead",
                 "exitcode", "pid", "send_lock", "models", "detail",
                 "lanes")

    def __init__(self, wid: int):
        self.wid = wid
        #: dispatch lanes (ServerPool worker ids) feeding this process
        self.lanes = {wid}
        self.proc = None
        self.conn = None
        self.reader: Optional[threading.Thread] = None
        self.ready = threading.Event()
        self.dead = False
        self.exitcode: Optional[int] = None
        self.pid: Optional[int] = None
        self.send_lock = threading.Lock()
        self.models: set = set()
        self.detail = ""

    def send(self, frame: bytes) -> None:
        conn = self.conn
        if conn is None or self.dead:
            raise WorkerCrashed(self.wid, self.detail or "process gone")
        with self.send_lock:
            conn.send_bytes(frame)


class ProcPool(ServerPool):
    """:class:`ServerPool` whose workers are separate OS processes.

    Dispatch, admission control, EDF/priority scheduling, heartbeat
    supervision and recycling are all inherited — this subclass swaps
    the execution transport (``remote_run`` over the pipe protocol) and
    the liveness sources (child ``hb`` frames + exitcodes)."""

    mode = "process"

    def __init__(self, execute, *,
                 model_paths: Optional[Dict[str, str]] = None,
                 child_trace_capacity: int = 65536,
                 lanes_per_proc: int = 2, **kw):
        # subclass state first: the base __init__ spawns workers, which
        # calls straight back into our overridden _spawn_locked
        self._ctx = mp.get_context("spawn")
        self._plock = threading.RLock()
        self._procs: Dict[int, _Proc] = {}
        self._model_paths: Dict[str, str] = dict(model_paths or {})
        self._pending: Dict[int, tuple] = {}
        self._req_ids = itertools.count(1)
        self._boot_failures = 0    # consecutive died-before-ready spawns
        self._child_trace_capacity = int(child_trace_capacity) \
            if _trace.active() is not None else 0
        #: dispatch lanes per child process.  One lane ping-pongs with
        #: the child (send batch -> wait -> claim next), leaving the
        #: child idle for the whole parent-side turnaround every batch;
        #: a second lane keeps the pipe primed with the next batch so a
        #: saturated child never waits on the parent (the fault-free
        #: process-pool throughput gate in benchmarks.robust_bench).
        self._lanes = max(1, int(lanes_per_proc))
        #: lane wid -> its process (many lanes share one _Proc)
        self._lane_proc: Dict[int, _Proc] = {}
        kw["workers"] = int(kw.get("workers", 2)) * self._lanes
        super().__init__(execute, **kw)

    # -- model registry ----------------------------------------------------
    def register_model(self, name: str, path: str) -> None:
        """Hand one model's artifact to every worker (and to all future
        spawns).  Children mmap it copy-on-write; the pipe is ordered,
        so a batch submitted after this call never races the load."""
        with self._plock:
            self._model_paths[name] = path
            procs = [p for p in self._procs.values()
                     if p.conn is not None and not p.dead]
        for p in procs:
            try:
                p.send(pack_frame({"type": "load", "model": name,
                                   "path": path}))
            except (WorkerCrashed, BrokenPipeError, OSError):
                pass               # dying worker: its replacement spawns
                                   # with the updated path snapshot

    # -- spawning (off the request path) -----------------------------------
    def _spawn_locked(self, wid: int) -> None:
        with self._plock:
            p = next((q for q in self._procs.values()
                      if not q.dead and len(q.lanes) < self._lanes),
                     None)
            if p is not None:
                # share an existing child process: a second dispatch
                # lane keeps its pipe primed with the next batch
                p.lanes.add(wid)
                self._lane_proc[wid] = p
            else:
                p = _Proc(wid)
                self._procs[wid] = p
                self._lane_proc[wid] = p
                threading.Thread(target=self._launch, args=(wid, p),
                                 name=f"npu-proc-launch-{wid}",
                                 daemon=True).start()
        super()._spawn_locked(wid)

    def _launch(self, wid: int, p: _Proc) -> None:
        """Launcher thread: process spawn + artifact load take ~1s —
        never on a dispatcher thread (dispatch gates on
        ``_worker_ready`` and the supervisor beats booting workers)."""
        boots = self._boot_failures
        if boots:                  # crash-loop backoff: a child that dies
            time.sleep(min(0.05 * (2 ** min(boots, 6)), 2.0))
        try:                       # before ready must not spin respawns
            with self._plock:
                paths = dict(self._model_paths)
                parent_conn, child_conn = self._ctx.Pipe(duplex=True)
                p.conn = parent_conn
            proc = self._ctx.Process(
                target=_worker_main,
                args=(child_conn, wid, paths,
                      max(0.01, self.heartbeat_timeout_s / 4),
                      self._child_trace_capacity),
                name=f"npu-proc-{wid}", daemon=True)
            proc.start()
            child_conn.close()
            p.proc = proc
            p.reader = threading.Thread(
                target=self._reader, args=(wid, p),
                name=f"npu-proc-reader-{wid}", daemon=True)
            p.reader.start()
        except Exception as e:     # spawn failed: supervisor recycles
            p.detail = repr(e)
            self._mark_dead(p)

    # -- per-process reader thread -----------------------------------------
    def _reader(self, wid: int, p: _Proc) -> None:
        """Demux one child's frames: heartbeats feed the FaultMonitor,
        replies wake their pending ``remote_run``, EOF marks death."""
        conn = p.conn
        while True:
            try:
                buf = conn.recv_bytes()
                c = _chaos.active()
                if c is not None:
                    buf = c.maybe_flip_frame(buf)
                header, arrays = unpack_frame(buf, copy=False)
            except (EOFError, OSError):
                break
            except FrameCorrupt as e:
                # payload integrity fault, framing intact: fail only
                # the pending batch this frame answered (the executor
                # re-dispatches it to a healthy worker) and keep
                # reading — the stream is NOT poisoned
                req = (e.header or {}).get("req")
                with self._plock:
                    slot = self._pending.pop(req, None) \
                        if req is not None else None
                if slot is not None:
                    ev, box = slot[0], slot[1]
                    box["corrupt"] = str(e)
                    ev.set()
                    _trace.instant("frame_corrupt", "fault",
                                   args={"worker": wid, "req": req})
                    continue
                p.detail = str(e)  # unattributable: recycle the worker
                break
            except ProtocolError as e:
                p.detail = str(e)  # desynchronized: recycle the worker
                break
            kind = header.get("type")
            if kind == "hb":
                seq = int(header.get("seq", 0))
                for lane in tuple(p.lanes):
                    self.monitor.beat(lane, seq)
            elif kind == "ready":
                p.pid = header.get("pid")
                p.models = set(header.get("models", ()))
                if header.get("errors"):
                    p.detail = "; ".join(
                        f"{n}: {e}"
                        for n, e in header["errors"].items())
                p.ready.set()
                self._boot_failures = 0
                for lane in tuple(p.lanes):
                    self.monitor.beat(lane, 0)
                _trace.instant("proc_ready", "fault",
                               args={"worker": wid, "pid": p.pid})
                with self._cv:
                    self._cv.notify_all()
            elif kind in ("res", "err", "spans"):
                # any reply is liveness evidence: a saturated child is
                # never idle long enough to emit hb frames
                for lane in tuple(p.lanes):
                    self.monitor.beat(lane, int(header.get("req", 0)))
                with self._plock:
                    slot = self._pending.pop(header["req"], None)
                if slot is not None:
                    ev, box = slot[0], slot[1]
                    if kind == "res":
                        box["out"] = arrays
                    elif kind == "err":
                        box["err"] = (header.get("cls", ""),
                                      header.get("msg", ""))
                    else:
                        box["spans"] = (float(header.get("epoch", 0.0)),
                                        header.get("doc") or
                                        {"traceEvents": []})
                    ev.set()
            elif kind == "bye":
                break
            # "loaded" acks and unknown frames: nothing to do
        self._mark_dead(p)

    def _mark_dead(self, p: _Proc) -> None:
        if p.dead:
            return
        p.dead = True
        if not p.ready.is_set():
            self._boot_failures += 1
        if p.proc is not None:
            p.proc.join(timeout=0.5)
            p.exitcode = p.proc.exitcode
        with self._plock:
            stale = [k for k, s in self._pending.items() if s[2] is p]
            slots = [self._pending.pop(k) for k in stale]
        for ev, box, _ in slots:
            box["crash"] = True
            ev.set()
        _trace.instant("proc_dead", "fault",
                       args={"worker": p.wid, "pid": p.pid,
                             "exitcode": p.exitcode})
        with self._cv:
            self._cv.notify_all()

    # -- remote execution ---------------------------------------------------
    def remote_run(self, wid: int, name: str, feeds: List[dict],
                   trace_ids: Optional[List[int]] = None) -> List[dict]:
        """Stack ``feeds``, ship them to worker ``wid``'s process, and
        unstack the reply.  Raises :class:`WorkerCrashed` if the process
        dies with the batch in flight (the executor re-dispatches) and
        rebuilds typed child-side errors otherwise."""
        p = self._lane_proc.get(wid)
        if p is None or p.dead or not p.ready.is_set():
            raise WorkerCrashed(wid, (p.detail if p else "")
                                or "no live process")
        c = _chaos.active()
        kill_mode = c.maybe_kill(wid) if c is not None else None
        req = next(self._req_ids)
        ev = threading.Event()
        box: dict = {}
        with self._plock:
            if p.dead:
                raise WorkerCrashed(wid, p.detail or "process died")
            self._pending[req] = (ev, box, p)
        try:
            if kill_mode in ("segv", "oom"):
                # crash trampoline: the child dies on this frame, the
                # run frame behind it is lost in the pipe — a faithful
                # mid-flight crash
                p.send(pack_frame({"type": "crash", "mode": kill_mode}))
            elif kill_mode == "kill":
                # SIGKILL with the batch claimed and in flight: no
                # goodbye frame, the parent only ever sees pipe EOF
                if p.proc is not None:
                    p.proc.kill()
                    p.proc.join(0.1)
            p.send(pack_run_frame(
                {"type": "run", "req": req, "model": name,
                 "n": len(feeds), "trace_ids": list(trace_ids or ())},
                feeds))
        except (WorkerCrashed, BrokenPipeError, OSError) as e:
            with self._plock:
                self._pending.pop(req, None)
            # a failed send is definitive: mark the worker dead *now* so
            # its dispatcher thread stops claiming (waiting for the
            # reader's EOF would let it crash-loop through the queue)
            self._mark_dead(p)
            raise WorkerCrashed(wid, p.detail or repr(e)) from None
        # the reader sets ``ev`` on every outcome — result, child error,
        # pipe EOF (_mark_dead) and pool close (close kills the child,
        # EOF follows).  The long-timeout re-check is pure paranoia; a
        # short poll here costs real throughput (each timeout wake
        # contends the global pool lock, ~10 extra wakeups per batch
        # across the lanes on a saturated 1-core box)
        while not ev.wait(1.0):
            if p.dead or box:
                break
            if not self._running:
                with self._plock:
                    self._pending.pop(req, None)
                raise WorkerCrashed(wid, "pool closed")
        if "out" in box:
            out = box["out"]
            return [{k: v[i] for k, v in out.items()}
                    for i in range(len(feeds))]
        if "corrupt" in box:
            raise FrameCorrupt(wid, box["corrupt"])
        if "err" in box:
            err = _rebuild_error(*box["err"])
            if isinstance(err, FrameCorrupt):
                err.worker = wid   # attribute the child-side refusal
            raise err
        raise WorkerCrashed(
            wid, p.detail or (f"exitcode {p.exitcode}"
                              if p.exitcode is not None else "pipe EOF"))

    # -- ServerPool hooks ---------------------------------------------------
    def _worker_ready(self, wid: int) -> bool:
        p = self._lane_proc.get(wid)
        return (p is not None and p.ready.is_set() and not p.dead)

    def _idle_beat(self, wid: int, seq: int) -> None:
        """No parent-side idle beats: the child's ``hb`` frames are the
        only idle liveness signal, so a hung child goes stale even
        while its dispatcher thread spins healthily."""

    def _extra_dead_locked(self) -> List[int]:
        dead = []
        for wid, p in list(self._lane_proc.items()):
            if p.dead:
                dead.append(wid)
            elif p.proc is not None and p.proc.exitcode is not None:
                dead.append(wid)
        return dead

    def _on_recycle_locked(self, wid: int) -> None:
        p = self._lane_proc.pop(wid, None)
        if p is None:
            return
        p.lanes.discard(wid)
        try:
            if p.proc is not None and p.proc.is_alive():
                p.proc.kill()
        except Exception:
            pass
        try:
            if p.conn is not None:
                p.conn.close()     # reader EOFs -> _mark_dead -> pending
        except Exception:          # remote_runs fail with WorkerCrashed
            pass

    def _on_close(self) -> None:
        procs = list(self._procs.values())
        for p in procs:
            if p.dead or p.conn is None:
                continue
            try:
                p.send(pack_frame({"type": "close"}))
            except (WorkerCrashed, BrokenPipeError, OSError):
                pass
        deadline = time.monotonic() + 1.0
        for p in procs:
            if p.proc is None:
                continue
            p.proc.join(max(0.0, deadline - time.monotonic()))
            if p.proc.is_alive():
                p.proc.kill()
                p.proc.join(0.5)
            if p.exitcode is None:
                p.exitcode = p.proc.exitcode

    # -- observability ------------------------------------------------------
    def collect_child_traces(self, timeout: float = 2.0
                             ) -> List[Tuple[float, dict]]:
        """Pull every live child's tracer ring: a list of
        ``(child_epoch, chrome_trace_doc)`` pairs ready for
        :func:`repro.obs.trace.merge_chrome_traces`."""
        out: List[Tuple[float, dict]] = []
        for wid, p in sorted(self._procs.items()):
            if p.dead or not p.ready.is_set():
                continue
            req = next(self._req_ids)
            ev = threading.Event()
            box: dict = {}
            with self._plock:
                self._pending[req] = (ev, box, p)
            try:
                p.send(pack_frame({"type": "spans", "req": req}))
            except (WorkerCrashed, BrokenPipeError, OSError):
                with self._plock:
                    self._pending.pop(req, None)
                continue
            if ev.wait(timeout) and "spans" in box:
                out.append(box["spans"])
            else:
                with self._plock:
                    self._pending.pop(req, None)
        return out

    def worker_health(self) -> Dict[int, Dict[str, object]]:
        out = super().worker_health()
        for wid, h in out.items():
            p = self._lane_proc.get(wid)
            if p is None:
                continue
            h["pid"] = p.pid
            h["ready"] = p.ready.is_set()
            h["exitcode"] = p.exitcode if p.exitcode is not None else (
                p.proc.exitcode if p.proc is not None else None)
        return out
