"""Fault-tolerant serving runtime: deadlines, backpressure, worker pool.

The paper's thesis — sustained utilization under *real* workloads, not
peak TOPS — extends to the serving layer: real traffic is bursty, real
workers hang, real plans go bad.  This module is the robustness layer
around :class:`repro.api.Session`:

* **typed request outcomes** — every submitted :class:`Ticket`
  terminates with a result or a typed error (:class:`Overloaded` with a
  retry-after hint when admission control sheds load,
  :class:`DeadlineExceeded` when a ticket expires before execution,
  :class:`FlushError` aggregating per-model batch failures).  Nothing
  is ever silently dropped.
* **:class:`ServerPool`** — N worker threads, each owning its *own*
  lowered-plan arena (``CompiledModel.plan_for(owner=worker)``), fed by
  bounded per-model queues with a deadline-driven auto-flush: a batch
  dispatches when it fills, when its oldest entry has lingered
  ``linger_ms``, or when its earliest deadline minus the model's
  recent batch time comes due — latency-bounded, not cooperative.
* **fault detection + re-dispatch** — workers heartbeat a
  :class:`repro.runtime.fault.FaultMonitor`; a supervisor recycles
  workers whose beats stop (hung kernel), re-dispatches their in-flight
  batch to a healthy worker (recorded on a
  :class:`~repro.runtime.fault.BackupDispatcher`), and issues
  speculative backups for stragglers.  Tickets are idempotent — the
  first fulfillment wins, duplicated work is dropped.
* **:class:`CircuitBreaker`** + :class:`LatencyHistogram` — the
  per-model trip/half-open/recover state machine and the p50/p99
  surface ``Session.stats()`` reports.

Fault injection for all of the above lives in
:mod:`repro.runtime.chaos`; the open-loop traffic harness in
``benchmarks/robust_bench.py``.
"""
from __future__ import annotations

import heapq
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from ..obs import trace as _trace
from ..obs.metrics import LogHistogram, MetricsRegistry
from .fault import BackupDispatcher, FaultMonitor
from . import chaos as _chaos


# --------------------------------------------------------------------------
# Typed errors
# --------------------------------------------------------------------------


class ServingError(RuntimeError):
    """Base class of the serving runtime's typed request errors."""


class Overloaded(ServingError):
    """Admission control shed this request: the model's bounded queue
    is full.  ``retry_after_ms`` estimates when capacity frees up."""

    def __init__(self, model: str, depth: int, retry_after_ms: float):
        self.model = model
        self.queue_depth = depth
        self.retry_after_ms = float(retry_after_ms)
        super().__init__(
            f"{model}: queue full ({depth} queued) — retry in "
            f"~{self.retry_after_ms:.0f} ms")


class DeadlineExceeded(ServingError):
    """The ticket's deadline passed before its batch executed; the
    stale work was dropped instead of run."""

    def __init__(self, model: str, late_ms: float = 0.0):
        self.model = model
        self.late_ms = float(late_ms)
        super().__init__(f"{model}: deadline exceeded "
                         f"({self.late_ms:.1f} ms late)")


class WorkerLost(ServingError):
    """The session shut down (or a worker died unrecoverably) with this
    request still queued — the terminal error of a drained ticket."""


class WorkerCrashed(ServingError):
    """A worker *process* died (SIGKILL/SIGSEGV/OOM) with this batch in
    flight.  Never a terminal ticket error: the executor catches it and
    re-dispatches the batch to a surviving worker (first-fulfillment-wins
    tickets settle any duplicated work)."""

    def __init__(self, worker: int, detail: str = ""):
        self.worker = int(worker)
        super().__init__(f"worker {worker} crashed"
                         + (f": {detail}" if detail else ""))


class Cancelled(ServingError):
    """The caller cancelled this ticket (:meth:`Ticket.cancel`) before
    it produced a result.  Settlement is first-wins: a cancel that
    races the real result loses cleanly (``cancel()`` returns False and
    ``result()`` returns the value)."""

    def __init__(self, model: str):
        self.model = model
        super().__init__(f"{model}: request cancelled")


class FrameCorrupt(ServingError):
    """A process-pool pipe frame failed its CRC32 integrity check.
    Message boundaries survive corruption (the pipe transport is
    length-prefixed), so this is a *payload* fault, not a protocol
    desync: only the one batch the frame carried fails, and the
    executor re-dispatches it to a healthy worker instead of recycling
    the stream (:class:`~repro.runtime.procpool.ProtocolError` is the
    desync case).  ``header`` holds the frame's parsed header when the
    corruption spared it (how the reader attributes the fault to its
    pending request)."""

    def __init__(self, worker: int = -1, detail: str = "",
                 header: Optional[dict] = None):
        self.worker = int(worker)
        self.header = header
        super().__init__(f"worker {worker}: corrupt frame"
                         + (f": {detail}" if detail else ""))


class FlushError(ServingError):
    """One or more models' batches failed during a drain.  Every other
    model's requests were still executed; ``errors`` maps each failed
    model to its (typed) batch error."""

    def __init__(self, errors: Dict[str, BaseException]):
        self.errors = dict(errors)
        super().__init__("; ".join(
            f"{n}: {type(e).__name__}: {e}" for n, e in errors.items()))


# --------------------------------------------------------------------------
# Ticket
# --------------------------------------------------------------------------


class Ticket:
    """Handle for one queued request.

    Terminates exactly once — with a value or a typed error — no matter
    how many workers race to complete it (re-dispatched and speculative
    backup executions settle by first-fulfillment-wins).  ``result()``
    blocks on the worker pool (pooled sessions) or drains *only this
    model's* queue (synchronous sessions) — a slow unrelated model never
    blocks an independent ticket."""

    __slots__ = ("name", "deadline", "submitted_at", "trace_id",
                 "_session", "_event", "_lock", "_done", "_value",
                 "_error", "_cbs")

    def __init__(self, session, name: str,
                 deadline: Optional[float] = None):
        self._session = session
        self.name = name
        self.deadline = deadline          # chaos-clock absolute seconds
        self.submitted_at = time.monotonic()
        self.trace_id = _trace.new_trace_id()
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._done = False
        self._value = None
        self._error: Optional[BaseException] = None
        self._cbs: List[Callable] = []

    def _settle_locked(self) -> List[Callable]:
        self._done = True
        cbs, self._cbs = self._cbs, []
        return cbs

    def _fulfill(self, value) -> bool:
        with self._lock:
            if self._done:
                return False
            self._value = value
            cbs = self._settle_locked()
        self._event.set()
        for fn in cbs:
            fn(self)
        return True

    def _fail(self, error: BaseException) -> bool:
        with self._lock:
            if self._done:
                return False
            self._error = error
            cbs = self._settle_locked()
        self._event.set()
        for fn in cbs:
            fn(self)
        return True

    def on_done(self, fn: Callable[["Ticket"], None]) -> None:
        """Register ``fn(ticket)`` to run once when the ticket settles
        (immediately if it already has).  Callbacks run on whichever
        thread settles the ticket — possibly a pool worker holding the
        pool lock — so they must not block or call back into the
        settling pool (the fleet router obeys this by only recording
        state and waking its own thread)."""
        with self._lock:
            if not self._done:
                self._cbs.append(fn)
                return
        fn(self)

    def cancel(self) -> bool:
        """Cancel the request.  A ticket still queued is dropped before
        dispatch (its EDF heap slot freed); one already in flight
        settles :class:`Cancelled` unless the real result wins the race
        first.  Returns True when the cancellation settled the ticket,
        False when it had already settled (its result/error stands)."""
        sess = self._session
        if sess is not None and hasattr(sess, "_cancel"):
            return sess._cancel(self)
        return self._fail(Cancelled(self.name))

    @property
    def done(self) -> bool:
        return self._done

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    def result(self, timeout: Optional[float] = None):
        if not self._done:
            self._session._resolve(self, timeout)
        if not self._done:
            raise TimeoutError(
                f"{self.name}: ticket unresolved after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value


# --------------------------------------------------------------------------
# Latency histogram (p50/p99 without storing samples)
# --------------------------------------------------------------------------

#: the log-spaced histogram moved to :class:`repro.obs.metrics.
#: LogHistogram` (same O(1) record / ~5% quantile resolution, now also
#: the registry's summary-rendering child type); this alias keeps the
#: serving-era name importable.
LatencyHistogram = LogHistogram


# --------------------------------------------------------------------------
# Circuit breaker (per model)
# --------------------------------------------------------------------------


class CircuitBreaker:
    """K-consecutive-failure breaker with half-open recovery.

    ``closed`` — plan path; ``open`` — degraded to the interpretive
    oracle engine (slow but correct) until ``cooldown_s`` elapses;
    ``half_open`` — a re-lower probe is in flight; its outcome closes
    or re-opens the breaker."""

    def __init__(self, threshold: int = 3, cooldown_s: float = 2.0,
                 name: str = ""):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.name = name                  # trace attribution only
        self.state = "closed"
        self.failures = 0                 # consecutive
        self.trips = 0
        self.recoveries = 0
        self.opened_at = 0.0
        self._lock = threading.Lock()

    def allow_plan(self) -> bool:
        with self._lock:
            return self.state == "closed"

    def record_success(self) -> None:
        with self._lock:
            self.failures = 0
            if self.state == "half_open":
                self.state = "closed"
                self.recoveries += 1

    def record_failure(self, now: Optional[float] = None) -> bool:
        """Returns True when this failure trips the breaker open."""
        now = _chaos.now() if now is None else now
        with self._lock:
            self.failures += 1
            if self.state == "closed" and self.failures >= self.threshold:
                self.state = "open"
                self.opened_at = now
                self.trips += 1
                _trace.instant("breaker_open", "fault",
                               args={"model": self.name,
                                     "failures": self.failures})
                return True
            return False

    def try_probe(self, now: Optional[float] = None) -> bool:
        """Claim the half-open recovery probe once the cooldown has
        elapsed (only one caller wins per cooldown window)."""
        now = _chaos.now() if now is None else now
        with self._lock:
            if self.state == "open" and \
                    now - self.opened_at >= self.cooldown_s:
                self.state = "half_open"
                _trace.instant("breaker_half_open", "fault",
                               args={"model": self.name})
                return True
            return False

    def probe_failed(self, now: Optional[float] = None) -> None:
        now = _chaos.now() if now is None else now
        with self._lock:
            self.state = "open"
            self.opened_at = now

    def probe_succeeded(self) -> None:
        with self._lock:
            self.state = "closed"
            self.failures = 0
            self.recoveries += 1
        _trace.instant("breaker_closed", "fault",
                       args={"model": self.name})

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"state": self.state, "failures": self.failures,
                    "trips": self.trips, "recoveries": self.recoveries,
                    "threshold": self.threshold}


# --------------------------------------------------------------------------
# Worker pool
# --------------------------------------------------------------------------


class _InFlight:
    __slots__ = ("name", "entries", "started", "seq", "backed_up")

    def __init__(self, name, entries, started, seq):
        self.name = name
        self.entries = entries
        self.started = started
        self.seq = seq
        self.backed_up = False


class _Worker:
    __slots__ = ("wid", "thread", "abandoned", "batches", "requests",
                 "started_at", "seq")

    def __init__(self, wid: int):
        self.wid = wid
        self.thread: Optional[threading.Thread] = None
        self.abandoned = False
        self.batches = 0
        self.requests = 0
        self.started_at = time.monotonic()
        self.seq = 0


class ServerPool:
    """N serving workers over bounded per-model queues.

    ``execute(name, entries, worker_id)`` is the session's robust batch
    executor: it must fulfill or fail every ticket in ``entries`` and
    never raise (the pool still backstops it).  The pool owns admission
    control, SLO-aware dispatch, heartbeat-based failure detection,
    in-flight re-dispatch and worker recycling.

    **Dispatch policy** (SLO-aware, not FIFO): within a model, queued
    entries drain earliest-deadline-first (deadline-less entries rank
    last, in submission order); across models, a due batch from a
    higher ``set_priority()`` class always dispatches before a
    lower one.  Shedding prefers low-priority / least-urgent work: a
    full queue evicts its *latest*-deadline entry for an
    earlier-deadline arrival, and a full pool (``max_queue_total``)
    evicts from the lowest-priority backlogged model before shedding a
    higher-priority arrival."""

    #: dispatch estimate before a model has served enough batches for a
    #: meaningful p99 (and the admission-control retry-hint fallback)
    DEFAULT_EST_MS = 5.0
    #: batches a model must have served before its histogram is trusted
    MIN_EST_SAMPLES = 4
    #: recompute the memoized p99 after this many new samples
    EST_REFRESH = 16
    #: worker fault domain ("thread" here; "process" in
    #: :class:`repro.runtime.procpool.ProcPool`)
    mode = "thread"

    def __init__(self, execute: Callable, *, workers: int = 2,
                 max_batch: int = 8, max_queue: int = 64,
                 max_queue_total: Optional[int] = None,
                 linger_ms: float = 2.0,
                 heartbeat_timeout_s: float = 0.5,
                 straggler_backup_after_s: Optional[float] = None,
                 registry: Optional[MetricsRegistry] = None):
        self._execute = execute
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.max_queue_total = (None if max_queue_total is None
                                else int(max_queue_total))
        self.linger_s = float(linger_ms) / 1e3
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.backup_after_s = (straggler_backup_after_s
                               if straggler_backup_after_s is not None
                               else 4 * self.heartbeat_timeout_s)
        self.monitor = FaultMonitor(n_hosts=0,
                                    timeout_s=heartbeat_timeout_s)
        self.dispatcher = BackupDispatcher(self.monitor)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        #: per-model batch service time — the deadline-driven auto-flush
        #: reserves this model's *p99* before each ticket's deadline
        #: (tail-safe, unlike the EWMA it replaced: one slow outlier
        #: batch no longer decays out of the estimate while stragglers
        #: are still possible)
        self._batch_ms = self.registry.histogram(
            "repro_pool_batch_ms",
            "batch service time per model (pool workers)", ("model",))
        #: name -> (hist count at compute time, p99) memo — _claim_locked
        #: runs under the pool lock on every worker wake, so the bucket
        #: scan is amortized over EST_REFRESH new samples
        self._est_memo: Dict[str, Tuple[int, float]] = {}

        self._cv = threading.Condition()
        #: name -> EDF min-heap of (deadline_key, seq, feed, ticket, enq)
        self._queues: Dict[str, List[tuple]] = {}
        self.priorities: Dict[str, int] = {}
        self._inflight: Dict[int, _InFlight] = {}
        self._workers: Dict[int, _Worker] = {}
        self._running = True
        self._next_wid = workers
        self._seq = 0
        self._enq_seq = 0        # submission order within a deadline class
        self._requeue_seq = 0    # negative: re-dispatched work goes first
        self.counters = {"dispatched_batches": 0, "dispatched_requests": 0,
                         "shed": 0, "deadline_misses": 0,
                         "priority_evictions": 0,
                         "redispatched_batches": 0, "recycled_workers": 0,
                         "speculative_backups": 0}
        self.deadline_misses: Dict[str, int] = {}
        self.shed: Dict[str, int] = {}

        for wid in range(workers):
            self._spawn_locked(wid)
        self._supervisor = threading.Thread(
            target=self._supervise, name="npu-pool-supervisor", daemon=True)
        self._supervisor.start()

    def set_priority(self, name: str, priority: int) -> None:
        """Assign the model's dispatch priority class (default 0;
        higher dispatches first and is preferred when shedding)."""
        with self._cv:
            self.priorities[name] = int(priority)

    # -- dispatch estimate (p99 of served batches) --------------------------
    def _dispatch_est_ms(self, name: str, p: float = 99.0) -> float:
        """How long a batch of ``name`` is expected to take, from the
        *p99* of its served-batch histogram — the reservation the
        deadline-driven auto-flush subtracts from a ticket's deadline.
        Memoized by sample count (the claim loop calls this constantly
        under the pool lock)."""
        h = self._batch_ms.labels(model=name)
        count = h.count
        if count < self.MIN_EST_SAMPLES:
            return self.DEFAULT_EST_MS
        memo = self._est_memo.get(name)
        if memo is not None and count - memo[0] < self.EST_REFRESH:
            return memo[1]
        est = h.percentile(p)
        self._est_memo[name] = (count, est)
        return est

    # -- admission ----------------------------------------------------------
    @staticmethod
    def _dl_key(ticket: Ticket) -> float:
        return ticket.deadline if ticket.deadline is not None else math.inf

    def _push_locked(self, name: str, feed, ticket: Ticket,
                     requeue: bool = False) -> None:
        q = self._queues.setdefault(name, [])
        if requeue:
            # re-dispatched work is the pool's oldest: negative seq ranks
            # it ahead of every queued entry in the same deadline class
            self._requeue_seq -= 1
            seq = self._requeue_seq
        else:
            self._enq_seq += 1
            seq = self._enq_seq
        heapq.heappush(q, (self._dl_key(ticket), seq, feed, ticket,
                           _chaos.now()))

    def _requeue_locked(self, name: str, entries) -> int:
        """Push a failed/straggling batch's still-live entries back for
        another worker (first-fulfillment-wins settles duplicates)."""
        live = 0
        for feed, ticket in entries:
            if ticket.done:
                continue
            self._push_locked(name, feed, ticket, requeue=True)
            live += 1
        if live:
            self._cv.notify_all()
        return live

    def _evict_locked(self, name: str) -> bool:
        """Evict the least-urgent (latest-deadline, newest) entry of the
        model's queue to admit more urgent work; False if empty."""
        q = self._queues.get(name)
        if not q:
            return False
        victim = max(q, key=lambda e: (e[0], e[1]))
        q.remove(victim)
        heapq.heapify(q)
        _, _, _, ticket, _ = victim
        self.counters["shed"] += 1
        self.counters["priority_evictions"] += 1
        self.shed[name] = self.shed.get(name, 0) + 1
        _trace.instant("priority_eviction", "serving",
                       trace_id=ticket.trace_id,
                       args={"model": name, "depth": len(q)})
        ticket._fail(Overloaded(name, len(q), self._retry_hint(name)))
        return True

    def _retry_hint(self, name: str) -> float:
        # retry hint from the typical (p50) batch time — the tail
        # estimate would over-back-off healthy clients
        q = self._queues.get(name, ())
        h = self._batch_ms.labels(model=name)
        est = h.percentile(50) \
            if h.count >= self.MIN_EST_SAMPLES else 10.0
        return max(1.0, est * (len(q) / max(1, self.max_batch)))

    def submit(self, name: str, feed, ticket: Ticket) -> None:
        with self._cv:
            if not self._running:
                raise ServingError("pool is closed")
            prio = self.priorities.get(name, 0)
            q = self._queues.setdefault(name, [])
            if self.max_queue_total is not None and \
                    sum(len(x) for x in self._queues.values()) >= \
                    self.max_queue_total and len(q) < self.max_queue:
                # pool-wide saturation: prefer shedding a lower-priority
                # model's least-urgent entry over this arrival
                victims = sorted(
                    (n for n, x in self._queues.items()
                     if x and self.priorities.get(n, 0) < prio),
                    key=lambda n: self.priorities.get(n, 0))
                if not (victims and self._evict_locked(victims[0])):
                    self._shed_locked(name, ticket, len(q))
            if len(q) >= self.max_queue:
                # model queue full: an earlier-deadline arrival evicts
                # the queue's latest-deadline entry; anything else sheds
                worst = max(q, key=lambda e: (e[0], e[1]))
                if not (self._dl_key(ticket) < worst[0]
                        and self._evict_locked(name)):
                    self._shed_locked(name, ticket, len(q))
            self._push_locked(name, feed, ticket)
            self._cv.notify()

    def _shed_locked(self, name: str, ticket: Ticket, depth: int):
        self.counters["shed"] += 1
        self.shed[name] = self.shed.get(name, 0) + 1
        _trace.instant("shed", "serving", trace_id=ticket.trace_id,
                       args={"model": name, "depth": depth})
        raise Overloaded(name, depth, self._retry_hint(name))

    def queue_depth(self, name: Optional[str] = None) -> int:
        with self._cv:
            if name is not None:
                return len(self._queues.get(name, ()))
            return sum(len(q) for q in self._queues.values())

    def discard(self, name: str, ticket: Ticket) -> int:
        """Drop a (cancelled) ticket's queued entries, freeing their
        EDF heap slots immediately — a cancelled ticket must not hold
        queue capacity until a worker pops past it.  Entries already
        claimed by a worker are left to settle first-wins."""
        with self._cv:
            q = self._queues.get(name)
            if not q:
                return 0
            keep = [e for e in q if e[3] is not ticket]
            removed = len(q) - len(keep)
            if removed:
                q[:] = keep
                heapq.heapify(q)
        return removed

    # -- dispatch (deadline-driven auto-flush) ------------------------------
    def _miss_locked(self, name: str, ticket: Ticket, now: float) -> None:
        self.counters["deadline_misses"] += 1
        self.deadline_misses[name] = self.deadline_misses.get(name, 0) + 1
        _trace.instant("deadline_miss", "serving",
                       trace_id=ticket.trace_id,
                       args={"model": name,
                             "late_ms": (now - ticket.deadline) * 1e3})
        ticket._fail(DeadlineExceeded(
            name, late_ms=(now - ticket.deadline) * 1e3))

    def _claim_locked(self, now: float
                      ) -> Tuple[Optional[Tuple[str, List]], float]:
        """Pick the most urgent dispatchable model batch, or the time
        until one becomes due.  A batch is due when it is full, when its
        oldest entry has lingered ``linger_ms``, or when its earliest
        deadline minus the model's recent batch time arrives.  Among
        due models the highest priority class wins, breaking ties by
        urgency; entries pop in EDF order."""
        best, next_due = None, math.inf
        for name, q in self._queues.items():
            if not q:
                continue
            # q[0] is the EDF head (earliest deadline); linger is keyed
            # to the *oldest* entry so deadline-less work still flushes
            due = min(e[4] for e in q) + self.linger_s
            head_dl = q[0][0]
            if math.isfinite(head_dl):
                est = self._dispatch_est_ms(name) / 1e3
                due = min(due, head_dl - est)
            if len(q) >= self.max_batch:
                due = now
            if due <= now:
                cand = (-self.priorities.get(name, 0), due, name)
                if best is None or cand < best:
                    best = cand
            else:
                next_due = min(next_due, due)
        if best is None:
            return None, next_due
        best_name = best[2]
        q = self._queues[best_name]
        entries = []
        while q and len(entries) < self.max_batch:
            _, _, feed, ticket, _ = heapq.heappop(q)
            if ticket.done:
                continue           # settled elsewhere (requeue duplicate)
            if ticket.deadline is not None and now > ticket.deadline:
                self._miss_locked(best_name, ticket, now)
                continue
            entries.append((feed, ticket))
        if not entries:                    # the whole head was expired
            return None, 0.0
        return (best_name, entries), 0.0

    # -- workers ------------------------------------------------------------
    def _spawn_locked(self, wid: int) -> None:
        w = _Worker(wid)
        w.thread = threading.Thread(target=self._worker_loop, args=(wid,),
                                    name=f"npu-worker-{wid}", daemon=True)
        self._workers[wid] = w
        self.monitor.register(wid)         # explicit: clears tombstones
        w.thread.start()

    def _worker_ready(self, wid: int) -> bool:
        """Whether this worker may claim work (process pools gate on
        the child process having finished loading its models)."""
        return True

    def _idle_beat(self, wid: int, seq: int) -> None:
        """Heartbeat for an idle worker.  Thread pools beat from the
        dispatcher thread itself; process pools leave this to the child
        process's heartbeat frames, so a hung child goes stale even
        while its parent-side dispatcher is healthy."""
        self.monitor.beat(wid, seq)

    def _worker_loop(self, wid: int) -> None:
        beat_every = max(0.01, self.heartbeat_timeout_s / 4)
        while True:
            with self._cv:
                w = self._workers.get(wid)
                if w is None or w.abandoned or not self._running:
                    return
                now = _chaos.now()
                if not self._worker_ready(wid):
                    # still booting (process spawn/model load): beat so
                    # the supervisor doesn't recycle a healthy boot
                    self.monitor.beat(wid, w.seq)
                    self._cv.wait(beat_every)
                    continue
                claim, next_due = self._claim_locked(now)
                if claim is None:
                    self._idle_beat(wid, w.seq)
                    wait = beat_every if next_due is math.inf else \
                        min(beat_every, max(0.0, next_due - now))
                    self._cv.wait(wait)
                    continue
                name, entries = claim
                self._seq += 1
                w.seq = self._seq
                self._inflight[wid] = _InFlight(
                    name, entries, time.monotonic(), w.seq)
                self.counters["dispatched_batches"] += 1
                self.counters["dispatched_requests"] += len(entries)

            # ---- outside the lock: chaos stall = a hung kernel (no
            # heartbeats while stalled — that IS the failure signature)
            c = _chaos.active()
            if c is not None:
                stall = c.maybe_stall_s(wid)
                if stall:
                    time.sleep(stall)
            with self._cv:
                inf = self._inflight.get(wid)
                if inf is None or inf.seq != w.seq:
                    # supervisor re-dispatched this batch while we hung —
                    # drop the duplicate work (tickets settle first-wins)
                    continue
            self.monitor.beat(wid, w.seq)
            t0 = time.monotonic()
            try:
                self._execute(name, entries, wid)
            except BaseException as e:     # backstop: executor must not
                for _, ticket in entries:  # raise, but never lose tickets
                    ticket._fail(e if isinstance(e, Exception)
                                 else ServingError(repr(e)))
            dt = time.monotonic() - t0
            tr = _trace.active()
            if tr is not None:
                tr.complete("worker", "serving", t0, t0 + dt,
                            args={"model": name, "worker": wid,
                                  "n": len(entries)})
            self._batch_ms.observe(dt * 1e3, model=name)
            with self._cv:
                self._inflight.pop(wid, None)
                w.batches += 1
                w.requests += len(entries)
                self.monitor.beat(wid, w.seq, step_time_s=dt)
                self._cv.notify_all()

    # -- supervision: detect, re-dispatch, recycle --------------------------
    def _extra_dead_locked(self) -> List[int]:
        """Extra dead-worker ids beyond heartbeat staleness (process
        pools report child exitcodes here)."""
        return []

    def _supervise(self) -> None:
        interval = max(0.02, self.heartbeat_timeout_s / 4)
        while True:
            time.sleep(interval)
            with self._cv:
                if not self._running:
                    return
                dead = {wid for wid in self.monitor.dead_hosts()
                        if wid in self._workers
                        and not self._workers[wid].abandoned}
                dead.update(wid for wid in self._extra_dead_locked()
                            if wid in self._workers
                            and not self._workers[wid].abandoned)
                for wid in sorted(dead):
                    self._recycle_locked(wid)
                # stragglers: speculative backup (first result wins)
                stragglers = set(self.monitor.stragglers())
                now = time.monotonic()
                for wid, inf in list(self._inflight.items()):
                    slow = now - inf.started > self.backup_after_s
                    if inf.backed_up or not slow or (
                            wid not in stragglers and
                            now - inf.started < 2 * self.backup_after_s):
                        continue
                    inf.backed_up = True
                    live = self._requeue_locked(inf.name, inf.entries)
                    self.dispatcher.backups_issued.append(
                        (inf.seq, wid, -1))
                    self.counters["speculative_backups"] += 1
                    _trace.instant("speculative_backup", "fault",
                                   args={"model": inf.name,
                                         "worker": wid,
                                         "live": live})
                    self._cv.notify_all()

    def _on_recycle_locked(self, wid: int) -> None:
        """Subclass hook: tear down the recycled worker's process/pipe
        resources (called under the pool lock, old worker abandoned)."""

    def _recycle_locked(self, wid: int) -> None:
        """A worker stopped heartbeating mid-batch (or its process
        died): re-dispatch its in-flight work to the healthy workers,
        abandon the thread (it drops its duplicate results if it ever
        wakes) and spawn a replacement."""
        w = self._workers[wid]
        w.abandoned = True
        inf = self._inflight.pop(wid, None)
        new_wid = self._next_wid
        self._next_wid += 1
        if inf is not None:
            self._requeue_locked(inf.name, inf.entries)
            self.counters["redispatched_batches"] += 1
            self.dispatcher.backups_issued.append((inf.seq, wid, new_wid))
        self.monitor.retire(wid)
        self.counters["recycled_workers"] += 1
        _trace.instant("worker_recycled", "fault",
                       args={"worker": wid, "replacement": new_wid,
                             "redispatched": inf is not None})
        self._on_recycle_locked(wid)
        self._spawn_locked(new_wid)
        self._cv.notify_all()

    def redispatch(self, name: str, entries, wid: int) -> None:
        """A dispatched batch lost its worker (:class:`WorkerCrashed`):
        hand the still-live entries to the survivors — or, if the pool
        is shutting down, terminate them with a typed error."""
        with self._cv:
            if self._running:
                if self._requeue_locked(name, entries):
                    self.counters["redispatched_batches"] += 1
                    _trace.instant("crash_redispatch", "fault",
                                   args={"model": name, "worker": wid})
                return
        for _, ticket in entries:
            ticket._fail(WorkerLost(
                f"{name}: worker {wid} lost during shutdown"))

    # -- draining / shutdown ------------------------------------------------
    def drain(self, names=None, timeout: Optional[float] = None) -> bool:
        """Block until every queued/in-flight request (of ``names``, or
        all) has terminated.  Returns False on timeout."""
        def clear():
            for name, q in self._queues.items():
                if names is not None and name not in names:
                    continue
                if q:
                    return False
            for inf in self._inflight.values():
                if names is None or inf.name in names:
                    return False
            return True
        with self._cv:
            return self._cv.wait_for(clear, timeout)

    def _on_close(self) -> None:
        """Subclass hook: tear down worker processes (called after the
        pool stops, before the dispatcher threads are joined)."""

    def close(self, timeout: float = 5.0) -> None:
        with self._cv:
            self._running = False
            leftovers = []
            for name, q in self._queues.items():
                while q:
                    _, _, feed, ticket, _ = heapq.heappop(q)
                    leftovers.append((name, ticket))
            self._cv.notify_all()
        for name, ticket in leftovers:
            ticket._fail(WorkerLost(f"{name}: session closed with the "
                                    f"request still queued"))
        self._on_close()
        deadline = time.monotonic() + timeout
        for w in list(self._workers.values()):
            if w.thread is not None and not w.abandoned:
                w.thread.join(max(0.0, deadline - time.monotonic()))

    # -- health -------------------------------------------------------------
    def worker_health(self) -> Dict[int, Dict[str, object]]:
        with self._cv:
            now = time.monotonic()
            out = {}
            for wid, w in self._workers.items():
                hb = self.monitor.beats.get(wid)
                times = self.monitor.step_times.get(wid, [])
                out[wid] = {
                    "alive": bool(w.thread and w.thread.is_alive()),
                    "abandoned": w.abandoned,
                    "batches": w.batches,
                    "requests": w.requests,
                    "inflight": self._inflight.get(wid) is not None,
                    "last_beat_age_s": (now - hb.last_beat) if hb
                    else None,
                    "mean_batch_s": (sum(times[-16:]) / len(times[-16:]))
                    if times else None,
                }
            return out

    def stats(self) -> Dict[str, object]:
        with self._cv:
            return {
                "workers": len([w for w in self._workers.values()
                                if not w.abandoned]),
                "queued": {n: len(q) for n, q in self._queues.items()
                           if q},
                "dispatch_est_ms": {
                    n: round(self._dispatch_est_ms(n), 3)
                    for (n,), h in self._batch_ms.series().items()
                    if h.count},
                "batch_ms": {
                    n: h.snapshot()
                    for (n,), h in self._batch_ms.series().items()
                    if h.count},
                "backups_issued": len(self.dispatcher.backups_issued),
                **self.counters,
            }
