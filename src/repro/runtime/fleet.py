"""Fleet-level serving: replicated pools, health-aware routing, hedged
requests, and a silent-corruption auditor.

A :class:`Fleet` manages N *replica* :class:`~repro.api.session.Session`
instances — each with its own worker pool (thread- or process-mode),
each modeling one host — behind a single ``submit()`` surface.  One
pool surviving worker crashes (PRs 6/9) is not a serving story: real
fleets lose whole hosts, route around sick replicas, roll artifacts
forward without downtime, and — the failure mode that dominates fleet
cost at scale because it never raises — detect replicas that silently
return *wrong bytes*.

What the fleet layer adds on top of the single-pool runtime:

* **health-scored routing** — each replica in a model's placement set
  is scored from its queue depth, circuit-breaker state and recent p99
  (read straight from the session's ``repro_request_latency_ms``
  metrics family); requests route to the best-scoring replica.
  Placement is per-model (``add(..., replicas=k)``) and
  :meth:`rebalance` re-homes models (and their program-cache pins)
  onto the least-loaded replicas as traffic shifts.
* **request hedging** — when a request's only attempt is still
  unsettled after a p99-derived timeout, the router re-issues it to a
  second replica; the existing idempotent first-fulfillment-wins
  :class:`~repro.runtime.serving.Ticket` settles whichever copy lands
  first (request-level speculative execution across pools — the
  roadmap item).
* **pool-level failover** — a replica whose pool dies (every worker
  lost; chaos ``kill_pool`` or a supervisor giving up) fails its
  queued attempts with ``WorkerLost``; the router catches each one and
  re-homes the request on a surviving replica under bounded
  exponential backoff + jitter.  Zero ticket loss: every fleet ticket
  still terminates with a result or a typed error.
* **rolling artifact updates** — :meth:`update` swaps one replica at a
  time (drain, swap, restore), gated by a *canary* that shadow-verifies
  the new artifact's plan outputs against the interpretive oracle
  before any replica swaps; a mismatch rejects the update with
  :class:`UpdateRejected` and no replica is touched.
* **silent-corruption auditor** — a configurable fraction of fulfilled
  responses is re-executed on the interpretive oracle in the
  background; a replica whose audit-mismatch count crosses the
  threshold is *quarantined* (routing stops immediately) and then
  recycled (session torn down and rebuilt).  This is the only defense
  against a replica that corrupts results without erroring.

Every routing / hedge / failover / audit / update decision emits a
trace instant (``fleet_*``) and counts into ``repro_fleet_*`` metrics
families on the fleet's own registry.

Construction goes through :meth:`repro.api.Session.fleet`::

    fleet = Session.fleet(replicas=3, workers=2, audit_fraction=0.05)
    fleet.add("mobilenet_v2", precision="int8", replicas=2)
    t = fleet.submit("mobilenet_v2", image, deadline_ms=100)
    out = t.result()

Fault injection for all of the above lives in
:mod:`repro.runtime.chaos` (``kill_pool`` / ``corrupt_output`` /
``corrupt_canary``); the open-loop harness in
``benchmarks/fleet_bench.py``.
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..obs import trace as _trace
from ..obs.metrics import MetricsRegistry
from . import chaos as _chaos
from .serving import (Cancelled, DeadlineExceeded, Overloaded,
                      ServingError, Ticket, WorkerLost)

#: request errors that are the caller's fault: terminal, never re-homed
_CLIENT_ERRORS = (ValueError, TypeError, KeyError)
#: errors that terminate the fleet ticket instead of re-dispatching
_TERMINAL = (DeadlineExceeded, Cancelled) + _CLIENT_ERRORS


class FleetError(ServingError):
    """Base class of fleet-level typed errors."""


class UpdateRejected(FleetError):
    """A rolling artifact update was rejected: the canary's
    shadow-verification of the new artifact against the interpretive
    oracle mismatched (or a replica swap failed).  When the canary
    rejects, *no* replica was swapped — the fleet keeps serving the
    old artifact."""


class Replica:
    """One replica: a Session (own worker pool) plus fleet-side state.

    ``state``: ``live`` (routable) / ``updating`` (draining for an
    artifact swap) / ``quarantined`` (audit caught it corrupting) /
    ``dead`` (pool lost; being recycled).  Only ``live`` replicas
    receive new work."""

    __slots__ = ("rid", "session", "state", "deaths", "quarantines",
                 "audit_mismatches", "served")

    def __init__(self, rid: int, session):
        self.rid = rid
        self.session = session
        self.state = "live"
        self.deaths = 0
        self.quarantines = 0
        self.audit_mismatches = 0
        self.served = 0


class _Request:
    """Router-side state of one fleet ticket: which replicas have been
    tried, how many attempts are live, hedge/backoff bookkeeping."""

    __slots__ = ("ticket", "name", "feed", "t0", "tried", "attempts",
                 "live", "hedged", "hedge_rid", "hedge_after_s",
                 "redispatches", "retry_at", "last_err")

    def __init__(self, ticket: Ticket, name: str, feed,
                 hedge_after_s: Optional[float]):
        self.ticket = ticket
        self.name = name
        self.feed = feed
        self.t0 = _chaos.now()
        self.tried: Set[int] = set()
        self.attempts: List[Tuple[int, Ticket]] = []
        self.live = 0
        self.hedged = False
        self.hedge_rid = -1
        self.hedge_after_s = hedge_after_s     # None = hedging disabled
        self.redispatches = 0
        self.retry_at: Optional[float] = None  # chaos-clock abs seconds
        self.last_err: Optional[BaseException] = None


class Fleet:
    """N replica Sessions behind one health-routed ``submit()``.

    The fleet absorbs backpressure instead of surfacing it: an
    ``Overloaded`` shed on one replica re-routes to another (bounded by
    ``max_redispatch`` backoff rounds), so ``submit()`` never raises
    ``Overloaded`` — a ticket whose re-dispatch budget exhausts fails
    with the last typed error instead.  Deadlines stay absolute across
    re-homes and hedges."""

    #: hedge timeout before a model has served enough requests for a
    #: meaningful p99
    DEFAULT_HEDGE_MS = 50.0
    #: samples required before the latency p99 drives the hedge timeout
    MIN_HEDGE_SAMPLES = 16
    #: breaker-state routing penalties (scored against ~queue-depth/
    #: max_batch units; an open breaker must lose to any healthy queue)
    _BREAKER_PENALTY = {"closed": 0.0, "half_open": 2.0, "open": 4.0}

    def __init__(self, replicas: int = 2, *,
                 session_factory=None,
                 workers: int = 2, mode: str = "thread",
                 max_batch: int = 8, max_queue: int = 64,
                 hedge: bool = True,
                 hedge_after_ms: Optional[float] = None,
                 hedge_floor_ms: float = 5.0,
                 hedge_cap_ms: float = 1000.0,
                 hedge_budget: float = 0.10,
                 audit_fraction: float = 0.0,
                 audit_threshold: int = 3,
                 audit_backlog: int = 64,
                 max_redispatch: int = 8,
                 backoff_base_ms: float = 2.0,
                 backoff_cap_ms: float = 100.0,
                 seed: int = 0,
                 **session_kw):
        if replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        if int(workers) < 1:
            raise ValueError("fleet replicas need worker pools "
                             "(workers >= 1)")
        if session_factory is None:
            from repro.api.session import Session
            session_factory = Session
        self._factory = session_factory
        self._mode = mode
        self._workers = int(workers)
        self._max_batch = int(max_batch)
        self._max_queue = int(max_queue)
        self._session_kw = dict(session_kw)
        self.hedge = bool(hedge)
        self.hedge_after_ms = hedge_after_ms
        self.hedge_floor_ms = float(hedge_floor_ms)
        self.hedge_cap_ms = float(hedge_cap_ms)
        #: hedges are capped to this fraction of submitted requests —
        #: the tail-at-scale guardrail: a hedge timeout that lags a
        #: load shift (the p99 estimate is trailing) must not double
        #: the offered load and *create* the tail it exists to cut
        self.hedge_budget = float(hedge_budget)
        self.audit_fraction = float(audit_fraction)
        self.audit_threshold = int(audit_threshold)
        self.audit_backlog = int(audit_backlog)
        self.max_redispatch = int(max_redispatch)
        self.backoff_base_ms = float(backoff_base_ms)
        self.backoff_cap_ms = float(backoff_cap_ms)
        self._rng = random.Random(seed)

        #: the fleet's own metrics surface (replica sessions keep their
        #: own registries; this one aggregates fleet decisions)
        self.registry = MetricsRegistry()
        self._m_latency = self.registry.histogram(
            "repro_fleet_request_ms",
            "end-to-end fleet request latency (first-winning attempt)",
            ("model",))
        self.registry.register_collector(self._collect_metrics)
        self.counters = {
            "requests": 0, "completed": 0, "failed": 0,
            "hedges": 0, "hedge_wins": 0, "redispatches": 0,
            "pool_deaths": 0, "quarantines": 0, "recycles": 0,
            "audit_ok": 0, "audit_mismatch": 0, "audit_error": 0,
            "audit_dropped": 0, "updates_ok": 0,
            "updates_rolled_back": 0, "updates_failed": 0,
            "cancelled": 0, "deadline_misses": 0, "exhausted": 0,
        }

        #: the fleet lock.  Rule: never call into a replica's pool or
        #: session while holding it — attempt-ticket callbacks run
        #: under pool locks and re-enter here (pool lock -> fleet lock
        #: is the only permitted order)
        self._cv = threading.Condition()
        self._replicas: Dict[int, Replica] = {}
        self._placement: Dict[str, Set[int]] = {}
        self._specs: Dict[str, dict] = {}
        self._oracles: Dict[str, object] = {}
        self._requests: Dict[Ticket, _Request] = {}
        self._req_counts: Dict[str, int] = {}
        self._audit_q: deque = deque()
        self._running = True
        self.closed = False

        for rid in range(int(replicas)):
            self._replicas[rid] = Replica(rid, self._new_session(rid))

        self._router_t = threading.Thread(
            target=self._router, name="npu-fleet-router", daemon=True)
        self._router_t.start()
        self._audit_t = threading.Thread(
            target=self._auditor, name="npu-fleet-auditor", daemon=True)
        self._audit_t.start()

    # -- construction / registry -------------------------------------------
    def _new_session(self, rid: int):
        return self._factory(workers=(self._mode, self._workers),
                             max_batch=self._max_batch,
                             max_queue=self._max_queue,
                             tag=f"r{rid}", **self._session_kw)

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def replicas(self) -> Dict[int, str]:
        """rid -> state snapshot."""
        with self._cv:
            return {rid: rep.state
                    for rid, rep in sorted(self._replicas.items())}

    def _choose_rids(self, replicas) -> List[int]:
        with self._cv:
            live = sorted(rid for rid, rep in self._replicas.items())
            loads = {rid: sum(1 for p in self._placement.values()
                              if rid in p) for rid in live}
        if replicas is None:
            return live
        if isinstance(replicas, int):
            k = max(1, min(int(replicas), len(live)))
            return sorted(sorted(live, key=lambda r: (loads[r], r))[:k])
        rids = sorted(int(r) for r in replicas)
        bad = [r for r in rids if r not in loads]
        if bad:
            raise ValueError(f"unknown replica id(s) {bad}")
        return rids

    def _apply_spec(self, sess, spec: dict) -> None:
        if spec["kind"] == "load":
            sess.load(spec["path"], name=spec["name"],
                      pin=spec["pin"], priority=spec["priority"])
        else:
            sess.add(spec["source"], name=spec["name"],
                     precision=spec["precision"], options=spec["options"],
                     warmup=spec["warmup"], pin=spec["pin"],
                     priority=spec["priority"], **spec["kw"])

    def add(self, source, name: Optional[str] = None,
            replicas=None, precision: str = "auto", options=None,
            warmup: bool = False, pin: bool = False,
            priority: Optional[int] = None, **kw):
        """Compile and register one model on its replica set —
        ``replicas`` is ``None`` (all), a count (that many least-loaded
        replicas) or an explicit list of replica ids.  The compile is
        shared through the process-global program cache, so N replicas
        cost one solve.  Returns the :class:`CompiledModel` (also the
        fleet's audit/canary oracle reference)."""
        rids = self._choose_rids(replicas)
        with self._cv:
            sessions = [self._replicas[r].session for r in rids]
        model = sessions[0].add(source, name=name, precision=precision,
                                options=options, warmup=warmup, pin=pin,
                                priority=priority, **kw)
        name = name or model.name
        # replicas 2..N (and future recycles) register the already-
        # quantized bundle: PTQ ran once, and a Graph source must not be
        # re-quantized (calibration annotates the graph in place)
        if model.qm is not None:
            source, precision = model.qm, "auto"
        for sess in sessions[1:]:
            sess.add(source, name=name, precision=precision,
                     options=options, warmup=warmup, pin=pin,
                     priority=priority, **kw)
        with self._cv:
            self._specs[name] = dict(
                kind="add", source=source, name=name,
                precision=precision, options=options, warmup=warmup,
                pin=pin, priority=priority, kw=dict(kw))
            self._placement[name] = set(rids)
            self._oracles[name] = model
        return model

    def load(self, path: str, name: Optional[str] = None,
             replicas=None, pin: bool = False,
             priority: Optional[int] = None):
        """Register a model from an on-disk artifact on its replica set
        (replicas mmap the same artifact copy-on-write)."""
        rids = self._choose_rids(replicas)
        with self._cv:
            sessions = [self._replicas[r].session for r in rids]
        model = sessions[0].load(path, name=name, pin=pin,
                                 priority=priority)
        name = name or model.name
        for sess in sessions[1:]:
            sess.load(path, name=name, pin=pin, priority=priority)
        with self._cv:
            self._specs[name] = dict(kind="load", path=path, name=name,
                                     pin=pin, priority=priority)
            self._placement[name] = set(rids)
            self._oracles[name] = model
        return model

    def models(self) -> List[str]:
        with self._cv:
            return sorted(self._specs)

    def placement(self) -> Dict[str, List[int]]:
        with self._cv:
            return {n: sorted(p) for n, p in self._placement.items()}

    # -- health-scored routing ----------------------------------------------
    def _candidates(self, name: str,
                    exclude: Optional[Set[int]] = None) -> List[Replica]:
        with self._cv:
            placed = self._placement.get(name, ())
            return [rep for rid, rep in self._replicas.items()
                    if rep.state == "live" and rid in placed
                    and not (exclude and rid in exclude)]

    def _score(self, rep: Replica, name: str) -> Optional[Tuple]:
        """(load score sans p99, raw p99) — p99 is normalized against
        the candidate median by the caller.  None = unscorable (pool
        torn down under us)."""
        sess = rep.session
        pool = sess._pool
        try:
            depth = pool.queue_depth(name) if pool is not None else 0
        except Exception:
            return None
        br = sess._breakers.get(name)
        pen = 0.0 if br is None else \
            self._BREAKER_PENALTY.get(br.state, 4.0)
        # recent p99, read from the session's existing metrics family
        fam = sess.registry.histogram(
            "repro_request_latency_ms",
            "end-to-end served request latency", ("model",))
        h = fam.labels(model=name)
        p99 = h.percentile(99) if h.count >= 8 else 0.0
        return (depth / max(1, self._max_batch) + pen, p99)

    def _pick(self, name: str,
              exclude: Optional[Set[int]] = None) -> Optional[Replica]:
        """The best-scoring live replica of the model's placement set:
        queue depth (batches of backlog) + breaker penalty + recent p99
        (normalized by the candidate median so a uniformly-slow model
        doesn't distort the comparison).  Ties break toward the replica
        that has served least."""
        cands = self._candidates(name, exclude)
        if not cands and exclude:
            cands = self._candidates(name, None)   # all tried: reuse
        if not cands:
            return None
        scored = []
        for rep in cands:               # no fleet lock: pool locks inside
            s = self._score(rep, name)
            if s is not None:
                scored.append((rep,) + s)
        if not scored:
            return None
        pos = sorted(s[2] for s in scored if s[2] > 0)
        med = pos[len(pos) // 2] if pos else 0.0
        return min(scored,
                   key=lambda s: (s[1] + (s[2] / med if med else 0.0),
                                  s[0].served, s[0].rid))[0]

    # -- request path --------------------------------------------------------
    def submit(self, name: str, inputs, deadline_ms: Optional[float] = None,
               hedge: Optional[bool] = None) -> Ticket:
        """Route one request to the healthiest replica and return its
        fleet :class:`Ticket`.  ``hedge=None`` uses the fleet default;
        the hedge timeout derives from the model's fleet-level p99.
        Backpressure and replica loss re-route internally (bounded);
        the ticket terminates with a value or a typed error, never
        silently."""
        with self._cv:
            if not self._running:
                raise ServingError("fleet is closed")
            if name not in self._specs:
                raise KeyError(
                    f"model {name!r} not registered "
                    f"(have: {sorted(self._specs)})")
            self._req_counts[name] = self._req_counts.get(name, 0) + 1
            self.counters["requests"] += 1
        now = _chaos.now()
        deadline = None
        if deadline_ms is not None:
            deadline = now + float(deadline_ms) / 1e3
        ticket = Ticket(self, name, deadline)
        if deadline is not None and deadline <= now:
            with self._cv:
                self.counters["deadline_misses"] += 1
            ticket._fail(DeadlineExceeded(name, 0.0))
            return ticket
        use_hedge = self.hedge if hedge is None else bool(hedge)
        req = _Request(ticket, name, inputs,
                       self._hedge_after_s(name) if use_hedge else None)
        with self._cv:
            self._requests[ticket] = req
        if not self._dispatch(req):
            self._backoff_or_fail(req)
        return ticket

    def _hedge_after_s(self, name: str) -> float:
        if self.hedge_after_ms is not None:
            return float(self.hedge_after_ms) / 1e3
        h = self._m_latency.labels(model=name)
        if h.count >= self.MIN_HEDGE_SAMPLES:
            ms = h.percentile(99)
        else:
            ms = self.DEFAULT_HEDGE_MS
        return min(max(ms, self.hedge_floor_ms), self.hedge_cap_ms) / 1e3

    def _dispatch(self, req: _Request, hedge: bool = False) -> bool:
        """Submit one attempt for ``req`` on the best replica.  Returns
        False only when no live replica is routable; admission errors
        flow through the attempt ticket into :meth:`_attempt_done`
        (single settlement path)."""
        rep = self._pick(req.name, exclude=req.tried or None)
        if rep is None:
            return False
        if hedge and rep.rid in req.tried:
            return False           # a hedge must land on a new replica
        sess = rep.session
        attempt = Ticket(sess, req.name, req.ticket.deadline)
        attempt.trace_id = req.ticket.trace_id    # one trace, N attempts
        with self._cv:
            if req.ticket.done:
                return True
            req.live += 1
            req.tried.add(rep.rid)
            req.attempts.append((rep.rid, attempt))
            if hedge:
                req.hedge_rid = rep.rid
                self.counters["hedges"] += 1
            rep.served += 1
        attempt.on_done(
            lambda a, _rid=rep.rid: self._attempt_done(req, _rid, a))
        _trace.instant("fleet_hedge" if hedge else "fleet_route",
                       "fleet", trace_id=req.ticket.trace_id,
                       args={"model": req.name, "replica": rep.rid})
        try:
            pool = sess._pool
            if pool is None:
                raise ServingError("replica has no pool")
            pool.submit(req.name, req.feed, attempt)
        except (Overloaded, ServingError) as e:
            # shed or closing pool: settle the attempt so the failure
            # takes the one normal path (bookkeeping + backoff re-home)
            attempt._fail(e)
        except Exception as e:                     # pool teardown races
            attempt._fail(ServingError(repr(e)))
        return True

    def _attempt_done(self, req: _Request, rid: int, a: Ticket) -> None:
        """Attempt-ticket settlement hook.  May run on a pool worker
        thread holding that pool's lock: only fleet-lock state updates
        and (for the winning value) the fleet ticket settlement happen
        here — re-dispatch work is deferred to the router thread."""
        err = a.error
        if err is None:
            won = req.ticket._fulfill(a._value)
            with self._cv:
                req.live -= 1
                hedge_win = won and req.hedged and rid == req.hedge_rid
                if won:
                    self._requests.pop(req.ticket, None)
                    self.counters["completed"] += 1
                    if hedge_win:
                        self.counters["hedge_wins"] += 1
                self._cv.notify_all()
            if won:
                self._m_latency.observe(
                    (time.monotonic() - req.ticket.submitted_at) * 1e3,
                    model=req.name)
                if hedge_win:
                    _trace.instant("fleet_hedge_win", "fleet",
                                   trace_id=req.ticket.trace_id,
                                   args={"model": req.name,
                                         "replica": rid})
                self._maybe_audit(req.name, rid, req.feed, a._value)
            return
        with self._cv:
            req.live -= 1
            if req.ticket.done:
                self._requests.pop(req.ticket, None)
                self._cv.notify_all()
                return
            if isinstance(err, _TERMINAL):
                req.ticket._fail(err)
                self._requests.pop(req.ticket, None)
                self.counters["failed"] += 1
                if isinstance(err, DeadlineExceeded):
                    self.counters["deadline_misses"] += 1
                self._cv.notify_all()
                return
            req.last_err = err
            if req.live > 0:
                # a hedge twin is still racing: let it settle the ticket
                self._cv.notify_all()
                return
            if req.redispatches >= self.max_redispatch:
                req.ticket._fail(err)
                self._requests.pop(req.ticket, None)
                self.counters["failed"] += 1
                self.counters["exhausted"] += 1
                self._cv.notify_all()
                return
            self._schedule_retry_locked(req, err)

    def _schedule_retry_locked(self, req: _Request,
                               err: BaseException) -> None:
        """Arm a bounded-exponential-backoff re-dispatch (jittered so
        a mass failover doesn't re-converge on one survivor)."""
        req.redispatches += 1
        self.counters["redispatches"] += 1
        base = min(self.backoff_cap_ms,
                   self.backoff_base_ms * (2 ** (req.redispatches - 1)))
        delay_ms = base * (0.5 + 0.5 * self._rng.random())
        req.retry_at = _chaos.now() + delay_ms / 1e3
        _trace.instant("fleet_failover", "fleet",
                       trace_id=req.ticket.trace_id,
                       args={"model": req.name,
                             "reason": type(err).__name__,
                             "redispatch": req.redispatches,
                             "delay_ms": round(delay_ms, 2)})
        self._cv.notify_all()

    def _backoff_or_fail(self, req: _Request) -> None:
        """No replica was routable right now: back off (one may recycle
        back to life) until the re-dispatch budget exhausts."""
        with self._cv:
            if req.ticket.done or req.live > 0 or \
                    req.retry_at is not None:
                return
            err = req.last_err or WorkerLost(
                f"{req.name}: no live replica")
            if req.redispatches >= self.max_redispatch:
                req.ticket._fail(err)
                self._requests.pop(req.ticket, None)
                self.counters["failed"] += 1
                self.counters["exhausted"] += 1
                self._cv.notify_all()
                return
            self._schedule_retry_locked(req, err)

    def _resolve(self, ticket: Ticket, timeout: Optional[float]) -> None:
        ticket._event.wait(timeout)

    def _cancel(self, ticket: Ticket) -> bool:
        """:meth:`Ticket.cancel` on a fleet ticket: settle it
        ``Cancelled`` (first-wins) and cancel every replica attempt so
        queued copies free their EDF heap slots."""
        won = ticket._fail(Cancelled(ticket.name))
        with self._cv:
            req = self._requests.pop(ticket, None)
            if won:
                self.counters["cancelled"] += 1
            attempts = list(req.attempts) if req is not None else []
            self._cv.notify_all()
        if won:
            _trace.instant("fleet_cancel", "fleet",
                           trace_id=ticket.trace_id,
                           args={"model": ticket.name})
        for _rid, attempt in attempts:
            attempt.cancel()
        return won

    # -- router thread -------------------------------------------------------
    def _router(self) -> None:
        while True:
            due: List[Tuple[_Request, str]] = []
            with self._cv:
                if not self._running:
                    return
                now = _chaos.now()
                next_due = now + 0.05
                for req in list(self._requests.values()):
                    t = req.ticket
                    if t.done:
                        self._requests.pop(t, None)
                        continue
                    dl = t.deadline
                    if dl is not None and now > dl and req.live == 0:
                        # stranded in backoff past its deadline
                        t._fail(DeadlineExceeded(
                            req.name, (now - dl) * 1e3))
                        self._requests.pop(t, None)
                        self.counters["failed"] += 1
                        self.counters["deadline_misses"] += 1
                        continue
                    if req.retry_at is not None:
                        if now >= req.retry_at:
                            req.retry_at = None
                            due.append((req, "retry"))
                        else:
                            next_due = min(next_due, req.retry_at)
                    elif req.hedge_after_s is not None and \
                            not req.hedged and req.live == 1:
                        h_at = req.t0 + req.hedge_after_s
                        if now < h_at:
                            next_due = min(next_due, h_at)
                        elif self.counters["hedges"] < \
                                self.hedge_budget * max(
                                    1, self.counters["requests"]):
                            req.hedged = True     # claim under the lock
                            due.append((req, "hedge"))
                        # over budget: leave it — the pool serves it
                self._cv.notify_all()
            # outside the fleet lock: chaos + pool calls
            self._poll_chaos()
            for req, act in due:
                if act == "hedge":
                    self._dispatch(req, hedge=True)
                elif not self._dispatch(req):
                    self._backoff_or_fail(req)
            with self._cv:
                if not self._running:
                    return
                wait = max(0.001, min(next_due - _chaos.now(), 0.05))
                self._cv.wait(wait)

    def _poll_chaos(self) -> None:
        c = _chaos.active()
        if c is None:
            return
        for rid in c.take_pool_kills():
            self.kill_replica(rid, reason="chaos")

    # -- pool-level failover -------------------------------------------------
    def kill_replica(self, rid: int, reason: str = "dead") -> bool:
        """Declare one replica's pool dead (every worker lost at once).
        Its queued attempts fail ``WorkerLost`` — the router re-homes
        each on the survivors with backoff — and the replica recycles
        in the background (tear down, rebuild, re-register, resume)."""
        with self._cv:
            rep = self._replicas.get(rid)
            if rep is None or rep.state != "live":
                return False
            rep.state = "dead"
            rep.deaths += 1
            self.counters["pool_deaths"] += 1
        _trace.instant("fleet_pool_dead", "fleet",
                       args={"replica": rid, "reason": reason})
        threading.Thread(target=self._recycle, args=(rid, reason),
                         name=f"npu-fleet-recycle-{rid}",
                         daemon=True).start()
        return True

    def _recycle(self, rid: int, reason: str) -> None:
        """Tear the replica's session down (queued attempts drain back
        to the router as ``WorkerLost`` failures) and rebuild it from
        the registered model specs."""
        with self._cv:
            rep = self._replicas.get(rid)
            if rep is None:
                return
            old = rep.session
            names = [n for n, p in self._placement.items() if rid in p]
            specs = [dict(self._specs[n]) for n in names]
        try:
            old.close()
        except Exception:
            pass
        try:
            sess = self._new_session(rid)
            for spec in specs:
                self._apply_spec(sess, spec)
        except Exception as e:
            _trace.instant("fleet_recycle_failed", "fleet",
                           args={"replica": rid, "error": repr(e)})
            return                 # replica stays dead; others serve
        with self._cv:
            rep.session = sess
            rep.audit_mismatches = 0
            rep.state = "live"
            self.counters["recycles"] += 1
            self._cv.notify_all()
        _trace.instant("fleet_replica_recycled", "fleet",
                       args={"replica": rid, "reason": reason})

    # -- silent-corruption auditor ------------------------------------------
    def _maybe_audit(self, name: str, rid: int, feed, out) -> None:
        if self.audit_fraction <= 0.0:
            return
        with self._cv:
            if self._rng.random() >= self.audit_fraction:
                return
            if len(self._audit_q) >= self.audit_backlog:
                self.counters["audit_dropped"] += 1
                return
            self._audit_q.append((name, rid, feed, out))
            self._cv.notify_all()

    def _auditor(self) -> None:
        while True:
            with self._cv:
                while self._running and not self._audit_q:
                    self._cv.wait(0.1)
                if not self._running:
                    return
                name, rid, feed, out = self._audit_q.popleft()
                oracle = self._oracles.get(name)
            if oracle is None:
                continue
            try:
                mismatch = self._audit_mismatch(oracle, feed, out)
            except Exception:
                with self._cv:
                    self.counters["audit_error"] += 1
                continue
            with self._cv:
                self.counters[
                    "audit_mismatch" if mismatch else "audit_ok"] += 1
                over = False
                if mismatch:
                    rep = self._replicas.get(rid)
                    if rep is not None:
                        rep.audit_mismatches += 1
                        over = (rep.audit_mismatches >=
                                self.audit_threshold and
                                rep.state == "live")
            if mismatch:
                _trace.instant("fleet_audit_mismatch", "fleet",
                               args={"model": name, "replica": rid})
                if over:
                    self._quarantine(rid)

    @staticmethod
    def _audit_mismatch(oracle, feed, out) -> bool:
        """Re-execute the sampled request on the interpretive oracle
        and compare every output within the model's plan-parity
        tolerance (floored: bit-identical semantics still deserve an
        epsilon against dtype round-tripping)."""
        want = oracle(feed, engine="interp")
        sem = oracle.semantics
        for k, w in want.items():
            got = np.asarray(out[k], dtype=np.float64)
            ref = np.asarray(w, dtype=np.float64)
            if got.shape != ref.shape:
                return True
            if not got.size:
                continue
            tol = max(sem.plan_parity_tol(k), 1e-6) if sem is not None \
                else 1e-6
            if float(np.max(np.abs(got - ref))) > tol:
                return True
        return False

    def _quarantine(self, rid: int) -> None:
        """Audit verdict: the replica returns wrong bytes.  Stop
        routing to it *now*, then recycle it in the background."""
        with self._cv:
            rep = self._replicas.get(rid)
            if rep is None or rep.state != "live":
                return
            rep.state = "quarantined"
            rep.quarantines += 1
            self.counters["quarantines"] += 1
            mismatches = rep.audit_mismatches
        _trace.instant("fleet_quarantine", "fleet",
                       args={"replica": rid, "mismatches": mismatches})
        threading.Thread(target=self._recycle, args=(rid, "audit"),
                         name=f"npu-fleet-recycle-{rid}",
                         daemon=True).start()

    # -- rolling artifact updates -------------------------------------------
    def update(self, name: str, path: str, probe_feeds: int = 2) -> int:
        """Rolling artifact update: canary-verify the new artifact,
        then drain and swap one replica at a time (requests keep
        routing to the others).  The canary runs *before* any swap —
        plan outputs of the new artifact shadow-verified against its
        interpretive oracle — and a mismatch raises
        :class:`UpdateRejected` with zero replicas touched (the
        rollback).  Returns the number of replicas swapped."""
        with self._cv:
            spec = self._specs.get(name)
            if spec is None:
                raise KeyError(f"model {name!r} not registered")
            spec = dict(spec)
            rids = sorted(self._placement.get(name, ()))
        from repro.api.compiled import CompiledModel
        new = CompiledModel.load(path, mmap=True)
        detail = self._canary(name, new, probe_feeds)
        if detail is not None:
            with self._cv:
                self.counters["updates_rolled_back"] += 1
            _trace.instant("fleet_update_rollback", "fleet",
                           args={"model": name, "detail": detail})
            raise UpdateRejected(
                f"{name}: canary rejected the new artifact ({detail}) "
                f"— rolled back, no replica swapped")
        pin = bool(spec.get("pin", False))
        priority = spec.get("priority")
        swapped = 0
        for rid in rids:
            with self._cv:
                rep = self._replicas.get(rid)
                if rep is None or rep.state != "live":
                    continue       # recycling replicas rebuild from the
                rep.state = "updating"     # updated spec written below
            try:
                rep.session.flush(name, timeout=30.0)       # drain
                if pin and name in rep.session._pinned:
                    rep.session.unpin(name)
                rep.session.load(path, name=name, pin=pin,
                                 priority=priority)
                swapped += 1
            except Exception as e:
                with self._cv:
                    rep.state = "live"
                    self.counters["updates_failed"] += 1
                    self._cv.notify_all()
                raise UpdateRejected(
                    f"{name}: swap failed on replica {rid} after "
                    f"{swapped} swap(s): {e}") from e
            with self._cv:
                rep.state = "live"
                self._cv.notify_all()
            _trace.instant("fleet_update_swap", "fleet",
                           args={"model": name, "replica": rid})
        with self._cv:
            self._specs[name] = dict(kind="load", path=path, name=name,
                                     pin=pin, priority=priority)
            self._oracles[name] = new
            self.counters["updates_ok"] += 1
        return swapped

    @staticmethod
    def _canary(name: str, new, probe_feeds: int) -> Optional[str]:
        """Shadow-verify the new artifact: its compiled replay plan
        must match its interpretive oracle on probe inputs, within the
        plan-parity tolerance.  Returns a mismatch description, or
        None when the canary passes."""
        rng = np.random.default_rng(0)
        sem = new.semantics
        for i in range(max(1, int(probe_feeds))):
            feed = {t.name: (np.zeros(t.shape, dtype=np.float32) if i == 0
                             else rng.standard_normal(t.shape)
                             .astype(np.float32))
                    for t in new.graph.inputs}
            want = new(feed, engine="interp")
            got = new(feed)                       # plan engine
            c = _chaos.active()
            if c is not None and c.check_canary(name):
                got = _chaos.flip_outputs(got)    # a bad artifact swap
            for k, ref in want.items():
                g = np.asarray(got[k], dtype=np.float64)
                r = np.asarray(ref, dtype=np.float64)
                tol = max(sem.plan_parity_tol(k), 1e-6) \
                    if sem is not None else 1e-6
                err = float(np.max(np.abs(g - r))) if g.size else 0.0
                if g.shape != r.shape or err > tol:
                    return (f"probe {i} output {k}: max|err|="
                            f"{err:.3e} > tol {tol:.3e}")
        return None

    # -- pin rebalancing -----------------------------------------------------
    def rebalance(self) -> Dict[str, List[int]]:
        """Re-home models onto the least-loaded live replicas from
        observed traffic (heaviest models placed first, keeping each
        model's replica-set size).  Program-cache pins follow: a pinned
        model pins on its new homes and unpins where it left.  Returns
        the models that moved with their new placement."""
        with self._cv:
            live = sorted(rid for rid, rep in self._replicas.items()
                          if rep.state == "live")
            traffic = {n: self._req_counts.get(n, 0)
                       for n in self._placement}
            sizes = {n: max(1, len(p))
                     for n, p in self._placement.items()}
            specs = {n: dict(self._specs[n]) for n in self._placement}
            old_placement = {n: set(p)
                             for n, p in self._placement.items()}
        if not live:
            return {}
        load = {rid: 0.0 for rid in live}
        moves: Dict[str, List[int]] = {}
        for n in sorted(traffic, key=lambda n: (-traffic[n], n)):
            k = min(sizes[n], len(live))
            homes = set(sorted(live, key=lambda r: (load[r], r))[:k])
            share = max(1, traffic[n]) / k
            for r in homes:
                load[r] += share
            old = old_placement[n]
            spec = specs[n]
            for rid in sorted(homes - old):       # register on new homes
                with self._cv:
                    sess = self._replicas[rid].session
                if n not in sess:
                    self._apply_spec(sess, spec)
                elif spec.get("pin"):
                    sess.pin(n)
            for rid in sorted(old - homes):       # unpin where it left
                with self._cv:
                    rep = self._replicas.get(rid)
                if rep is None or rep.state != "live":
                    continue
                if spec.get("pin") and n in rep.session._pinned:
                    rep.session.unpin(n)
            with self._cv:
                self._placement[n] = homes
            if homes != old:
                moves[n] = sorted(homes)
        if moves:
            _trace.instant("fleet_rebalance", "fleet",
                           args={"moves": {n: v
                                           for n, v in moves.items()}})
        return moves

    # -- draining / shutdown -------------------------------------------------
    def flush(self, timeout: float = 60.0) -> bool:
        """Block until every submitted fleet ticket has settled.
        Returns False on timeout."""
        deadline = time.monotonic() + timeout
        with self._cv:
            return self._cv.wait_for(
                lambda: not self._requests,
                max(0.0, deadline - time.monotonic()))

    def close(self) -> None:
        """Shut the fleet down: unsettled tickets fail with a typed
        ``WorkerLost`` (never silently lost), replicas close."""
        if self.closed:
            return
        self.closed = True
        with self._cv:
            self._running = False
            reqs = list(self._requests.values())
            self._requests.clear()
            self._audit_q.clear()
            reps = list(self._replicas.values())
            self._cv.notify_all()
        for req in reqs:
            req.ticket._fail(WorkerLost(
                f"{req.name}: fleet closed with the request unsettled"))
        self._router_t.join(2.0)
        self._audit_t.join(2.0)
        for rep in reps:
            try:
                rep.session.close()
            except Exception:
                pass

    # -- observability -------------------------------------------------------
    _STATE_CODE = {"live": 0, "updating": 1, "quarantined": 2, "dead": 3}

    def _collect_metrics(self) -> None:
        reg = self.registry
        with self._cv:
            counters = dict(self.counters)
            reps = [(rid, rep.state, rep.served, rep.deaths,
                     rep.quarantines, rep.audit_mismatches)
                    for rid, rep in sorted(self._replicas.items())]
            inflight = len(self._requests)
            req_counts = dict(self._req_counts)
        ev = reg.counter("repro_fleet_events_total",
                         "fleet routing/hedge/failover/audit events",
                         ("event",))
        for k, v in counters.items():
            ev.set_total(v, event=k)
        reg.gauge("repro_fleet_inflight",
                  "unsettled fleet requests").set(inflight)
        st = reg.gauge("repro_fleet_replica_state",
                       "replica state (0=live 1=updating 2=quarantined "
                       "3=dead)", ("replica",))
        routed = reg.counter("repro_fleet_routed_total",
                             "attempts routed per replica", ("replica",))
        deaths = reg.counter("repro_fleet_replica_deaths_total",
                             "pool deaths per replica", ("replica",))
        quar = reg.counter("repro_fleet_quarantines_total",
                           "audit quarantines per replica", ("replica",))
        mism = reg.gauge("repro_fleet_audit_mismatches",
                         "audit mismatches since last recycle",
                         ("replica",))
        for rid, state, served, d, q, m in reps:
            st.set(self._STATE_CODE.get(state, 3), replica=rid)
            routed.set_total(served, replica=rid)
            deaths.set_total(d, replica=rid)
            quar.set_total(q, replica=rid)
            mism.set(m, replica=rid)
        reqs = reg.counter("repro_fleet_requests_total",
                           "fleet requests submitted", ("model",))
        for n, v in req_counts.items():
            reqs.set_total(v, model=n)

    def metrics(self) -> str:
        """The fleet registry as Prometheus text exposition."""
        return self.registry.render()

    def stats(self) -> dict:
        with self._cv:
            reps = {rid: {"state": rep.state, "served": rep.served,
                          "deaths": rep.deaths,
                          "quarantines": rep.quarantines,
                          "audit_mismatches": rep.audit_mismatches}
                    for rid, rep in sorted(self._replicas.items())}
            out = {"replicas": reps,
                   "placement": {n: sorted(p)
                                 for n, p in self._placement.items()},
                   "inflight": len(self._requests),
                   "per_model_requests": dict(self._req_counts),
                   **{k: v for k, v in self.counters.items()}}
        out["latency"] = {
            n: h.snapshot()
            for (n,), h in self._m_latency.series().items() if h.count}
        return out

    def report(self) -> str:
        s = self.stats()
        lines = [f"Fleet: {len(s['replicas'])} replica(s), "
                 f"{s['requests']} request(s), {s['hedges']} hedged "
                 f"({s['hedge_wins']} hedge wins), "
                 f"{s['redispatches']} re-dispatched, "
                 f"{s['pool_deaths']} pool death(s), "
                 f"{s['quarantines']} quarantine(s)"]
        for rid, r in s["replicas"].items():
            lines.append(
                f"  r{rid}: {r['state']:<12} served {r['served']:>6}  "
                f"deaths {r['deaths']}  quarantines {r['quarantines']}  "
                f"audit-mismatches {r['audit_mismatches']}")
        for n, lat in s["latency"].items():
            lines.append(f"  {n}: p50 {lat['p50_ms']:.2f} ms / "
                         f"p99 {lat['p99_ms']:.2f} ms "
                         f"({lat['count']} served)")
        return "\n".join(lines)
