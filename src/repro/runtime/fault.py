"""Fault tolerance: heartbeats, failure detection, elastic re-mesh,
straggler mitigation.

On a real multi-pod deployment each host runs a :class:`Heartbeat`; the
coordinator's :class:`FaultMonitor` detects missed beats, triggers a
checkpoint-restore restart with a *shrunk* data axis (elastic re-mesh) and
keeps a straggler score per host from step-time telemetry (backup-step
dispatch hook).  In this CPU container the same machinery runs with
simulated hosts — the tests inject failures/stragglers and assert the
recovery path (detect -> remesh -> restore -> identical loss curve).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..obs import trace as _trace


# --------------------------------------------------------------------------
# Heartbeats / failure detection
# --------------------------------------------------------------------------


@dataclass
class Heartbeat:
    host_id: int
    last_beat: float = field(default_factory=time.monotonic)
    last_step: int = -1

    def beat(self, step: int) -> None:
        self.last_beat = time.monotonic()
        self.last_step = step


class FaultMonitor:
    """Detects dead hosts (missed heartbeats) and stragglers (step-time
    outliers)."""

    def __init__(self, n_hosts: int, timeout_s: float = 10.0,
                 straggler_ratio: float = 2.0):
        self.timeout_s = timeout_s
        self.ratio = straggler_ratio
        self.beats = {i: Heartbeat(i) for i in range(n_hosts)}
        self.step_times: Dict[int, List[float]] = {i: []
                                                   for i in range(n_hosts)}
        self.failed: set = set()
        self.retired: set = set()

    def register(self, host_id: int) -> None:
        """Explicitly (re-)register a host: clears any tombstone and
        starts a fresh heartbeat record.  Spawning a worker goes through
        here, never through an implicit first ``beat()``."""
        self.retired.discard(host_id)
        self.beats[host_id] = Heartbeat(host_id)
        self.step_times[host_id] = []
        self.failed.discard(host_id)

    def beat(self, host_id: int, step: int,
             step_time_s: Optional[float] = None) -> None:
        if host_id in self.retired:
            # a recycled worker's final heartbeat can still be in flight
            # when retire() runs; without the tombstone it would
            # auto-register below and resurrect the dead entry, which
            # the supervisor then detects (and recycles) forever
            return
        hb = self.beats.get(host_id)
        if hb is None:
            # tolerate (and auto-register) hosts that joined after
            # construction — replacement workers recycled into a serving
            # pool beat with fresh ids
            hb = self.beats[host_id] = Heartbeat(host_id)
        hb.beat(step)
        if step_time_s is not None:
            t = self.step_times.setdefault(host_id, [])
            t.append(step_time_s)
            if len(t) > 64:
                del t[:-64]

    def mark_failed(self, host_id: int) -> None:
        self.failed.add(host_id)
        _trace.instant("host_failed", "fault", args={"host": host_id})

    def retire(self, host_id: int) -> None:
        """Forget a host (a recycled worker): it no longer counts as
        dead, healthy or a straggler, and its id is tombstoned — late
        beats are dropped until :meth:`register` re-admits the id."""
        self.beats.pop(host_id, None)
        self.step_times.pop(host_id, None)
        self.failed.discard(host_id)
        self.retired.add(host_id)
        _trace.instant("host_retired", "fault", args={"host": host_id})

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        # `now if ... else` — not `now or`: now=0.0 is a legitimate
        # simulated-clock value, not "unset"
        now = time.monotonic() if now is None else now
        dead = [h for h, b in self.beats.items()
                if h not in self.failed
                and now - b.last_beat > self.timeout_s]
        return sorted(set(dead) | self.failed)

    def stragglers(self) -> List[int]:
        """Hosts whose recent mean step time exceeds `ratio` x the
        fleet median (median-based: robust at small host counts where a
        single outlier inflates the z-score denominator)."""
        means = {h: float(np.mean(t[-16:]))
                 for h, t in self.step_times.items() if len(t) >= 4}
        if len(means) < 3:
            return []
        med = float(np.median(list(means.values())))
        return [h for h, m in means.items()
                if m > self.ratio * max(med, 1e-9)]

    def healthy_hosts(self) -> List[int]:
        dead = set(self.dead_hosts())
        return [h for h in self.beats if h not in dead]


# --------------------------------------------------------------------------
# Elastic re-mesh
# --------------------------------------------------------------------------


def elastic_data_axis(n_healthy_chips: int, model_axis: int
                      ) -> Tuple[int, int]:
    """Largest (data, model) grid that fits the surviving chips with the
    model axis preserved (TP degree cannot change without resharding the
    weights' inner dimension).  Returns (n_data, dropped_chips)."""
    n_data = n_healthy_chips // model_axis
    if n_data == 0:
        raise RuntimeError(
            f"{n_healthy_chips} chips cannot host model axis {model_axis}")
    # keep the data axis a power of two for collective efficiency
    n_data = 2 ** int(math.floor(math.log2(n_data)))
    return n_data, n_healthy_chips - n_data * model_axis


@dataclass
class ElasticPlan:
    old_shape: Tuple[int, int]
    new_shape: Tuple[int, int]
    batch_per_shard_old: int
    batch_per_shard_new: int

    @property
    def changed(self) -> bool:
        return self.old_shape != self.new_shape


def plan_remesh(global_batch: int, old_data: int, model_axis: int,
                n_healthy_chips: int) -> ElasticPlan:
    new_data, _ = elastic_data_axis(n_healthy_chips, model_axis)
    assert global_batch % new_data == 0, \
        f"global batch {global_batch} not divisible by {new_data}"
    return ElasticPlan(
        old_shape=(old_data, model_axis),
        new_shape=(new_data, model_axis),
        batch_per_shard_old=global_batch // old_data,
        batch_per_shard_new=global_batch // new_data,
    )


# --------------------------------------------------------------------------
# Straggler mitigation: backup-step dispatch
# --------------------------------------------------------------------------


class BackupDispatcher:
    """Speculative re-dispatch: when a host is flagged as straggler, its
    shard of the *next* step is also dispatched to the fastest healthy
    host; whichever result arrives first wins (the other is cancelled).
    Here the dispatch is a callback so tests can simulate timing."""

    def __init__(self, monitor: FaultMonitor):
        self.monitor = monitor
        self.backups_issued: List[Tuple[int, int, int]] = []

    def maybe_backup(self, step: int,
                     run_shard: Callable[[int, int], float]) -> Dict:
        """run_shard(host, step) -> step time.  Returns per-host times
        with backups applied."""
        stragglers = set(self.monitor.stragglers())
        healthy = [h for h in self.monitor.healthy_hosts()
                   if h not in stragglers]
        times: Dict[int, float] = {}
        for h in self.monitor.healthy_hosts():
            t = run_shard(h, step)
            if h in stragglers and healthy:
                fastest = min(healthy,
                              key=lambda x: np.mean(
                                  self.monitor.step_times[x][-4:] or [0]))
                tb = run_shard(fastest, step)
                self.backups_issued.append((step, h, fastest))
                t = min(t, tb)
            times[h] = t
            self.monitor.beat(h, step, t)
        return times
