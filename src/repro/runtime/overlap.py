"""Compute/communication overlap — the DAE principle at pod scale.

The paper hides DMA behind compute with tick-level scheduling (§IV-B).
The pod-scale equivalents implemented here:

  * **Microbatched gradient accumulation** (`accumulate_grads`): the
    global batch is split into microbatches scanned inside one jit;
    XLA/GSPMD overlaps microbatch k+1's compute with microbatch k's
    gradient reduce-scatter (the same max(l_C, l_DM) objective — with the
    penalty that more microbatches mean more collective launches, the
    paper's delta*N_DM term).
  * **Bucketed grad sync** (`bucket_tree`): leaves are grouped into
    ~bucket_bytes buckets so each all-reduce is large enough to saturate
    the link but small enough to overlap (all-reduce of bucket k while
    bucket k+1 is still being produced).
  * **Async collective hints** (`overlap_flags`): the XLA flags a
    launcher should set for latency-hiding collectives on real TPU.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp


def overlap_flags() -> Dict[str, str]:
    """XLA flags enabling async collectives + latency-hiding scheduler
    (applied by launch/train.py on real TPU backends)."""
    return {
        "xla_tpu_enable_async_collective_fusion": "true",
        "xla_tpu_enable_async_collective_fusion_fuse_all_gather": "true",
        "xla_tpu_overlap_compute_collective_tc": "true",
        "xla_enable_async_all_gather": "true",
        "xla_enable_async_collective_permute": "true",
    }


def split_microbatches(batch: Dict[str, jnp.ndarray], n_micro: int
                       ) -> Dict[str, jnp.ndarray]:
    """(B, ...) -> (n_micro, B/n_micro, ...) for lax.scan."""

    def sp(x):
        B = x.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        return x.reshape((n_micro, B // n_micro) + x.shape[1:])

    return jax.tree_util.tree_map(sp, batch)


def accumulate_grads(loss_fn: Callable, params: Any,
                     batch: Dict[str, jnp.ndarray], n_micro: int
                     ) -> Tuple[jnp.ndarray, Any]:
    """Mean loss/grads over `n_micro` microbatches via lax.scan — fixed
    memory in n_micro, and the per-microbatch reduce-scatter overlaps the
    next microbatch's backward under GSPMD."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)
    mb = split_microbatches(batch, n_micro)
    gfn = jax.value_and_grad(loss_fn)

    def body(carry, micro):
        acc_loss, acc_g = carry
        loss, g = gfn(params, micro)
        acc_g = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(a.dtype), acc_g, g)
        return (acc_loss + loss, acc_g), None

    zero_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                           zero_g), mb)
    inv = 1.0 / n_micro
    return loss * inv, jax.tree_util.tree_map(lambda g: g * inv, grads)


def bucket_tree(tree: Any, bucket_bytes: int = 4 << 20
                ) -> List[List[Tuple[int, Any]]]:
    """Greedy size-bucketing of tree leaves (index, leaf) for bucketed
    all-reduce scheduling."""
    leaves = list(enumerate(jax.tree_util.tree_leaves(tree)))
    buckets: List[List[Tuple[int, Any]]] = []
    cur: List[Tuple[int, Any]] = []
    size = 0
    for i, leaf in leaves:
        b = leaf.size * leaf.dtype.itemsize
        if cur and size + b > bucket_bytes:
            buckets.append(cur)
            cur, size = [], 0
        cur.append((i, leaf))
        size += b
    if cur:
        buckets.append(cur)
    return buckets
