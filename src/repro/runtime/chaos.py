"""Chaos hooks: controlled fault injection for the serving runtime.

The robustness contract of the serving stack ("every submitted ticket
terminates with a result or a typed error, within latency bounds,
while things break") is only testable if the breakage is reproducible.
This module is the single switchboard the runtime consults at its
instrumented points; tests and ``benchmarks/robust_bench.py`` arm it,
production code never does (the hooks are ``None`` and every check is
one attribute load on the happy path).

Injectable fault classes
------------------------

* **worker stalls** — ``stall_worker(wid, seconds)``: the next batch
  that worker picks up hangs mid-execution *without heartbeating*,
  exactly like a wedged kernel; the pool's ``FaultMonitor`` must detect
  the missed beats, re-dispatch the in-flight batch and recycle the
  worker.
* **plan poisoning** — ``poison_plan(model, times=N)``: the model's
  compiled-replay execution raises (``PlanError`` by default, or any
  error you pass, e.g. a transient one) for the next N batches.  Drives
  the retry path, the per-model circuit breaker and the degradation to
  the interpretive oracle engine.
* **artifact corruption** — ``corrupt_artifacts(times=N)``: the program
  cache's disk tier raises ``ArtifactError`` on read, exercising the
  reject-and-recompile path (never silently replay a bad artifact).
* **clock skew** — ``skew_clock(seconds)``: shifts the serving
  runtime's deadline clock (``now()``), expiring queued tickets the way
  an NTP step or a suspended VM does.
* **worker murder** — ``kill_worker(wid, mode)`` /
  ``oom_worker(wid)``: the next batch dispatched to that worker's
  *process* (``repro.runtime.procpool.ProcPool``) dies mid-flight —
  ``"kill"`` SIGKILLs from the parent mid-compute, ``"segv"`` trips a
  child-side SIGSEGV crash trampoline, ``"oom"`` aborts the child with
  the OOM-killed exit status.  ``worker_id=-1`` murders whichever
  worker dispatches next.  Exercises crash detection, in-flight
  re-dispatch and off-request-path respawn (zero ticket loss).
* **pool murder** — ``kill_pool(replica)``: the fleet router
  (``repro.runtime.fleet``) tears the whole replica pool down — every
  worker lost at once, the host-death fault class.  Queued attempts
  fail ``WorkerLost`` and the router re-homes them on the surviving
  replicas with bounded backoff (zero ticket loss).
* **frame corruption** — ``corrupt_frames(times=N)``: flips one bit in
  the blob payload of the next N process-pool data frames on the
  parent's receive path.  The frame's CRC32 must catch it, fail only
  that batch with a typed ``FrameCorrupt`` and re-dispatch — never
  recycle the stream.
* **silent output corruption** — ``corrupt_output(model, times=N,
  tag=...)``: the tagged session serves *wrong bytes* for the model's
  next N batches without any error — the bit-flip fault class that
  only an end-to-end audit (the fleet's interp-oracle re-execution
  sampler) can catch.
* **artifact-swap corruption** — ``corrupt_canary(model, times=N)``:
  the next rolling-update canary for the model sees corrupted plan
  outputs; ``Fleet.update`` must reject the swap and roll back.

Usage::

    with chaos.inject() as c:
        c.poison_plan("mobilenet_v2", times=5)
        ...                       # serve traffic; watch it degrade
    # hooks disarmed, counters in c.stats()
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, Optional


class ChaosError(RuntimeError):
    """Default error raised by armed plan-poisoning hooks."""


class TransientChaosError(ChaosError):
    """A chaos error the serving retry policy treats as transient."""


class Chaos:
    """One armed fault schedule.  All mutators and probes are
    thread-safe (the serving pool probes from worker threads)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._stalls: Dict[int, float] = {}       # worker id -> seconds
        self._plan_faults: Dict[str, list] = {}   # model -> [err, ...]
        self._artifact_faults = 0
        self._kills: Dict[int, str] = {}          # worker id -> mode
        self._skew_s = 0.0
        self._pool_kills: list = []               # fleet replica ids
        self._frame_faults = 0
        #: (model, session tag or None) -> remaining silent corruptions
        self._output_faults: Dict[tuple, int] = {}
        self._canary_faults: Dict[str, int] = {}  # model -> remaining
        self.injected = {"stalls": 0, "plan_faults": 0,
                         "artifact_faults": 0, "kills": 0,
                         "pool_kills": 0, "frame_flips": 0,
                         "output_flips": 0, "canary_corruptions": 0}

    # -- arming (tests / benchmarks) ----------------------------------------
    def stall_worker(self, worker_id: int, seconds: float) -> None:
        """The next batch this worker claims stalls for ``seconds``
        without heartbeating (one-shot)."""
        with self._lock:
            self._stalls[int(worker_id)] = float(seconds)

    def poison_plan(self, model: str, error: Optional[Exception] = None,
                    times: int = 1) -> None:
        """The model's next ``times`` plan executions raise ``error``
        (fresh ``ChaosError`` instances by default)."""
        with self._lock:
            q = self._plan_faults.setdefault(model, [])
            q.extend([error] * times)

    def corrupt_artifacts(self, times: int = 1) -> None:
        """The next ``times`` disk-tier artifact reads fail."""
        with self._lock:
            self._artifact_faults += int(times)

    def kill_worker(self, worker_id: int, mode: str = "kill") -> None:
        """Murder the worker *process* during its next dispatched
        batch (one-shot).  ``mode``: ``"kill"`` = parent-side SIGKILL
        mid-compute; ``"segv"`` = child-side SIGSEGV crash trampoline;
        ``"oom"`` = child aborts with exit status 137.
        ``worker_id=-1`` targets whichever worker dispatches next."""
        if mode not in ("kill", "segv", "oom"):
            raise ValueError(f"unknown kill mode {mode!r}")
        with self._lock:
            self._kills[int(worker_id)] = mode

    def oom_worker(self, worker_id: int) -> None:
        """The worker process aborts as if the OOM killer took it."""
        self.kill_worker(worker_id, mode="oom")

    def skew_clock(self, seconds: float) -> None:
        """Shift the serving deadline clock by ``seconds`` (cumulative;
        positive = forward, expiring pending deadlines)."""
        with self._lock:
            self._skew_s += float(seconds)

    def kill_pool(self, replica: int) -> None:
        """Mark a whole fleet replica pool for death: the fleet router
        consumes the arm on its next tick and tears the replica's pool
        down (every worker lost at once — the host-death fault)."""
        with self._lock:
            self._pool_kills.append(int(replica))

    def corrupt_frames(self, times: int = 1) -> None:
        """Flip one bit in the blob payload of the next ``times``
        process-pool data frames on the parent's receive path."""
        with self._lock:
            self._frame_faults += int(times)

    def corrupt_output(self, model: str, times: int = 1,
                       tag: Optional[str] = None) -> None:
        """The tagged session (``Session(tag=...)``; ``tag=None``
        matches any session) silently serves perturbed outputs for the
        model's next ``times`` batches — no error raised, nothing trips
        a breaker.  Only an end-to-end audit catches it."""
        with self._lock:
            key = (model, tag)
            self._output_faults[key] = \
                self._output_faults.get(key, 0) + int(times)

    def corrupt_canary(self, model: str, times: int = 1) -> None:
        """The model's next ``times`` rolling-update canary runs see
        corrupted plan outputs (a bad artifact swap); ``Fleet.update``
        must reject the swap and roll back."""
        with self._lock:
            self._canary_faults[model] = \
                self._canary_faults.get(model, 0) + int(times)

    # -- probes (the serving runtime) ---------------------------------------
    def maybe_stall_s(self, worker_id: int) -> float:
        """Seconds this worker must hang right now (0.0 = healthy);
        consuming the one-shot stall."""
        with self._lock:
            s = self._stalls.pop(int(worker_id), 0.0)
            if s:
                self.injected["stalls"] += 1
            return s

    def check_plan(self, model: str) -> None:
        """Raise the model's next scheduled plan fault, if any."""
        with self._lock:
            q = self._plan_faults.get(model)
            if not q:
                return
            err = q.pop(0)
            self.injected["plan_faults"] += 1
        raise err if err is not None else ChaosError(
            f"chaos: poisoned plan for {model!r}")

    def maybe_kill(self, worker_id: int) -> Optional[str]:
        """The kill mode armed for this worker's next batch (or for any
        worker via the -1 wildcard), consuming the one-shot fault."""
        with self._lock:
            m = self._kills.pop(int(worker_id), None)
            if m is None:
                m = self._kills.pop(-1, None)
            if m is not None:
                self.injected["kills"] += 1
            return m

    def check_artifact(self, path: str) -> None:
        """Raise ``ArtifactError`` if an artifact-read fault is armed."""
        with self._lock:
            if self._artifact_faults <= 0:
                return
            self._artifact_faults -= 1
            self.injected["artifact_faults"] += 1
        from repro.core.serialize import ArtifactError
        raise ArtifactError(f"chaos: corrupted artifact {path}")

    def take_pool_kills(self) -> list:
        """Drain (and count) every armed replica-pool kill."""
        with self._lock:
            kills, self._pool_kills = self._pool_kills, []
            self.injected["pool_kills"] += len(kills)
            return kills

    def maybe_flip_frame(self, buf: bytes) -> bytes:
        """Flip one bit in a pipe frame's blob payload if a frame fault
        is armed.  Frames without a blob payload (heartbeats, ready
        acks) pass through unconsumed — the fault targets data frames,
        whose CRC failure is attributable to one pending batch."""
        import struct as _struct
        if len(buf) < 12:
            return buf
        (hlen,) = _struct.unpack_from("<I", buf, 4)
        blob_off = 12 + hlen
        if len(buf) <= blob_off:
            return buf             # headers-only frame: not a target
        with self._lock:
            if self._frame_faults <= 0:
                return buf
            self._frame_faults -= 1
            self.injected["frame_flips"] += 1
        b = bytearray(buf)
        b[blob_off] ^= 0x40
        return bytes(b)

    def maybe_corrupt_output(self, model: str,
                             tag: Optional[str] = None) -> bool:
        """Consume one armed silent-output corruption for this
        (model, session tag) — exact tag match first, then the
        ``tag=None`` wildcard."""
        with self._lock:
            for key in ((model, tag), (model, None)):
                n = self._output_faults.get(key, 0)
                if n > 0:
                    self._output_faults[key] = n - 1
                    self.injected["output_flips"] += 1
                    return True
            return False

    def check_canary(self, model: str) -> bool:
        """Consume one armed canary corruption for this model."""
        with self._lock:
            n = self._canary_faults.get(model, 0)
            if n > 0:
                self._canary_faults[model] = n - 1
                self.injected["canary_corruptions"] += 1
                return True
            return False

    def now(self) -> float:
        with self._lock:
            return time.monotonic() + self._skew_s

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.injected)


def flip_outputs(out: Dict[str, object]) -> Dict[str, object]:
    """Silently perturb one element of one output array — the
    bit-flip-class corruption a CRC can't see (it happens *before*
    serialization) and only an end-to-end interp-oracle audit catches.
    Returns a new dict; the input arrays are never mutated."""
    import numpy as np
    bad = dict(out)
    for k in sorted(bad):
        v = np.asarray(bad[k])
        if not v.size:
            continue
        w = v.copy()
        flat = w.reshape(-1)
        flat[0] = flat[0] + (1e3 if w.dtype.kind == "f" else 64)
        bad[k] = w
        return bad
    return bad


#: the armed schedule, or None (production).  Runtime code reads this
#: once per probe point; ``inject()`` installs/disarms it.
_ACTIVE: Optional[Chaos] = None


def active() -> Optional[Chaos]:
    return _ACTIVE


def now() -> float:
    """The serving runtime's deadline clock: monotonic time plus any
    injected skew.  This is the only clock deadline logic may use."""
    c = _ACTIVE
    return time.monotonic() if c is None else c.now()


@contextmanager
def inject():
    """Arm a fresh fault schedule for the duration of the block (also
    hooks the program cache's disk tier so ``corrupt_artifacts`` works
    without the core layer importing this module)."""
    global _ACTIVE
    from repro.core import pipeline
    c = Chaos()
    prev, _ACTIVE = _ACTIVE, c
    prev_hook = pipeline.set_disk_read_hook(c.check_artifact)
    try:
        yield c
    finally:
        _ACTIVE = prev
        pipeline.set_disk_read_hook(prev_hook)
