"""Chaos hooks: controlled fault injection for the serving runtime.

The robustness contract of the serving stack ("every submitted ticket
terminates with a result or a typed error, within latency bounds,
while things break") is only testable if the breakage is reproducible.
This module is the single switchboard the runtime consults at its
instrumented points; tests and ``benchmarks/robust_bench.py`` arm it,
production code never does (the hooks are ``None`` and every check is
one attribute load on the happy path).

Injectable fault classes
------------------------

* **worker stalls** — ``stall_worker(wid, seconds)``: the next batch
  that worker picks up hangs mid-execution *without heartbeating*,
  exactly like a wedged kernel; the pool's ``FaultMonitor`` must detect
  the missed beats, re-dispatch the in-flight batch and recycle the
  worker.
* **plan poisoning** — ``poison_plan(model, times=N)``: the model's
  compiled-replay execution raises (``PlanError`` by default, or any
  error you pass, e.g. a transient one) for the next N batches.  Drives
  the retry path, the per-model circuit breaker and the degradation to
  the interpretive oracle engine.
* **artifact corruption** — ``corrupt_artifacts(times=N)``: the program
  cache's disk tier raises ``ArtifactError`` on read, exercising the
  reject-and-recompile path (never silently replay a bad artifact).
* **clock skew** — ``skew_clock(seconds)``: shifts the serving
  runtime's deadline clock (``now()``), expiring queued tickets the way
  an NTP step or a suspended VM does.
* **worker murder** — ``kill_worker(wid, mode)`` /
  ``oom_worker(wid)``: the next batch dispatched to that worker's
  *process* (``repro.runtime.procpool.ProcPool``) dies mid-flight —
  ``"kill"`` SIGKILLs from the parent mid-compute, ``"segv"`` trips a
  child-side SIGSEGV crash trampoline, ``"oom"`` aborts the child with
  the OOM-killed exit status.  ``worker_id=-1`` murders whichever
  worker dispatches next.  Exercises crash detection, in-flight
  re-dispatch and off-request-path respawn (zero ticket loss).

Usage::

    with chaos.inject() as c:
        c.poison_plan("mobilenet_v2", times=5)
        ...                       # serve traffic; watch it degrade
    # hooks disarmed, counters in c.stats()
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, Optional


class ChaosError(RuntimeError):
    """Default error raised by armed plan-poisoning hooks."""


class TransientChaosError(ChaosError):
    """A chaos error the serving retry policy treats as transient."""


class Chaos:
    """One armed fault schedule.  All mutators and probes are
    thread-safe (the serving pool probes from worker threads)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._stalls: Dict[int, float] = {}       # worker id -> seconds
        self._plan_faults: Dict[str, list] = {}   # model -> [err, ...]
        self._artifact_faults = 0
        self._kills: Dict[int, str] = {}          # worker id -> mode
        self._skew_s = 0.0
        self.injected = {"stalls": 0, "plan_faults": 0,
                         "artifact_faults": 0, "kills": 0}

    # -- arming (tests / benchmarks) ----------------------------------------
    def stall_worker(self, worker_id: int, seconds: float) -> None:
        """The next batch this worker claims stalls for ``seconds``
        without heartbeating (one-shot)."""
        with self._lock:
            self._stalls[int(worker_id)] = float(seconds)

    def poison_plan(self, model: str, error: Optional[Exception] = None,
                    times: int = 1) -> None:
        """The model's next ``times`` plan executions raise ``error``
        (fresh ``ChaosError`` instances by default)."""
        with self._lock:
            q = self._plan_faults.setdefault(model, [])
            q.extend([error] * times)

    def corrupt_artifacts(self, times: int = 1) -> None:
        """The next ``times`` disk-tier artifact reads fail."""
        with self._lock:
            self._artifact_faults += int(times)

    def kill_worker(self, worker_id: int, mode: str = "kill") -> None:
        """Murder the worker *process* during its next dispatched
        batch (one-shot).  ``mode``: ``"kill"`` = parent-side SIGKILL
        mid-compute; ``"segv"`` = child-side SIGSEGV crash trampoline;
        ``"oom"`` = child aborts with exit status 137.
        ``worker_id=-1`` targets whichever worker dispatches next."""
        if mode not in ("kill", "segv", "oom"):
            raise ValueError(f"unknown kill mode {mode!r}")
        with self._lock:
            self._kills[int(worker_id)] = mode

    def oom_worker(self, worker_id: int) -> None:
        """The worker process aborts as if the OOM killer took it."""
        self.kill_worker(worker_id, mode="oom")

    def skew_clock(self, seconds: float) -> None:
        """Shift the serving deadline clock by ``seconds`` (cumulative;
        positive = forward, expiring pending deadlines)."""
        with self._lock:
            self._skew_s += float(seconds)

    # -- probes (the serving runtime) ---------------------------------------
    def maybe_stall_s(self, worker_id: int) -> float:
        """Seconds this worker must hang right now (0.0 = healthy);
        consuming the one-shot stall."""
        with self._lock:
            s = self._stalls.pop(int(worker_id), 0.0)
            if s:
                self.injected["stalls"] += 1
            return s

    def check_plan(self, model: str) -> None:
        """Raise the model's next scheduled plan fault, if any."""
        with self._lock:
            q = self._plan_faults.get(model)
            if not q:
                return
            err = q.pop(0)
            self.injected["plan_faults"] += 1
        raise err if err is not None else ChaosError(
            f"chaos: poisoned plan for {model!r}")

    def maybe_kill(self, worker_id: int) -> Optional[str]:
        """The kill mode armed for this worker's next batch (or for any
        worker via the -1 wildcard), consuming the one-shot fault."""
        with self._lock:
            m = self._kills.pop(int(worker_id), None)
            if m is None:
                m = self._kills.pop(-1, None)
            if m is not None:
                self.injected["kills"] += 1
            return m

    def check_artifact(self, path: str) -> None:
        """Raise ``ArtifactError`` if an artifact-read fault is armed."""
        with self._lock:
            if self._artifact_faults <= 0:
                return
            self._artifact_faults -= 1
            self.injected["artifact_faults"] += 1
        from repro.core.serialize import ArtifactError
        raise ArtifactError(f"chaos: corrupted artifact {path}")

    def now(self) -> float:
        with self._lock:
            return time.monotonic() + self._skew_s

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.injected)


#: the armed schedule, or None (production).  Runtime code reads this
#: once per probe point; ``inject()`` installs/disarms it.
_ACTIVE: Optional[Chaos] = None


def active() -> Optional[Chaos]:
    return _ACTIVE


def now() -> float:
    """The serving runtime's deadline clock: monotonic time plus any
    injected skew.  This is the only clock deadline logic may use."""
    c = _ACTIVE
    return time.monotonic() if c is None else c.now()


@contextmanager
def inject():
    """Arm a fresh fault schedule for the duration of the block (also
    hooks the program cache's disk tier so ``corrupt_artifacts`` works
    without the core layer importing this module)."""
    global _ACTIVE
    from repro.core import pipeline
    c = Chaos()
    prev, _ACTIVE = _ACTIVE, c
    prev_hook = pipeline.set_disk_read_hook(c.check_artifact)
    try:
        yield c
    finally:
        _ACTIVE = prev
        pipeline.set_disk_read_hook(prev_hook)
