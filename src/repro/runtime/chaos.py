"""Chaos hooks: controlled fault injection for the serving runtime.

The robustness contract of the serving stack ("every submitted ticket
terminates with a result or a typed error, within latency bounds,
while things break") is only testable if the breakage is reproducible.
This module is the single switchboard the runtime consults at its
instrumented points; tests and ``benchmarks/robust_bench.py`` arm it,
production code never does (the hooks are ``None`` and every check is
one attribute load on the happy path).

Injectable fault classes
------------------------

* **worker stalls** — ``stall_worker(wid, seconds)``: the next batch
  that worker picks up hangs mid-execution *without heartbeating*,
  exactly like a wedged kernel; the pool's ``FaultMonitor`` must detect
  the missed beats, re-dispatch the in-flight batch and recycle the
  worker.
* **plan poisoning** — ``poison_plan(model, times=N)``: the model's
  compiled-replay execution raises (``PlanError`` by default, or any
  error you pass, e.g. a transient one) for the next N batches.  Drives
  the retry path, the per-model circuit breaker and the degradation to
  the interpretive oracle engine.
* **artifact corruption** — ``corrupt_artifacts(times=N)``: the program
  cache's disk tier raises ``ArtifactError`` on read, exercising the
  reject-and-recompile path (never silently replay a bad artifact).
* **clock skew** — ``skew_clock(seconds)``: shifts the serving
  runtime's deadline clock (``now()``), expiring queued tickets the way
  an NTP step or a suspended VM does.

Usage::

    with chaos.inject() as c:
        c.poison_plan("mobilenet_v2", times=5)
        ...                       # serve traffic; watch it degrade
    # hooks disarmed, counters in c.stats()
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, Optional


class ChaosError(RuntimeError):
    """Default error raised by armed plan-poisoning hooks."""


class TransientChaosError(ChaosError):
    """A chaos error the serving retry policy treats as transient."""


class Chaos:
    """One armed fault schedule.  All mutators and probes are
    thread-safe (the serving pool probes from worker threads)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._stalls: Dict[int, float] = {}       # worker id -> seconds
        self._plan_faults: Dict[str, list] = {}   # model -> [err, ...]
        self._artifact_faults = 0
        self._skew_s = 0.0
        self.injected = {"stalls": 0, "plan_faults": 0,
                         "artifact_faults": 0}

    # -- arming (tests / benchmarks) ----------------------------------------
    def stall_worker(self, worker_id: int, seconds: float) -> None:
        """The next batch this worker claims stalls for ``seconds``
        without heartbeating (one-shot)."""
        with self._lock:
            self._stalls[int(worker_id)] = float(seconds)

    def poison_plan(self, model: str, error: Optional[Exception] = None,
                    times: int = 1) -> None:
        """The model's next ``times`` plan executions raise ``error``
        (fresh ``ChaosError`` instances by default)."""
        with self._lock:
            q = self._plan_faults.setdefault(model, [])
            q.extend([error] * times)

    def corrupt_artifacts(self, times: int = 1) -> None:
        """The next ``times`` disk-tier artifact reads fail."""
        with self._lock:
            self._artifact_faults += int(times)

    def skew_clock(self, seconds: float) -> None:
        """Shift the serving deadline clock by ``seconds`` (cumulative;
        positive = forward, expiring pending deadlines)."""
        with self._lock:
            self._skew_s += float(seconds)

    # -- probes (the serving runtime) ---------------------------------------
    def maybe_stall_s(self, worker_id: int) -> float:
        """Seconds this worker must hang right now (0.0 = healthy);
        consuming the one-shot stall."""
        with self._lock:
            s = self._stalls.pop(int(worker_id), 0.0)
            if s:
                self.injected["stalls"] += 1
            return s

    def check_plan(self, model: str) -> None:
        """Raise the model's next scheduled plan fault, if any."""
        with self._lock:
            q = self._plan_faults.get(model)
            if not q:
                return
            err = q.pop(0)
            self.injected["plan_faults"] += 1
        raise err if err is not None else ChaosError(
            f"chaos: poisoned plan for {model!r}")

    def check_artifact(self, path: str) -> None:
        """Raise ``ArtifactError`` if an artifact-read fault is armed."""
        with self._lock:
            if self._artifact_faults <= 0:
                return
            self._artifact_faults -= 1
            self.injected["artifact_faults"] += 1
        from repro.core.serialize import ArtifactError
        raise ArtifactError(f"chaos: corrupted artifact {path}")

    def now(self) -> float:
        with self._lock:
            return time.monotonic() + self._skew_s

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.injected)


#: the armed schedule, or None (production).  Runtime code reads this
#: once per probe point; ``inject()`` installs/disarms it.
_ACTIVE: Optional[Chaos] = None


def active() -> Optional[Chaos]:
    return _ACTIVE


def now() -> float:
    """The serving runtime's deadline clock: monotonic time plus any
    injected skew.  This is the only clock deadline logic may use."""
    c = _ACTIVE
    return time.monotonic() if c is None else c.now()


@contextmanager
def inject():
    """Arm a fresh fault schedule for the duration of the block (also
    hooks the program cache's disk tier so ``corrupt_artifacts`` works
    without the core layer importing this module)."""
    global _ACTIVE
    from repro.core import pipeline
    c = Chaos()
    prev, _ACTIVE = _ACTIVE, c
    prev_hook = pipeline.set_disk_read_hook(c.check_artifact)
    try:
        yield c
    finally:
        _ACTIVE = prev
        pipeline.set_disk_read_hook(prev_hook)
