"""Serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b \
        --smoke --batch 4 --prompt-len 32 --gen 16

Demonstrates the inference path every decode cell of the dry-run lowers:
jit'd ``serve_step`` (one token for the whole batch against the cache),
greedy sampling, and per-arch cache handling (KV / MLA latent / SSD
state / ring buffers).  Prefill here replays tokens through decode steps
(identical math; the dry-run's prefill cell lowers the fused
full-sequence path).
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.registry import get_arch
from .mesh import make_mesh, use_mesh


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          smoke: bool = True, seed: int = 0, max_len: Optional[int] = None):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    max_len = max_len or (prompt_len + gen)
    mesh = make_mesh(1, 1)

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len),

                           ).astype(np.int32)

    aux = None
    extra = {}
    if cfg.enc_dec:
        audio = rng.normal(size=(batch, cfg.n_audio_frames,
                                 cfg.d_model)).astype(np.float32)
        extra["audio_embed"] = audio
    if cfg.family == "vlm":
        extra["vision_embed"] = rng.normal(
            size=(batch, cfg.n_vision_tokens,
                  cfg.d_model)).astype(np.float32)

    with use_mesh(mesh):
        params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        if cfg.enc_dec:
            enc = lm.encode_audio(cfg, params, extra["audio_embed"])
            aux = {"enc_states": enc,
                   "cross_kv": lm.cross_kv(cfg, params, enc)}
        cache = lm.init_cache(cfg, batch, max_len)

        @jax.jit
        def step(params, cache, token, pos):
            return lm.decode_step(cfg, params, cache, token, pos, aux=aux)

        # prefill by replaying the prompt (teacher-forced decode)
        t0 = time.monotonic()
        tok = None
        for t in range(prompt_len):
            logits, cache = step(params, cache, prompts[:, t],
                                 jnp.int32(t))
        t_prefill = time.monotonic() - t0

        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t0 = time.monotonic()
        for t in range(prompt_len, prompt_len + gen):
            out.append(np.asarray(tok))
            logits, cache = step(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_decode = time.monotonic() - t0

    gen_tokens = np.stack(out, axis=1)
    print(f"prefill {prompt_len} toks x {batch} streams: "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode  {gen} toks x {batch} streams: {t_decode*1e3:.1f} ms "
          f"({gen*batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample generations (first stream):", gen_tokens[0][:12])
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen=args.gen, smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
