"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so
importing this module never touches jax device state — only the dry-run
entrypoint forces the 512-device host platform.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def use_mesh(mesh):
    """Context manager activating `mesh` across jax versions.

    ``jax.set_mesh`` only exists in newer jax; on older releases the
    Mesh object itself is the context manager that installs the
    resource environment.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def named_shardings(mesh, tree):
    """Convert a pytree of PartitionSpec / None into NamedShardings.

    Older ``jax.jit`` rejects bare PartitionSpecs (and None subtree
    markers) in in/out_shardings; NamedSharding works on every version.
    None maps to the replicated sharding.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    def conv(s):
        if s is None:
            return NamedSharding(mesh, PartitionSpec())
        if isinstance(s, PartitionSpec):
            return NamedSharding(mesh, s)
        return s

    return jax.tree_util.tree_map(
        conv, tree,
        is_leaf=lambda x: x is None or isinstance(x, PartitionSpec))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(n_data: int, n_model: int, n_pod: int = 1):
    """Explicit mesh for tests / elastic re-mesh."""
    if n_pod > 1:
        return jax.make_mesh((n_pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def single_device_mesh():
    """1x1 mesh for CPU unit tests (specs resolve, collectives no-op)."""
    return jax.make_mesh((1, 1), ("data", "model"))
