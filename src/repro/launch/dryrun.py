"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces the *compiled* SPMD artifact for the
production mesh — 16x16 = 256 chips per pod, and 2x16x16 = 512 chips
across two pods — proving the distribution config is coherent:
shardings consistent, collectives lowerable, memory per chip reported.
No arrays are allocated: inputs are ShapeDtypeStruct and parameters are
``jax.eval_shape`` trees.

Artifacts (memory analysis, cost analysis, collective-byte breakdown,
roofline terms) are cached as JSON under ``experiments/dryrun/`` so the
benchmarks and EXPERIMENTS.md tables re-read them without recompiling.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
        --shape train_4k [--multi-pod] [--all] [--out experiments/dryrun]
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (even transitively
# via repro modules): jax locks the device count at first backend init.

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.analysis import roofline as rl
from repro.analysis.hlo import analyze_hlo
from repro.models.registry import (ARCH_IDS, SHAPES, build_step, cells,
                                   get_arch)
from .mesh import make_production_mesh, named_shardings, use_mesh


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             out_dir: str = "experiments/dryrun",
             overrides: Optional[Dict] = None,
             tag: str = "") -> Dict:
    """Lower+compile one cell; returns (and caches) the artifact dict."""
    import dataclasses
    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    ss = SHAPES[shape]

    t0 = time.monotonic()
    bundle = build_step(cfg, shape, with_pod=multi_pod)
    with use_mesh(mesh):
        jitted = jax.jit(
            bundle.fn,
            in_shardings=named_shardings(mesh, bundle.in_specs),
            out_shardings=named_shardings(mesh, bundle.out_specs),
            donate_argnums=bundle.donate or (),
        )
        lowered = jitted.lower(*bundle.args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) \
        else (cost_list or {})
    hlo = compiled.as_text()
    # trip-count-corrected flops/bytes/collectives (XLA's cost_analysis
    # counts while bodies once — see analysis/hlo.py)
    hc = analyze_hlo(hlo)

    mem_d = {}
    per_chip_bytes = 0.0
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
        per_chip_bytes = (mem_d.get("argument_size_in_bytes", 0)
                          - mem_d.get("alias_size_in_bytes", 0)
                          + mem_d.get("output_size_in_bytes", 0)
                          + mem_d.get("temp_size_in_bytes", 0))

    roof = rl.build_roofline(
        arch=arch, shape=shape, mesh_name=mesh_name, chips=chips,
        flops_per_chip=hc.flops, bytes_per_chip=hc.bytes,
        wire_bytes_per_chip=hc.wire_bytes,
        model_flops=rl.model_flops_for(cfg, ss),
        collectives=hc.collective_bytes,
        memory_per_chip=per_chip_bytes,
    )

    art = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "multi_pod": multi_pod, "tag": tag,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory_analysis": mem_d,
        "per_chip_bytes": per_chip_bytes,
        "xla_cost_analysis": {k: float(v) for k, v in dict(cost).items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "transcendentals",
                                        "optimal_seconds")},
        "roofline": roof.to_json(),
        "collective_ops": roof.collectives,
        "collective_counts": dict(hc.collective_count),
        "max_trip": hc.max_trip,
        "hlo_bytes": len(hlo),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}_{shape}_{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for this mesh")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        for a in ARCH_IDS:
            for s in cells(get_arch(a)):
                todo.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for a, s in todo:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        path = os.path.join(args.out, f"{a}_{s}_{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {a} x {s} ({mesh_name})")
            continue
        try:
            t0 = time.monotonic()
            art = run_cell(a, s, multi_pod=args.multi_pod,
                           out_dir=args.out)
            r = art["roofline"]
            print(f"[ok]   {a:22s} {s:12s} {mesh_name:8s} "
                  f"compile={art['compile_s']:6.1f}s "
                  f"hbm={art['per_chip_bytes']/1e9:7.2f}GB "
                  f"bound={r['bottleneck']:10s} "
                  f"roofline={r['peak_fraction']*100:5.1f}%",
                  flush=True)
            print("  memory_analysis:", art["memory_analysis"])
            print("  cost_analysis: flops/chip=%.3e bytes/chip=%.3e"
                  % (r["flops_per_chip"], r["bytes_per_chip"]))
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"[FAIL] {a} x {s}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        return 1
    print("\nall cells compiled clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
