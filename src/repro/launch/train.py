"""Training driver: data pipeline -> jit train_step -> checkpoints,
with fault-tolerant restart and elastic re-mesh.

End-to-end example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \
        --smoke --steps 30 --ckpt-dir /tmp/ckpt

On a real pod the same driver runs under `jax.distributed.initialize()`
with the production mesh; here the mesh defaults to every local device.
The loop demonstrates the full production posture: deterministic
per-step data, async checkpointing every K steps, restart-from-latest,
heartbeat + straggler telemetry, and (optionally) microbatched gradient
accumulation with cross-pod int8 gradient compression.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace
from functools import partial
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import lm
from repro.models.registry import get_arch, state_specs
from repro.models.train import (TrainOptions, init_train_state,
                                make_train_step)
from repro.runtime.fault import FaultMonitor
from .mesh import make_mesh, named_shardings, use_mesh


def train_loop(arch: str, steps: int = 30, smoke: bool = True,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
               seq_len: int = 128, global_batch: int = 8,
               n_micro: int = 1, compress: bool = False,
               n_data: Optional[int] = None, n_model: Optional[int] = None,
               log_every: int = 5, seed: int = 0):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    nd = jax.device_count()
    n_model = n_model or 1
    n_data = n_data or (nd // n_model)
    mesh = make_mesh(n_data, n_model)

    opts = TrainOptions(n_micro=n_micro, compress_grads=compress,
                        total_steps=max(steps, 2))
    step_fn = make_train_step(cfg, opts=opts)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    pipe = Pipeline(dcfg)
    monitor = FaultMonitor(n_hosts=1)

    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0

    with use_mesh(mesh):
        state = init_train_state(cfg, jax.random.PRNGKey(seed), opts=opts)
        if ckpt is not None and ckpt.latest_step() is not None:
            state, start_step, meta = ckpt.restore(state)
            import jax.numpy as jnp
            state = jax.tree_util.tree_map(jnp.asarray, state)
            print(f"[restore] resumed from step {start_step}")
            # fast-forward the data pipeline deterministically
            pipe.close()
            pipe = Pipeline(dcfg, start_step=start_step)

        sspec = named_shardings(mesh, state_specs(cfg, state,
                                                  n_model=n_model))
        repl = named_shardings(mesh, None)
        jitted = jax.jit(step_fn, in_shardings=(sspec, repl),
                         out_shardings=(sspec, repl),
                         donate_argnums=(0,))
        losses = []
        for i in range(start_step, steps):
            t0 = time.monotonic()
            batch = next(pipe)
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            monitor.beat(0, i, dt)
            losses.append(loss)
            if i % log_every == 0 or i == steps - 1:
                print(f"step {i:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics['grad_norm']):8.3f}  "
                      f"{dt*1e3:7.1f} ms", flush=True)
            if ckpt is not None and (i + 1) % ckpt_every == 0:
                ckpt.save_async(i + 1, state, meta={"loss": loss})
        if ckpt is not None and losses:
            ckpt.wait()
            ckpt.save(steps, state, meta={"loss": losses[-1]})
    pipe.close()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    losses = train_loop(args.arch, steps=args.steps, smoke=args.smoke,
                        ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every,
                        seq_len=args.seq_len,
                        global_batch=args.global_batch,
                        n_micro=args.n_micro, compress=args.compress,
                        seed=args.seed)
    if losses:
        print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    else:
        print("nothing to do (checkpoint already at target step)")


if __name__ == "__main__":
    main()
