"""Functional banked-TCM simulator.

Replays a compiled :class:`NPUProgram` tick by tick against real tensor
data and asserts that the compiler's output is *correct*, not just fast:

  * every compute input is resident in TCM when used (Eq. 2),
  * tiles only enter TCM via fetch/compute and leave via push/death
    (Eq. 1 persistency),
  * banks are never double-held (allocation property d),
  * model outputs land in DRAM bit-identical (float32 tolerance) to the
    pure-numpy :func:`repro.core.ir.reference_execute` oracle.

This is the repro analogue of running the compiled binary on silicon.

It is the *validating* replay and the oracle the deployment-speed
engine is checked against: :mod:`repro.core.execplan` lowers the same
program once into a batch-vectorized :class:`ExecPlan` (no per-request
bookkeeping) whose outputs must match this executor bit for bit
(float32) or to the stored integer (int8/int4).
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
from numpy.lib.stride_tricks import sliding_window_view

from .ir import (Graph, Op, _apply_act, _attention_ref, _conv2d_ref,
                 _kvappend_ref, _layernorm_ref, _matmul_ref, _softmax_ref,
                 reference_execute)
from .program import NPUProgram, TileRef
from .tiling import TilingResult, in_row_range


class ExecutionError(RuntimeError):
    pass


@dataclass
class ExecutionReport:
    """Outcome of one replay.

    ``ticks`` and ``ddr_bytes`` are **per-request** modeled quantities:
    a batched plan execution (``batch > 1``) reports the schedule's
    fetch/push bytes for *one* request, not the batch aggregate, so
    DDR columns stay comparable across executors and batch sizes."""

    outputs: Dict[str, np.ndarray]
    max_err: float
    ticks: int
    ddr_bytes: int
    ok: bool = True
    batch: int = 1
    engine: str = "interp"            # "interp" | "plan"


# --------------------------------------------------------------------------
# Row/channel gathering from resident tiles
# --------------------------------------------------------------------------


class _TcmState:
    """Resident-tile store with indexed gathers.

    Tile lists are produced in ascending [r0, r1) order by the tiler, so
    the tiles covering a row/channel range form a contiguous slice found
    by bisection on cached boundary arrays — the replay's hottest path no
    longer scans every tile of a tensor per gather.

    Consecutive steps of the same op request heavily overlapping input
    row windows (stride < kernel height), so assembled windows are
    cached per tensor: a request fully inside the last window is a pure
    slice (no concat), and a request extending it assembles only the new
    rows.  The cache is versioned — any ``put``/``drop`` touching a
    tensor invalidates its window — and residency of the covering tiles
    is still asserted on every gather, so the validator's Eq.-2 check is
    as strict as the uncached path."""

    def __init__(self, g: Graph):
        self.g = g
        self.data: Dict[Tuple[str, int], np.ndarray] = {}
        self.resident: set = set()
        self._bounds: Dict[str, Tuple[List[int], List[int]]] = {}
        #: tensor -> (version, lo, hi, assembled rows [lo, hi))
        self._win: Dict[str, Tuple[int, int, int, np.ndarray]] = {}
        self._ver: Dict[str, int] = {}

    def put(self, tl: TileRef, arr: np.ndarray) -> None:
        self.data[tl.key] = arr
        self.resident.add(tl.key)
        self._ver[tl.tensor] = self._ver.get(tl.tensor, 0) + 1
        self._win.pop(tl.tensor, None)

    def drop(self, key: Tuple[str, int]) -> None:
        self.resident.discard(key)
        self.data.pop(key, None)
        self._ver[key[0]] = self._ver.get(key[0], 0) + 1
        self._win.pop(key[0], None)

    def _covering(self, tt, a: int, b: int) -> List[TileRef]:
        """Tiles (ascending) overlapping [a, b) on the tiled axis."""
        bounds = self._bounds.get(tt.tensor)
        if bounds is None:
            bounds = ([t.r0 for t in tt.tiles], [t.r1 for t in tt.tiles])
            self._bounds[tt.tensor] = bounds
        starts, ends = bounds
        i0 = bisect.bisect_right(ends, a)
        i1 = bisect.bisect_left(starts, b)
        return tt.tiles[i0:i1]

    def _assemble(self, tt, tensor: str, a: int, b: int) -> np.ndarray:
        """Concatenate rows [a, b) from resident tiles (uncached path)."""
        parts = []
        covered = a
        for tl in self._covering(tt, a, b):
            arr = self.data[tl.key]
            lo = max(a, tl.r0)
            hi = min(b, tl.r1)
            if lo != covered:
                raise ExecutionError(
                    f"gap gathering {tensor}[{a}:{b}) at row {covered}")
            parts.append(arr[lo - tl.r0: hi - tl.r0])
            covered = hi
        if covered < b:
            raise ExecutionError(
                f"rows {covered}:{b} of {tensor} missing from TCM")
        return np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]

    def gather_rows(self, tiling: TilingResult, tensor: str,
                    a: int, b: int) -> np.ndarray:
        """Assemble rows [a, b) of `tensor` from resident tiles."""
        tt = tiling.tiles[tensor]
        shape = self.g.tensors[tensor].shape
        if tt.axis == "chan":
            for tl in tt.tiles:
                if tl.key not in self.resident:
                    raise ExecutionError(f"{tl} not resident")
            ver = self._ver.get(tensor, 0)
            cached = self._win.get(tensor)
            if cached is not None and cached[0] == ver:
                full = cached[3]
            else:
                parts = [self.data[tl.key] for tl in tt.tiles]
                full = np.concatenate(parts, axis=-1) if len(parts) > 1 \
                    else parts[0]
                H = shape[0] if len(shape) == 3 else 1
                self._win[tensor] = (ver, 0, H, full)
            return full[a:b] if len(shape) == 3 else full
        # residency is asserted against the *current* tile set even when
        # the window data comes from the cache
        for tl in self._covering(tt, a, b):
            if tl.key not in self.resident:
                raise ExecutionError(f"{tl} not resident")
        ver = self._ver.get(tensor, 0)
        cached = self._win.get(tensor)
        if cached is not None and cached[0] == ver:
            _, lo, hi, arr = cached
            if lo <= a and b <= hi:
                return arr[a - lo: b - lo]
            if lo <= a < hi < b:
                # forward extension: assemble only the new rows
                ext = self._assemble(tt, tensor, hi, b)
                arr = np.concatenate([arr[a - lo:], ext], axis=0)
                self._win[tensor] = (ver, a, b, arr)
                return arr
        arr = self._assemble(tt, tensor, a, b)
        self._win[tensor] = (ver, a, b, arr)
        return arr

    def gather_param(self, tiling: TilingResult, tensor: str,
                     c0: int, c1: int) -> np.ndarray:
        tt = tiling.tiles[tensor]
        if tt.axis != "chan":
            tiles = list(tt.tiles)
        else:
            tiles = self._covering(tt, c0, c1)
        parts = []
        for tl in tiles:
            if tl.key not in self.resident:
                raise ExecutionError(f"param {tl} not resident")
            arr = self.data[tl.key]
            lo, hi = max(c0, tl.r0), min(c1, tl.r1)
            parts.append(arr[lo - tl.r0: hi - tl.r0])
        out = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        if out.shape[0] != c1 - c0:
            raise ExecutionError(f"param {tensor}[{c0}:{c1}) incomplete")
        return out


# --------------------------------------------------------------------------
# Per-step computation (mirrors ir.reference_execute on a row window)
# --------------------------------------------------------------------------


def gather_window(tcm: _TcmState, tiling: TilingResult, x, rr0: int,
                  rr1: int, kh: int, s: int, pt: int
                  ) -> Tuple[np.ndarray, int, int]:
    """Gather the input rows a kh-tall stride-s windowed op (conv/pool)
    needs to produce output rows [rr0, rr1), clipped to the valid input
    range.  Returns (window, top_pad, bottom_pad) — the receptive-field
    math shared by the float and quantized replay paths."""
    ih = x.shape[0]
    u0 = rr0 * s - pt
    u1 = (rr1 - 1) * s - pt + kh
    lo, hi = max(0, u0), min(ih, u1)
    win = tcm.gather_rows(tiling, x.name, lo, hi)
    return win, max(0, -u0), max(0, u1 - ih)


def _run_step(g: Graph, tiling: TilingResult, tcm: _TcmState, op: Op,
              r0: int, r1: int, axis: str) -> Dict[str, np.ndarray]:
    a = op.attrs
    k = op.kind
    out0 = g.tensors[op.outputs[0]]
    H = out0.shape[0] if len(out0.shape) == 3 else 1

    if axis == "chan":
        c0, c1 = r0, r1
        rr0, rr1 = 0, H
    else:
        c0 = 0
        c1 = out0.shape[-1]
        rr0, rr1 = r0, r1

    def rows_of(x, lo, hi):
        return tcm.gather_rows(tiling, x.name, lo, hi)

    if k in ("conv", "dwconv"):
        x = g.act_inputs(op)[0]
        kh = a["k"][0]
        s = a["stride"]
        pt, pb, pl, pr = a["pad"]
        win, top, bot = gather_window(tcm, tiling, x, rr0, rr1, kh, s, pt)
        w = tcm.gather_param(tiling, op.inputs[1], c0, c1)
        if k == "dwconv" and axis == "chan":
            win = win[:, :, c0:c1]
        y = _conv2d_ref(win, w, s, (top, bot, pl, pr), k == "dwconv")
        if len(op.inputs) > 2:
            y = y + tcm.gather_param(tiling, op.inputs[2], c0, c1)
        y = _apply_act(y, a.get("act", "none"))
    elif k == "fc":
        x = g.act_inputs(op)[0]
        xin = rows_of(x, 0, x.shape[0] if len(x.shape) == 3 else 1)
        w = tcm.gather_param(tiling, op.inputs[1], c0, c1)[:, 0, 0, :]
        y = (w @ xin.reshape(-1))
        if len(op.inputs) > 2:
            y = y + tcm.gather_param(tiling, op.inputs[2], c0, c1)
        y = _apply_act(y, a.get("act", "none")).reshape(1, 1, -1)
    elif k == "add":
        xs = [rows_of(x, *in_row_range(op, rr0, rr1,
                                       x.shape[0] if len(x.shape) == 3
                                       else 1))
              for x in g.act_inputs(op)]
        y = _apply_act(xs[0] + xs[1], a.get("act", "none"))
    elif k == "mul":
        xs = []
        for x in g.act_inputs(op):
            ih = x.shape[0] if len(x.shape) == 3 else 1
            lo, hi = in_row_range(op, rr0, rr1, ih)
            xs.append(rows_of(x, lo, hi))
        y = xs[0] * xs[1]
    elif k == "scalar":
        x = rows_of(g.act_inputs(op)[0], rr0, rr1)
        v = a["value"]
        y = {"add": x + v, "mul": x * v, "div": x / v}[a["op"]]
    elif k == "act":
        y = _apply_act(rows_of(g.act_inputs(op)[0], rr0, rr1), a["act"])
    elif k == "maxpool":
        x = g.act_inputs(op)[0]
        kk, s = a["k"], a["stride"]
        pt, pb, pl, pr = a["pad"]
        win, top, bot = gather_window(tcm, tiling, x, rr0, rr1, kk, s, pt)
        xp = np.pad(win, ((top, bot), (pl, pr), (0, 0)),
                    constant_values=-np.inf)
        # batched window reduction (one strided view, no Python loop)
        wins = sliding_window_view(xp, (kk, kk), axis=(0, 1))
        y = wins[::s, ::s].max(axis=(-2, -1))
    elif k == "avgpool":
        x = g.act_inputs(op)[0]
        ih = x.shape[0]
        if a["k"] == 0:
            # canonical layout before the reduction: numpy's pairwise
            # summation blocking follows the array's strides, and a
            # gathered window may be a transposed einsum-output view —
            # the mean must not depend on which tiles the window came
            # from (the compiled replay plan reduces contiguous
            # buffers and is asserted bit-exact against this path)
            win = np.ascontiguousarray(rows_of(x, 0, ih))
            y = win.mean(axis=(0, 1), keepdims=True)
        else:
            kk, s = a["k"], a["stride"]
            pt, pb, pl, pr = a["pad"]
            win, top, bot = gather_window(tcm, tiling, x, rr0, rr1,
                                          kk, s, pt)
            xp = np.pad(win, ((top, bot), (pl, pr), (0, 0)))
            wins = sliding_window_view(xp, (kk, kk), axis=(0, 1))
            y = wins[::s, ::s].sum(axis=(-2, -1), dtype=np.float32) \
                / (kk * kk)
    elif k == "resize":
        f = a["factor"]
        lo, hi = rr0 // f, (rr1 + f - 1) // f
        win = rows_of(g.act_inputs(op)[0], lo, hi)
        y = np.repeat(np.repeat(win, f, axis=0), f, axis=1)
        y = y[rr0 - lo * f: rr1 - lo * f]
    elif k == "concat":
        xs = [rows_of(x, rr0, rr1) for x in g.act_inputs(op)]
        y = np.concatenate(xs, axis=2)
    elif k == "split":
        xin = rows_of(g.act_inputs(op)[0], rr0, rr1)
        parts = np.split(xin, a["sections"], axis=2)
        return {o: p for o, p in zip(op.outputs, parts)}
    elif k == "matmul":
        xin = rows_of(g.act_inputs(op)[0], rr0, rr1)
        w = tcm.gather_param(tiling, op.inputs[1], c0, c1)[:, 0, 0, :]
        b = tcm.gather_param(tiling, op.inputs[2], c0, c1) \
            if len(op.inputs) > 2 else None
        y = _matmul_ref(xin, w, b, a.get("act", "none"))
    elif k == "layernorm":
        xin = rows_of(g.act_inputs(op)[0], rr0, rr1)
        cc = g.tensors[op.inputs[1]].shape[0]
        gamma = tcm.gather_param(tiling, op.inputs[1], 0, cc)
        beta = tcm.gather_param(tiling, op.inputs[2], 0, cc)
        y = _layernorm_ref(xin, gamma, beta, a["eps"])
    elif k == "softmax":
        y = _softmax_ref(rows_of(g.act_inputs(op)[0], rr0, rr1))
    elif k == "attention":
        q, kc, vc, ps = g.act_inputs(op)
        qin = rows_of(q, rr0, rr1)
        kin = rows_of(kc, 0, kc.shape[0])
        vin = rows_of(vc, 0, vc.shape[0])
        pin = rows_of(ps, 0, 1)
        y = _attention_ref(qin, kin, vin, pin, a,
                           q0=rr0, s_total=q.shape[0])
    elif k == "kvappend":
        cache, new, ps = g.act_inputs(op)
        cin = rows_of(cache, 0, cache.shape[0])
        nin = rows_of(new, 0, new.shape[0])
        pin = rows_of(ps, 0, 1)
        y = _kvappend_ref(cin, nin, pin)[rr0:rr1]
    else:  # pragma: no cover
        raise NotImplementedError(k)
    return {op.outputs[0]: y}


# --------------------------------------------------------------------------
# Execution semantics — float32 replay vs quantized replay
# --------------------------------------------------------------------------


class ExecSemantics:
    """Value semantics of one program replay.

    The replay loop (DMA residency, bank ledger, tile gathers) is
    precision-agnostic; this object decides what the *bytes* mean: how
    DRAM is initialized, how one compute step is evaluated on a row
    window, what the functional oracle is, and how outputs are compared
    against it.  The default instance is the float32 path; the int8/int4
    quantized path lives in :mod:`repro.quant.executor`."""

    name = "float32"

    def dram_init(self, g: Graph, inputs: Dict[str, np.ndarray],
                  weights: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        dram: Dict[str, np.ndarray] = {}
        for t in g.tensors.values():
            if t.kind == "input":
                dram[t.name] = np.asarray(inputs[t.name], dtype=np.float32)
            elif t.is_param:
                dram[t.name] = np.asarray(weights[t.name], dtype=np.float32)
        return dram

    def run_step(self, g: Graph, tiling: TilingResult, tcm: "_TcmState",
                 op: Op, r0: int, r1: int, axis: str
                 ) -> Dict[str, np.ndarray]:
        return _run_step(g, tiling, tcm, op, r0, r1, axis)

    def reference(self, g: Graph, inputs: Dict[str, np.ndarray],
                  weights: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return reference_execute(g, inputs, weights)

    def decode(self, tensor: str, arr: np.ndarray) -> np.ndarray:
        """Model-output DRAM bytes -> comparable float values."""
        return arr

    def tolerance(self, tensor: str, want: np.ndarray,
                  atol: float) -> float:
        """Max |got - want| accepted for one output tensor."""
        scale = float(np.max(np.abs(want)) + 1e-6) if want.size else 1.0
        return atol * max(1.0, scale)

    # -- plan lowering hooks (repro.core.execplan) --------------------------
    def plan_lowerer(self):
        """Step-lowering function for :func:`repro.core.execplan.
        lower_plan`.  The float path emits one kernel per program step
        so plan replay is bit-exact with the interpreter."""
        from .execplan import lower_float_steps
        return lower_float_steps

    def plan_dtype(self, tensor) -> np.dtype:
        """Stored dtype of one tensor's arena buffer."""
        return np.dtype(np.float32)

    def encode_input(self, name: str, arr: np.ndarray) -> np.ndarray:
        """Request values -> stored arena values (may be batched)."""
        return np.asarray(arr, dtype=np.float32)

    def plan_parity_tol(self, tensor: str) -> float:
        """Accepted |plan - interpreter| on one decoded output.  The
        float path is bit-exact; quantized semantics allow one step of
        the output quantization grid (rounding-boundary flips)."""
        return 0.0


FLOAT_SEMANTICS = ExecSemantics()


# --------------------------------------------------------------------------
# Program replay
# --------------------------------------------------------------------------


def execute(prog: NPUProgram, g: Graph, tiling: TilingResult,
            inputs: Dict[str, np.ndarray],
            weights: Dict[str, np.ndarray],
            check: bool = True, atol: float = 1e-4,
            semantics: Optional[ExecSemantics] = None) -> ExecutionReport:
    sem = semantics or FLOAT_SEMANTICS
    written: Dict[str, np.ndarray] = {}
    dram = sem.dram_init(g, inputs, weights)

    tcm = _TcmState(g)
    dead_after = prog.meta.get("dead_after_tick", {})
    ddr = 0

    def tile_slice(tl: TileRef, arr: np.ndarray) -> np.ndarray:
        t = g.tensors[tl.tensor]
        if t.is_param:
            return arr[tl.r0:tl.r1]
        if tl.axis == "chan":
            return arr[..., tl.r0:tl.r1]
        return arr[tl.r0:tl.r1]

    for tick in prog.ticks:
        for j in tick.dma:
            if j.kind in ("fetch", "lfetch"):
                src = dram.get(j.tile.tensor)
                if src is None:
                    raise ExecutionError(
                        f"tick {tick.index}: fetch of {j.tile} but tensor "
                        f"not in DRAM (never pushed?)")
                tcm.put(j.tile, tile_slice(j.tile, src))
                ddr += j.nbytes
            elif j.kind == "lcopy":
                pass  # halo duplication — layout-only, no data change
        if tick.compute:
            cj = tick.compute
            op = g.op(cj.op_name)
            if cj.r0 is not None:
                r0, r1, axis = cj.r0, cj.r1, cj.axis
            else:  # legacy program: derive the range from the out tiles
                axis = cj.out_tiles[0].axis
                r0 = min(tl.r0 for tl in cj.out_tiles
                         if tl.tensor == op.outputs[0])
                r1 = max(tl.r1 for tl in cj.out_tiles
                         if tl.tensor == op.outputs[0])
            results = sem.run_step(g, tiling, tcm, op, r0, r1, axis)
            for tl in cj.out_tiles:
                y = results[tl.tensor]
                if axis == "chan":
                    if tl.r0 < r0 or tl.r1 > r1:
                        # channel-split step writing a slice of a wider
                        # (bank-granular) output tile: read-modify-write
                        buf = tcm.data.get(tl.key)
                        if buf is None:
                            shape = y.shape[:-1] + (tl.r1 - tl.r0,)
                            buf = np.zeros(shape, dtype=y.dtype)
                        lo, hi = max(r0, tl.r0), min(r1, tl.r1)
                        buf[..., lo - tl.r0: hi - tl.r0] = \
                            y[..., lo - r0: hi - r0]
                        tcm.put(tl, buf)
                    else:
                        tcm.put(tl, y[..., tl.r0 - r0: tl.r1 - r0])
                else:
                    tcm.put(tl, y[tl.r0 - r0: tl.r1 - r0])
        for j in tick.dma:
            if j.kind == "push":
                t = g.tensors[j.tile.tensor]
                if j.tile.key not in tcm.resident:
                    raise ExecutionError(
                        f"tick {tick.index}: push of non-resident {j.tile}")
                arr = tcm.data[j.tile.key]
                if t.name not in dram:
                    dram[t.name] = np.zeros(t.shape, dtype=arr.dtype)
                    written[t.name] = np.zeros(t.shape, dtype=bool)
                if t.is_param:
                    dram[t.name][j.tile.r0:j.tile.r1] = arr
                elif j.tile.axis == "chan":
                    dram[t.name][..., j.tile.r0:j.tile.r1] = arr
                    if t.name in written:
                        written[t.name][..., j.tile.r0:j.tile.r1] = True
                else:
                    dram[t.name][j.tile.r0:j.tile.r1] = arr
                    if t.name in written:
                        written[t.name][j.tile.r0:j.tile.r1] = True
                tcm.drop(j.tile.key)
                ddr += j.nbytes
        for key in dead_after.get(tick.index, []):
            tcm.drop(tuple(key))

    max_err = 0.0
    outputs: Dict[str, np.ndarray] = {}
    if check:
        ref = sem.reference(g, inputs, weights)
        for t in g.outputs:
            if t.name not in dram:
                raise ExecutionError(f"output {t.name} never pushed to DRAM")
            if t.name in written and not written[t.name].all():
                raise ExecutionError(f"output {t.name} partially written")
            got = sem.decode(t.name, dram[t.name])
            want = ref[t.name]  # reference() returns decoded float values
            err = float(np.max(np.abs(got - want))) if got.size else 0.0
            tol = sem.tolerance(t.name, want, atol)
            if err > tol:
                raise ExecutionError(
                    f"output {t.name} mismatch ({sem.name}): "
                    f"max|err|={err:.3e} (tol {tol:.3e})")
            max_err = max(max_err, err)
            outputs[t.name] = got
    else:
        outputs = {t.name: dram.get(t.name) for t in g.outputs}

    return ExecutionReport(outputs, max_err, len(prog.ticks), ddr)
