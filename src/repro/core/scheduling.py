"""Tick-based decoupled-access-execute scheduling (paper §IV-B).

Scheduling takes the tile compute order produced by tiling/fusion and
turns it into *timed* jobs: per discrete tick, at most one compute job and
any number of datamover jobs, with latency ``sum_t max(l_DM(t), l_C(t)) +
delta*N_DM`` (Eq. 8).  Per the paper, scheduling does **not** re-order
tiles — it "focuses solely on optimizing memory latency hiding":

  * the compute job of step *k* is pinned to tick *k+1*;
  * every fetch / push / l-copy job gets a CP-chosen tick inside its
    feasibility window (fetch: after the tile exists and before its
    compute; push: after produce; l-copy: before the line-format compute);
  * persistency/dependency/memory constraints (Eq. 1/2/7) are enforced via
    the linearized residency formulation;
  * Eq. 3's bank-sharing bus conflicts cannot arise here because tiles are
    allocated at whole-bank granularity (V2P makes physical banks
    interchangeable) — the executor asserts this invariant.

A greedy just-in-time schedule (fetch at k-1, push right after produce,
spill by furthest-next-use) provides both the warm start and the job set;
the CP re-times jobs per partition window (the paper's problem
partitioning, Table II).  ``overlap=False`` reproduces the baseline
(eNPU-A-style) serialized compiler used in the §V comparisons.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import cpsolver
from .cpsolver import CPModel, MaxTerm
from .formats import FormatPlan, halo_rows, lcopy_bytes, switch_bytes
from .ir import Graph, Op
from .npu import NPUConfig, compute_job_cost, dma_cost
from .program import ComputeJob, DmaJob, NPUProgram, Tick, TileRef
from .tiling import ComputeStep, TilingResult, in_row_range


@dataclass
class SchedOptions:
    overlap: bool = True              # DAE on (ours) / off (baseline)
    partition: bool = True            # partition the CP (Table II)
    partition_steps: int = 12
    fetch_window: int = 4             # how early a fetch may move
    cp_time_limit_s: float = 1.0      # per partition
    cp_stall_s: Optional[float] = None   # early-exit: incumbent stall (s)
    cp_stall_nodes: Optional[int] = \
        cpsolver.DEFAULT_STALL_NODES      # …or stall (search nodes)
    parallel_cp: bool = True          # solve partition windows concurrently
    cp_engine: str = "incremental"    # cpsolver.ENGINES key
    tcm_frac: float = 1.0             # usable fraction of TCM banks
    dm_penalty: int = 16              # delta of Eq. (8)


# --------------------------------------------------------------------------
# Step expansion: tiles in / tiles out / cycles / required copies
# --------------------------------------------------------------------------


@dataclass
class _Step:
    idx: int
    op: Op
    out_tiles: List[TileRef]
    in_act: List[TileRef]
    in_par: List[TileRef]
    fmt: str
    cycles: int
    macs: int
    copy_bytes: int                   # l-copy / format-switch volume
    r0: int = 0                       # step range on the tiled axis
    r1: int = 0
    axis: str = "rows"


def _expand_steps(cfg: NPUConfig, g: Graph, plan: FormatPlan,
                  tiling: TilingResult) -> List[_Step]:
    steps: List[_Step] = []
    for k, st in enumerate(tiling.order):
        op = g.op(st.op_name)
        fmt = plan[op.name]
        outs: List[TileRef] = []
        in_act: List[TileRef] = []
        if st.axis == "chan":
            # channel sub-problem: all input rows, one weight chunk
            for oname in op.outputs:
                outs.extend(tiling.tiles[oname].covering_chan(st.r0, st.r1))
            for x in g.act_inputs(op):
                in_act.extend(tiling.tiles[x.name].tiles)
            in_par = [tl for p in g.param_inputs(op)
                      for tl in tiling.tiles[p.name].covering_chan(
                          st.r0, st.r1)]
            out0 = g.tensors[op.outputs[0]]
            H = out0.shape[0] if len(out0.shape) == 3 else 1
            jc = compute_job_cost(cfg, g, op, H, fmt,
                                  out_c=st.r1 - st.r0)
            rows = H
        else:
            for oname in op.outputs:
                outs.extend(tiling.tiles[oname].covering(st.r0, st.r1))
            for x in g.act_inputs(op):
                ih = x.shape[0] if len(x.shape) == 3 else 1
                a, b = in_row_range(op, st.r0, st.r1, ih)
                in_act.extend(tiling.tiles[x.name].covering(a, b))
            in_par = [tl for p in g.param_inputs(op)
                      for tl in tiling.tiles[p.name].tiles]
            rows = st.r1 - st.r0
            jc = compute_job_cost(cfg, g, op, rows, fmt)
        cb = 0
        if fmt == "line":
            cb += math.ceil(lcopy_bytes(g, op, rows) * 1)
        # line->depth re-fragmentation of inputs
        for x in g.act_inputs(op):
            if x.producer and plan.fmt.get(x.producer) == "line" \
                    and fmt == "depth":
                ih = x.shape[0] if len(x.shape) == 3 else 1
                a, b = in_row_range(op, st.r0, st.r1, ih)
                cb += math.ceil(x.bytes * max(0, b - a) / max(ih, 1))
        steps.append(_Step(k, op, outs, in_act, in_par, fmt,
                           jc.cycles, jc.macs, cb,
                           r0=st.r0, r1=st.r1, axis=st.axis))
    return steps


# --------------------------------------------------------------------------
# Greedy JIT schedule — produces the DMA job set + a feasible timing
# --------------------------------------------------------------------------


@dataclass
class _DmaDecision:
    kind: str                         # fetch | push | lcopy
    tile: TileRef
    nbytes: int
    cycles: int
    tick: int                         # greedy placement
    release: int                      # earliest legal tick
    deadline: int                     # latest legal tick


def _greedy_schedule(cfg: NPUConfig, g: Graph, steps: List[_Step],
                     opt: SchedOptions
                     ) -> Tuple[List[_DmaDecision],
                                List[Tuple[Tuple[str, int], int]]]:
    """Simulate ticks; return DMA decisions + tile death ticks.

    Tick layout: tick 0 reserved for initial fetches; compute of step k at
    tick k+1; tick T+1 for final pushes.

    Bank-ledger semantics (shared with the allocator):
      * a push at tick t frees its banks *within* t — the controller
        sequences datamover jobs, and l_DM(t) already sums their
        latencies; evicted tiles are never inputs of t's compute (Eq. 3);
      * a tile dying at tick t (last compute use at t) frees its banks at
        the *start of tick t+1* — a same-tick refill would race with the
        concurrently running compute that reads it (Eq. 3).
    """
    T = len(steps)
    cap = int(cfg.tcm_banks * opt.tcm_frac)

    # --- lifetime analysis ---
    produce_tick: Dict[Tuple[str, int], int] = {}
    last_use: Dict[Tuple[str, int], int] = {}
    uses: Dict[Tuple[str, int], List[int]] = {}
    for s in steps:
        for tl in s.out_tiles:
            produce_tick.setdefault(tl.key, s.idx + 1)
        for tl in s.in_act + s.in_par:
            last_use[tl.key] = s.idx + 1
            uses.setdefault(tl.key, []).append(s.idx + 1)

    import bisect

    def next_use(key: Tuple[str, int], t: int) -> int:
        us = uses.get(key)
        if not us:
            return 10 ** 9
        i = bisect.bisect_left(us, t)
        return us[i] if i < len(us) else 10 ** 9

    import heapq

    resident: Dict[Tuple[str, int], TileRef] = {}
    used_banks = 0
    # banks already subtracted from no tile but embargoed until free_tick
    pending_free: List[Tuple[int, int]] = []   # (free_tick, banks)
    decisions: List[_DmaDecision] = []
    death: List[Tuple[Tuple[str, int], int]] = []   # (key, tick) events
    spilled: Dict[Tuple[str, int], int] = {}   # key -> push tick
    evicted_at: Dict[Tuple[str, int], int] = {}   # key -> last evict tick
    # Belady eviction heap: max-heap on next-use (stored as -next_use).
    # Entries go stale when a tile is evicted/retired (lazy deletion) or
    # when time advances past a use.  A stale-small priority would BURY
    # a far-next-use tile below nearer ones, so a fresh entry is pushed
    # every time one of a resident tile's uses passes (the only event
    # that changes next_use); pops then see an accurate maximum, and
    # leftover stale duplicates are corrected or skipped on pop.
    evict_heap: List[Tuple[int, Tuple[str, int]]] = []

    def avail(at_tick: int) -> int:
        """Free banks usable by an acquisition at `at_tick`."""
        embargo = sum(b for ft, b in pending_free if ft > at_tick)
        return cap - used_banks - embargo

    def reap(at_tick: int) -> None:
        nonlocal pending_free
        pending_free = [(ft, b) for ft, b in pending_free if ft > at_tick]

    def evict(at_tick: int, needed: Set[Tuple[str, int]],
              want: int) -> None:
        """Push/drop resident tiles so `want` banks are free at
        `at_tick`.  Tiles used at this very tick (in `needed`) are
        untouchable (Eq. 3); everything else is evictable — dead tiles
        are dropped, live tiles are SPILLED (push now, re-fetch before
        their next use) in Belady order (farthest next use first),
        served from a lazy max-heap keyed on next-use instead of a
        per-shortfall sort over all residents (O(log n) per pop)."""
        nonlocal used_banks
        skipped: List[Tuple[int, Tuple[str, int]]] = []
        while evict_heap and avail(at_tick) < want:
            negnu, key = heapq.heappop(evict_heap)
            tl = resident.get(key)
            if tl is None:
                continue                   # stale: evicted/retired earlier
            nu = next_use(key, at_tick)
            if -negnu != nu:               # priority aged — fix and retry
                heapq.heappush(evict_heap, (-nu, key))
                continue
            if key in needed or produce_tick.get(key, -1) >= at_tick:
                # untouchable this call (in use now, or still being
                # produced) — park the entry and restore it afterwards
                skipped.append((negnu, key))
                continue
            needs_later = nu < 10 ** 9
            is_param_or_input = g.tensors[tl.tensor].kind in (
                "input",) or g.tensors[tl.tensor].is_param
            is_out = g.tensors[tl.tensor].kind == "output"
            if (needs_later and not is_param_or_input) or is_out:
                # activations must round-trip through DRAM; params and
                # model inputs still live in DRAM — drop and re-fetch.
                # The push may not be re-timed before the tile's last
                # compute use (a push releases the banks in the
                # allocator's replay), so its release is that use + 1,
                # not merely produce + 1.
                us = uses.get(key, ())
                i = bisect.bisect_right(us, at_tick)
                prev_use = us[i - 1] if i else 0
                decisions.append(_DmaDecision(
                    "push", tl, tl.nbytes, dma_cost(cfg, tl.nbytes),
                    at_tick,
                    release=max(produce_tick.get(key, 0), prev_use) + 1,
                    deadline=at_tick))
                if needs_later:
                    spilled[key] = at_tick
            del resident[key]
            used_banks -= tl.banks   # push frees within its tick
            death.append((key, at_tick))
            evicted_at[key] = at_tick
        for entry in skipped:
            heapq.heappush(evict_heap, entry)

    def make_resident(tl: TileRef, at_tick: int, compute_tick: int,
                      needed: Set[Tuple[str, int]],
                      via: Optional[str]) -> None:
        nonlocal used_banks
        if tl.key in resident:
            return
        if via is not None and compute_tick > at_tick \
                and evicted_at.get(tl.key) == at_tick:
            # the tile was evicted *within* this very tick (to make room
            # for this tick's outputs) — a same-tick refetch would race
            # the death event in the allocator/executor replay, so issue
            # the fetch in the compute tick instead (the supported
            # late-fetch slot: the controller sequences DMA before the
            # compute job within a tick).  Interleaved fused orders hit
            # this whenever a tile is used at ticks t-1 and t+1 but not t.
            at_tick = compute_tick
        if avail(at_tick) < tl.banks:
            evict(at_tick, needed, tl.banks)
        if avail(at_tick) < tl.banks and via is not None \
                and compute_tick > at_tick:
            # late-fetch fallback: issue the fetch in the compute tick
            # itself (the controller sequences DMA before the compute
            # job within a tick), so banks embargoed by tiles that died
            # in the previous tick become usable.  Costs pipeline slack,
            # which the DAE max(l_DM, l_C) accounting absorbs.
            reap(compute_tick - 1)
            at_tick = compute_tick
            if avail(at_tick) < tl.banks:
                evict(at_tick, needed, tl.banks)
        if avail(at_tick) < tl.banks:
            raise RuntimeError(
                f"greedy scheduler over capacity at tick {at_tick}: "
                f"need {tl.banks}, avail {avail(at_tick)} "
                f"(working set too large for TCM)")
        if via is not None:
            t = g.tensors[tl.tensor]
            # a re-fetch may never be re-timed before the eviction that
            # made it necessary — the death event would erase it in the
            # allocator/executor replay
            if tl.key in spilled:
                rel = spilled.pop(tl.key) + 1
            elif t.is_param or t.kind == "input":
                rel = evicted_at.get(tl.key, -1) + 1
            else:
                rel = max(produce_tick.get(tl.key, 0),
                          evicted_at.get(tl.key, -1)) + 1
            decisions.append(_DmaDecision(
                via, tl, tl.nbytes, dma_cost(cfg, tl.nbytes),
                max(rel, at_tick), release=rel,
                deadline=compute_tick - 1))
        resident[tl.key] = tl
        used_banks += tl.banks
        heapq.heappush(evict_heap,
                       (-next_use(tl.key, at_tick), tl.key))

    prev_needed: Set[Tuple[str, int]] = set()
    for s in steps:
        now = s.idx + 1
        reap(now - 1)
        needed = {tl.key for tl in s.in_act + s.in_par + s.out_tiles}
        # deps resident by tick `now` (fetched at <= now-1).  The fetch
        # runs concurrently with tick now-1's compute, so that step's
        # tiles are also untouchable (Eq. 3) — evicting them would force
        # the allocator into a repair spill.
        for tl in s.in_act + s.in_par:
            if tl.key not in resident:
                make_resident(tl, now - 1, now, needed | prev_needed,
                              via="fetch")
        # l-copy / format rearrangement right before compute
        if s.copy_bytes:
            dummy = TileRef(f"__halo__{s.idx}", 0, 0, 0, s.copy_bytes,
                            max(1, math.ceil(s.copy_bytes / cfg.bank_bytes)))
            decisions.append(_DmaDecision(
                "lcopy", dummy, s.copy_bytes,
                dma_cost(cfg, s.copy_bytes, kind="tcm"),
                now - 1, release=max(0, now - 2), deadline=now - 1))
            # the staging buffer dies with its compute — without this the
            # allocator holds its banks for the rest of the program
            death.append((dummy.key, now))
        # outputs occupy banks from the compute tick
        reap(now)
        for tl in s.out_tiles:
            make_resident(tl, now, now, needed, via=None)
        # this step consumed its inputs: their next_use advanced — push
        # refreshed heap entries so far-use tiles keep accurate priority
        for tl in s.in_act + s.in_par:
            if tl.key in resident:
                heapq.heappush(evict_heap,
                               (-next_use(tl.key, now + 1), tl.key))
        # retire tiles whose last use was this tick (banks free at now+1)
        for key in list(resident):
            if last_use.get(key, produce_tick.get(key, 0)) <= now \
                    and key not in {o.key for o in s.out_tiles}:
                tl = resident[key]
                is_out = g.tensors[tl.tensor].kind == "output"
                if is_out:
                    # the push IS the release event — recording a death
                    # too would drop the tile before its push executes
                    decisions.append(_DmaDecision(
                        "push", tl, tl.nbytes, dma_cost(cfg, tl.nbytes),
                        min(now + 1, T + 1), release=now + 1,
                        deadline=T + 1))
                else:
                    death.append((key, now))
                del resident[key]
                used_banks -= tl.banks
                pending_free.append((now + 1, tl.banks))
        prev_needed = needed

    # leftover residents that are model outputs must be pushed
    for key, tl in list(resident.items()):
        if g.tensors[tl.tensor].kind == "output":
            decisions.append(_DmaDecision(
                "push", tl, tl.nbytes, dma_cost(cfg, tl.nbytes),
                T + 1,
                release=max(produce_tick.get(key, T),
                            last_use.get(key, 0)) + 1,
                deadline=T + 1))
    return decisions, death


# --------------------------------------------------------------------------
# CP re-timing per partition window
# --------------------------------------------------------------------------


@dataclass
class _WindowCP:
    """One partition window's CP: model + var map + warm start.

    Windows partition the jobs by greedy tick and re-time strictly within
    [a, b), so they share no variables — building them all first and
    solving the batch concurrently (cpsolver.solve_many) is equivalent to
    the sequential sweep."""

    window_jobs: List[_DmaDecision]
    model: CPModel
    x: Dict[Tuple[int, int], int]
    warm: Dict[int, int]

    def apply(self, sol: cpsolver.Solution) -> None:
        if sol.feasible:
            for (ji, t), v in self.x.items():
                if sol[v]:
                    self.window_jobs[ji].tick = t


def _build_window_cp(cfg: NPUConfig, steps: List[_Step],
                     jobs: List[_DmaDecision], a: int, b: int,
                     l_c: Dict[int, int], opt: SchedOptions
                     ) -> Optional[_WindowCP]:
    """Build the CP that re-times jobs whose greedy tick is in [a, b) to
    minimize Eq. (8) over that window."""
    # Jobs whose legal window is inverted (deadline < release) are the
    # scheduler's same-tick late fetches: a tile spilled at tick t and
    # re-needed at t+1 is re-fetched *in* its compute tick (the
    # controller sequences DMA before compute within a tick).  They must
    # stay at their greedy tick — clamping them into [deadline, deadline]
    # would move the fetch before its own spill push and break
    # residency.  Fused (interleaved) orders hit this routinely.
    def _movable(j: _DmaDecision) -> bool:
        return min(j.deadline, b - 1) >= \
            max(j.release, a, j.tick - opt.fetch_window)

    window_jobs = [j for j in jobs if a <= j.tick < b and _movable(j)]
    if not window_jobs:
        return None
    m = CPModel(f"sched[{a}:{b})")
    x: Dict[Tuple[int, int], int] = {}
    for ji, j in enumerate(window_jobs):
        lo = max(j.release, a, j.tick - opt.fetch_window)
        hi = min(j.deadline, b - 1)
        ticks = list(range(lo, hi + 1))
        vs = []
        for t in ticks:
            v = m.bool(f"x[{ji},{t}]")
            x[(ji, t)] = v
            vs.append(v)
        m.add_exactly_one(vs, f"place:{ji}")

    # objective: per tick max(l_C, l_DM); l_DM from job placement
    mts = []
    for t in range(a, b):
        terms = [(v, window_jobs[ji].cycles)
                 for (ji, tt), v in x.items() if tt == t]
        base_dm = sum(j.cycles for j in jobs
                      if j.tick == t and j not in window_jobs)
        mts.append(MaxTerm([(l_c.get(t, 0), []),
                            (base_dm, terms)]))
    m.minimize([], const=0, max_terms=mts)

    # memory: residency extension cost of early fetches / late pushes.
    # fetch at t' keeps banks busy for [t'+1, deadline]; push at t' frees
    # banks after t'.  Capacity per tick:
    cap = int(cfg.tcm_banks * opt.tcm_frac)
    # base occupancy from the greedy placement of *all* jobs:
    # approximate — only constrain the delta movement of window jobs.
    for t in range(a, b):
        terms = []
        for ji, j in enumerate(window_jobs):
            if j.kind == "fetch":
                # resident at t if placed at t' <= t-1 (vs greedy j.tick)
                for tt in range(max(j.release, a), min(t, j.deadline + 1)):
                    if (ji, tt) in x and tt < j.tick:
                        terms.append((x[(ji, tt)], j.tile.banks))
        if terms:
            # headroom: banks unused at tick t under greedy (approximate
            # with 25% of capacity — the greedy targets tcm_frac*banks)
            m.add(terms, "<=", max(1, cap // 4), f"mem:{t}")

    ws = {}
    for (ji, t), v in x.items():
        ws[v] = 1 if window_jobs[ji].tick == t else 0
    # warm start legal by construction (greedy tick inside var range)
    return _WindowCP(window_jobs, m, x, ws)


def _retime_windows(cfg: NPUConfig, steps: List[_Step],
                    jobs: List[_DmaDecision],
                    windows: List[Tuple[int, int]],
                    l_c: Dict[int, int], opt: SchedOptions) -> None:
    """Build every window CP, solve the batch (concurrently when the
    windows are independent), and apply the chosen ticks in place."""
    cps = [w for w in (_build_window_cp(cfg, steps, jobs, a, b, l_c, opt)
                       for a, b in windows) if w is not None]
    if not cps:
        return
    tasks = [cpsolver.SolveTask(w.model, time_limit_s=opt.cp_time_limit_s,
                                warm_start=w.warm,
                                stall_limit_s=opt.cp_stall_s,
                                stall_limit_nodes=opt.cp_stall_nodes,
                                engine=opt.cp_engine)
             for w in cps]
    sols = cpsolver.solve_many(tasks, parallel=opt.parallel_cp)
    for w, sol in zip(cps, sols):
        w.apply(sol)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------


def schedule(cfg: NPUConfig, g: Graph, plan: FormatPlan,
             tiling: TilingResult, opt: Optional[SchedOptions] = None
             ) -> NPUProgram:
    opt = opt or SchedOptions()
    steps = _expand_steps(cfg, g, plan, tiling)
    T = len(steps)
    jobs, death = _greedy_schedule(cfg, g, steps, opt)
    l_c = {s.idx + 1: s.cycles for s in steps}

    if opt.overlap and opt.cp_time_limit_s > 0:
        if opt.partition:
            P = opt.partition_steps
            windows = [(a, min(a + P, T + 2))
                       for a in range(0, T + 2, P)]
        else:
            windows = [(0, T + 2)]
        _retime_windows(cfg, steps, jobs, windows, l_c, opt)

    ticks = [Tick(i) for i in range(T + 2)]
    for s in steps:
        ticks[s.idx + 1].compute = ComputeJob(
            s.op.name, s.out_tiles, s.in_act + s.in_par, s.fmt,
            s.cycles, s.macs, r0=s.r0, r1=s.r1, axis=s.axis)
    for j in jobs:
        t = min(max(j.tick, 0), T + 1)
        ticks[t].dma.append(DmaJob(j.kind, j.tile, j.nbytes, j.cycles))

    dead_after: Dict[int, List[Tuple[str, int]]] = {}
    for key, t in death:
        dead_after.setdefault(t, []).append(key)

    prog = NPUProgram(g.name, cfg, ticks, dm_penalty=opt.dm_penalty,
                      meta={"dead_after_tick": dead_after,
                            "overlap": opt.overlap,
                            "n_steps": T})
    return prog
