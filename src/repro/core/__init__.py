"""eIQ-Neutron compiler mid-end (the paper's primary contribution).

Public API:
    ir            — graph IR, builder, reference executor
    npu           — Neutron machine model + cost functions
    cpsolver      — self-contained 0-1 CP solver
    formats       — depth/line parallelism selection (§IV-A)
    tiling        — temporal tiling + layer fusion CP (§IV-C)
    scheduling    — tick DAE scheduling CP (§IV-B)
    allocation    — banked-TCM allocation + V2P (§IV-D)
    executor      — functional banked-TCM simulator (validation)
    pipeline      — compile_graph() driver
"""
from .ir import (Graph, GraphBuilder, Op, QParams, Tensor, graph_precision,
                 reference_execute)
from .npu import (ENPU_A, ENPU_B, NEUTRON_2TOPS, NPUConfig, compute_job_cost,
                  cycles_to_ms, dma_cost, effective_tops)
from .pipeline import (CompileResult, CompilerOptions, compile_graph,
                       program_cache_clear, program_cache_configure,
                       program_cache_info, program_cache_pin,
                       program_cache_unpin)
from .program import NPUProgram
from .serialize import ArtifactError

__all__ = [
    "Graph", "GraphBuilder", "Op", "QParams", "Tensor", "graph_precision",
    "reference_execute",
    "NPUConfig", "NEUTRON_2TOPS", "ENPU_A", "ENPU_B",
    "compute_job_cost", "dma_cost", "cycles_to_ms", "effective_tops",
    "CompileResult", "CompilerOptions", "compile_graph", "NPUProgram",
    "program_cache_clear", "program_cache_configure", "program_cache_info",
    "program_cache_pin", "program_cache_unpin",
    "ArtifactError",
]
