"""Timed NPU program representation.

The compiler back-end of the paper emits an executable made of *compute
jobs*, *data-transfer jobs* and *synchronization barriers* for the RISC-V
controller (paper §IV).  This module is that artifact: a list of discrete
ticks (the paper's DAE time discretization, §IV-B), each holding at most
one compute job plus any number of datamover jobs.  Latency accounting
follows Eq. (8): ``sum_t max(l_DM(t), l_C(t)) + delta * N_DM`` when the
decoupled access-execute overlap is enabled, or the serialized sum when it
is not (the baseline-compiler mode used for the eNPU-A/B comparisons).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .npu import NPUConfig


@dataclass(frozen=True)
class TileRef:
    """A tile of a tensor.

    axis == "rows": rows [r0, r1) of an (H, W, C) activation.
    axis == "chan": channels [r0, r1) — used for parameter outC chunks and
    for activations produced by huge-parameter ops, which the compiler
    partitions "into smaller sub-problems with fewer output features"
    (paper §III-B) so weights can be streamed set-by-set.
    """

    tensor: str
    index: int
    r0: int
    r1: int
    nbytes: int
    banks: int
    axis: str = "rows"

    @property
    def key(self) -> Tuple[str, int]:
        return (self.tensor, self.index)

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.tensor}#{self.index}[{self.r0}:{self.r1}]"


@dataclass
class ComputeJob:
    op_name: str
    out_tiles: List[TileRef]          # tiles produced (multi for split ops)
    in_tiles: List[TileRef]           # activation + parameter tiles consumed
    fmt: str                          # "depth" | "line"
    cycles: int
    macs: int = 0
    # step range on the tiled axis.  Channel-split steps follow *weight*
    # chunks and may write only a channel slice of a wider (bank-
    # granular) output tile, so the range cannot be derived from
    # out_tiles.  None (legacy) -> derive from out_tiles.
    r0: Optional[int] = None
    r1: Optional[int] = None
    axis: Optional[str] = None

    def __repr__(self) -> str:  # pragma: no cover
        return f"Compute({self.op_name}->{self.out_tiles}, {self.fmt})"


@dataclass
class DmaJob:
    kind: str                         # fetch | push | lcopy | lfetch
    tile: TileRef
    nbytes: int
    cycles: int

    def __repr__(self) -> str:  # pragma: no cover
        return f"Dma({self.kind} {self.tile}, {self.nbytes}B)"


@dataclass
class V2PJob:
    """Virtual-to-physical remap: tensor tile -> physical bank list."""

    tile: TileRef
    banks: List[int]
    cycles: int


@dataclass
class Tick:
    index: int
    compute: Optional[ComputeJob] = None
    dma: List[DmaJob] = field(default_factory=list)
    v2p: List[V2PJob] = field(default_factory=list)

    def l_c(self) -> int:
        return self.compute.cycles if self.compute else 0

    def l_dm(self) -> int:
        return sum(j.cycles for j in self.dma) + \
            sum(j.cycles for j in self.v2p)


@dataclass
class NPUProgram:
    name: str
    cfg: NPUConfig
    ticks: List[Tick] = field(default_factory=list)
    dm_penalty: int = 16              # delta of Eq. (8), cycles per DM job
    meta: Dict = field(default_factory=dict)

    # ---- replay structure ----
    def compute_steps(self) -> List[Tuple[ComputeJob, int, int, str]]:
        """The program's compute jobs in tick order with their step
        ranges resolved: ``(job, r0, r1, axis)``.  Legacy programs
        (``r0 is None``) derive the range from the out tiles exactly
        like the interpretive executor does — this is the step sequence
        both the interpreter and the plan lowering replay."""
        out: List[Tuple[ComputeJob, int, int, str]] = []
        for t in self.ticks:
            cj = t.compute
            if cj is None:
                continue
            if cj.r0 is not None:
                out.append((cj, cj.r0, cj.r1, cj.axis))
            else:
                axis = cj.out_tiles[0].axis
                t0 = cj.out_tiles[0].tensor
                r0 = min(tl.r0 for tl in cj.out_tiles if tl.tensor == t0)
                r1 = max(tl.r1 for tl in cj.out_tiles if tl.tensor == t0)
                out.append((cj, r0, r1, axis))
        return out

    # ---- latency accounting (Eq. 8) ----
    def latency_cycles(self, overlap: Optional[bool] = None) -> int:
        """DAE programs overlap DMA with compute (max per tick, Eq. 8);
        baseline-compiled programs serialize.  Defaults to the mode the
        program was scheduled with."""
        if overlap is None:
            overlap = bool(self.meta.get("overlap", True))
        n_dm = sum(len(t.dma) for t in self.ticks)
        if overlap:
            body = sum(max(t.l_dm(), t.l_c()) for t in self.ticks)
        else:
            body = sum(t.l_dm() + t.l_c() for t in self.ticks)
        return body + self.dm_penalty * n_dm

    def latency_ms(self, overlap: Optional[bool] = None) -> float:
        return self.latency_cycles(overlap) / self.cfg.freq_hz * 1e3

    def total_macs(self) -> int:
        return sum(t.compute.macs for t in self.ticks if t.compute)

    def ddr_bytes(self) -> int:
        return sum(j.nbytes for t in self.ticks for j in t.dma
                   if j.kind in ("fetch", "push", "lfetch"))

    def effective_tops(self) -> float:
        secs = self.latency_cycles() / self.cfg.freq_hz
        return 2 * self.total_macs() / secs / 1e12 if secs else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "ticks": len(self.ticks),
            "latency_ms": self.latency_ms(),
            "latency_ms_serial": self.latency_ms(overlap=False),
            "ddr_mb": self.ddr_bytes() / 1e6,
            "gmacs": self.total_macs() / 1e9,
            "effective_tops": self.effective_tops(),
            "peak_tops": self.cfg.peak_tops,
            "utilization": self.effective_tops() / self.cfg.peak_tops,
        }

    def memory_timeline(self) -> List[int]:
        """Banks resident per tick (for Fig. 6 reproduction).  Derived by
        replaying fetch/compute/push transitions."""
        resident: Dict[Tuple[str, int], int] = {}
        out = []
        for t in self.ticks:
            for j in t.dma:
                if j.kind in ("fetch", "lfetch", "lcopy"):
                    resident[j.tile.key] = j.tile.banks
                elif j.kind == "push":
                    resident.pop(j.tile.key, None)
            if t.compute:
                for tr in t.compute.out_tiles:
                    resident[tr.key] = tr.banks
                for tr in t.compute.in_tiles:
                    # dead-after-use tiles are dropped by the allocator;
                    # the timeline uses lifetime info stamped in meta.
                    pass
            dead = self.meta.get("dead_after_tick", {}).get(t.index, [])
            for key in dead:
                resident.pop(tuple(key), None)
            out.append(sum(resident.values()))
        return out
