"""Neutron NPU machine model (paper §III).

This is the analytical performance model of the eIQ Neutron subsystem the
compiler optimizes against — the "hardware half" of the co-design.  The
container has no NPU silicon, so the model plays the role the cycle
estimator plays inside the real compiler: it converts (job, tile, format)
into cycles, and the scheduler's objective (Eq. 8) is evaluated against it.

Model summary (paper §III-B/C):
  * ``cores`` compute cores; each has M pipelined dot-product units of
    vector length N -> 2*N*M ops/cycle/core.  N=M=16, 4 cores @1 GHz
    = 2.048 TOPS (the paper's 2-TOPS configuration).
  * One operand vector is broadcast to all M units (N bytes/cycle input
    bandwidth at full rate); the other operand can be held stationary in a
    per-core weight scratchpad W_C (8 KiB) or streamed.
  * A accumulators per unit (A = 2M = 32) allow A output pixels in flight,
    dividing the non-shared operand bandwidth by A.
  * Fused epilogue: rescale + activation + min/max pool at no extra cost.
  * Three 128-bit buses per core; TCM is multi-banked and non-arbitrated —
    conflicts are the *compiler's* job to avoid (scheduling constraint #3).
  * DMA: multi-dimensional strided DDR<->TCM and TCM<->TCM transfers.

Every returned latency is in cycles at ``freq`` (1 GHz default) so cycles
== nanoseconds; helpers convert to ms.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import lru_cache
from typing import Dict, Optional, Tuple

from .ir import DTYPE_BYTES, Graph, Op


def elem_bytes(dtype: str) -> float:
    """Storage bytes per element (int4 is nibble-packed: 0.5)."""
    return DTYPE_BYTES.get(dtype, 4.0)


def mac_rate(dtype: str) -> float:
    """MAC-array throughput multiplier vs the native int8 rate.

    The Neutron dot-product units are sized for 8-bit operands (paper
    §III-B): int8/int4 operands run the N-wide vector at full rate, while
    16/32-bit operands halve the effective vector length (two byte lanes
    per element pair) — i.e. quantized layers get the paper's 2x MAC
    throughput over a float32 fallback at identical silicon."""
    return 1.0 if dtype in ("int4", "int8") else 0.5


@dataclass(frozen=True)
class NPUConfig:
    """Hardware parameters.  Defaults = the paper's 2-TOPS MPU instance
    (N=M=16, A=2M, W_C=8KiB, 4 cores, 1 MiB TCM, 12 GB/s DDR)."""

    name: str = "neutron-2tops"
    cores: int = 4
    M: int = 16                      # dot-product units per core
    N: int = 16                      # dot-product vector length
    A: int = 32                      # accumulators per unit (2M)
    Wc_bytes: int = 8 * 1024         # per-core weight scratchpad
    freq_hz: float = 1.0e9
    tcm_bytes: int = 1 * 1024 * 1024
    tcm_banks: int = 32              # non-arbitrated banks
    bus_bytes: int = 16              # 128-bit operand/result buses
    n_buses: int = 3
    ddr_gbps: float = 12.0           # DDR bandwidth (GB/s)
    tcm_gbps: float = 64.0           # aggregate TCM bandwidth (GB/s)
    dma_setup_cycles: int = 400      # per DMA job programming overhead
    job_setup_cycles: int = 300      # per compute-job programming overhead
    v2p_cycles: int = 64             # V2P table update

    @property
    def peak_tops(self) -> float:
        return 2 * self.N * self.M * self.cores * self.freq_hz / 1e12

    @property
    def bank_bytes(self) -> int:
        return self.tcm_bytes // self.tcm_banks

    @property
    def ddr_bytes_per_cycle(self) -> float:
        return self.ddr_gbps * 1e9 / self.freq_hz

    @property
    def tcm_bytes_per_cycle(self) -> float:
        return self.tcm_gbps * 1e9 / self.freq_hz

    def scaled(self, factor: float) -> "NPUConfig":
        """eNPU-B-style scaling: x`factor` TOPS, SRAM and DDR bandwidth."""
        return replace(
            self,
            name=f"{self.name}-x{factor:g}",
            cores=int(self.cores * factor),
            tcm_bytes=int(self.tcm_bytes * factor),
            tcm_banks=int(self.tcm_banks * factor),
            ddr_gbps=self.ddr_gbps * factor,
            tcm_gbps=self.tcm_gbps * factor,
        )


#: the two reference configurations of paper §V.
NEUTRON_2TOPS = NPUConfig()
ENPU_A = replace(NPUConfig(), name="enpu-a")        # equal resources
ENPU_B = NPUConfig().scaled(2.0)                    # 2x resources


# --------------------------------------------------------------------------
# Compute-job cost model
# --------------------------------------------------------------------------


@dataclass
class JobCost:
    cycles: int
    macs: int
    in_bytes: int
    w_bytes: int
    out_bytes: int
    bound: str  # "compute" | "operand-bw" | "weight-bw" | "output-bw"

    @property
    def util(self) -> float:
        return self.macs / max(self.cycles, 1)


def _dot_engine_cycles(cfg: NPUConfig, out_pixels: int, out_c: int,
                       dot_len: int, engines: int,
                       weights_stationary: bool,
                       act_eb: float = 1.0, w_eb: float = 1.0,
                       rate: float = 1.0) -> Tuple[int, str]:
    """Cycles for one core-group to produce `out_pixels x out_c` results,
    each a dot product of length `dot_len`, spread over `engines` cores.

    Within a core: M units each produce one output-channel result per
    pass; A accumulators keep A pixels in flight.  The paper's bandwidth
    argument: the shared operand (ifmap in depth parallelism) needs
    N * act_eb bytes/cycle; the non-shared one (weights) is either
    stationary in W_C or streamed with A-fold reuse.

    ``act_eb``/``w_eb`` are bytes/element of the streamed activation and
    weight operands; ``rate`` is the MAC-array throughput multiplier
    (:func:`mac_rate`) — int8 runs the full N-wide vector per cycle,
    float32 half of it.
    """
    if engines <= 0:
        engines = 1
    # --- pure MAC throughput (with padding to lockstep, paper §IV-A)
    oc_per_engine = math.ceil(out_c / engines) if out_c else 0
    if oc_per_engine == 0 or out_pixels == 0 or dot_len == 0:
        return 0, "compute"
    oc_passes = math.ceil(oc_per_engine / cfg.M)
    dot_cycles = math.ceil(dot_len / (cfg.N * rate))
    compute = out_pixels * oc_passes * dot_cycles

    # --- operand (shared, e.g. ifmap) bandwidth: N*act_eb bytes/cycle
    #     needed, one 128-bit bus provides bus_bytes per cycle.
    operand_rate = min(1.0, cfg.bus_bytes / (cfg.N * act_eb))
    # --- weight bandwidth: stationary weights stream once per W_C refill;
    #     otherwise every pass re-reads them with A-fold pixel reuse.
    w_bytes_total = math.ceil(out_c * dot_len * w_eb)
    if weights_stationary and w_bytes_total <= cfg.Wc_bytes * engines:
        w_stream_cycles = math.ceil(w_bytes_total / (cfg.bus_bytes * engines))
        weight_limited = 0
    else:
        # streamed: per pixel-group of A, each engine re-fetches its slice
        per_engine_w = math.ceil(w_bytes_total / engines)
        refetches = math.ceil(out_pixels / cfg.A)
        w_stream_cycles = math.ceil(per_engine_w * refetches / cfg.bus_bytes)
        weight_limited = w_stream_cycles

    cycles = max(math.ceil(compute / operand_rate), w_stream_cycles)
    if cycles == compute:
        bound = "compute"
    elif cycles == weight_limited:
        bound = "weight-bw"
    else:
        bound = "operand-bw"
    return cycles, bound


_COST_MEMO_ENABLED = True
_JOB_COST_CACHE: Dict[Tuple, JobCost] = {}
_JOB_COST_CACHE_MAX = 1 << 16


def set_cost_memo(enabled: bool) -> None:
    """Toggle the compute/DMA cost memo (benchmarks time both modes)."""
    global _COST_MEMO_ENABLED
    _COST_MEMO_ENABLED = bool(enabled)
    if not enabled:
        cost_cache_clear()


def cost_cache_clear() -> None:
    _JOB_COST_CACHE.clear()
    _dma_cost_cached.cache_clear()


def _freeze(v):
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


def _job_cost_key(cfg: NPUConfig, g: Graph, op: Op, out_h: int, fmt: str,
                  engines: Optional[int], out_c: Optional[int]) -> Tuple:
    """Everything compute_job_cost reads, as a hashable key — the cost of
    a job depends only on op kind/attrs and operand shapes, never on
    tensor names, so repeated tiles, budget-ladder retries and repeated
    model compiles all hit the same entries."""
    return (cfg, op.kind, _freeze(op.attrs),
            g.tensors[op.output].shape, g.tensors[op.output].dtype,
            tuple((t.shape, t.dtype) for t in g.param_inputs(op)),
            tuple((t.shape, t.dtype) for t in g.act_inputs(op)),
            out_h, fmt, engines, out_c)


def compute_job_cost(cfg: NPUConfig, g: Graph, op: Op,
                     out_h: int, fmt: str, engines: Optional[int] = None,
                     out_c: Optional[int] = None) -> JobCost:
    """Cost of computing `out_h` output lines (restricted to `out_c`
    output channels when the op is channel-partitioned) of `op` in format
    `fmt` ("depth" or "line", paper §IV-A) on `engines` cores.

    Results are memoized (callers treat JobCost as read-only): the tiling
    and scheduling passes re-evaluate identical (op, tile, format) jobs
    thousands of times inside their CP loops."""
    if _COST_MEMO_ENABLED:
        key = _job_cost_key(cfg, g, op, out_h, fmt, engines, out_c)
        hit = _JOB_COST_CACHE.get(key)
        if hit is not None:
            return hit
        jc = _compute_job_cost(cfg, g, op, out_h, fmt, engines, out_c)
        if len(_JOB_COST_CACHE) < _JOB_COST_CACHE_MAX:
            _JOB_COST_CACHE[key] = jc
        return jc
    return _compute_job_cost(cfg, g, op, out_h, fmt, engines, out_c)


def _compute_job_cost(cfg: NPUConfig, g: Graph, op: Op,
                      out_h: int, fmt: str, engines: Optional[int] = None,
                      out_c: Optional[int] = None) -> JobCost:
    engines = engines or cfg.cores
    k = op.kind
    out = g.tensors[op.output]
    if out.kind == "parameter":  # pragma: no cover
        raise ValueError("op writes a parameter?")
    if len(out.shape) == 3:
        H, W, C = out.shape
    else:
        H, W, C = 1, 1, out.shape[0]
    out_h = min(out_h, H)
    c_frac = 1.0
    if out_c is not None and C:
        c_frac = out_c / C
        C = out_c
    a = op.attrs

    # precision: bytes/element of each operand class + MAC-array rate
    # (the paper's MAC arrays are int8-native; see mac_rate()).
    acts = g.act_inputs(op)
    params = g.param_inputs(op)
    act_eb = elem_bytes(acts[0].dtype if acts else out.dtype)
    w_eb = elem_bytes(params[0].dtype) if params else act_eb
    out_eb = elem_bytes(out.dtype)
    rate = min(mac_rate(acts[0].dtype) if acts else 1.0,
               mac_rate(params[0].dtype) if params else 1.0)

    w_bytes = math.ceil(sum(t.bytes for t in params) * c_frac)
    in_bytes = sum(t.bytes for t in acts)
    in_bytes = math.ceil(in_bytes * out_h / max(H, 1))
    out_bytes = math.ceil(out_h * W * C * out_eb)

    if k in ("conv", "fc"):
        wt = params[0]
        oc, fh, fw, ic = wt.shape
        dot_len = fh * fw * ic
        pixels = out_h * W
        if fmt == "depth":
            # split outC over engines; ifmap broadcast-shared
            cyc, bound = _dot_engine_cycles(cfg, pixels, C, dot_len,
                                            engines, weights_stationary=True,
                                            act_eb=act_eb, w_eb=w_eb,
                                            rate=rate)
        else:
            # line: split lines over engines; weights broadcast-shared
            pix_e = math.ceil(out_h / engines) * W
            cyc, bound = _dot_engine_cycles(cfg, pix_e, C, dot_len, 1,
                                            weights_stationary=True,
                                            act_eb=act_eb, w_eb=w_eb,
                                            rate=rate)
        macs = pixels * C * dot_len
    elif k == "dwconv":
        wt = params[0]
        _, fh, fw, _ = wt.shape
        dot_len = fh * fw
        pixels = out_h * W
        if fmt == "depth":
            cyc, bound = _dot_engine_cycles(cfg, pixels,
                                            math.ceil(C / 1), dot_len,
                                            engines, True,
                                            act_eb=act_eb, w_eb=w_eb,
                                            rate=rate)
            # depthwise cannot share the ifmap across channels: each unit
            # needs its own channel stream -> M-fold operand bandwidth.
            cyc = max(cyc, math.ceil(pixels * C * dot_len * act_eb
                                     / (cfg.bus_bytes * engines)))
            bound = "operand-bw" if cyc > pixels else bound
        else:
            pix_e = math.ceil(out_h / engines) * W
            cyc, bound = _dot_engine_cycles(cfg, pix_e, C, dot_len, 1, True,
                                            act_eb=act_eb, w_eb=w_eb,
                                            rate=rate)
        macs = pixels * C * dot_len
    elif k in ("add", "mul", "scalar", "act", "concat", "split", "pad"):
        # element-wise / data-movement ops: TCM-bandwidth bound, fused
        # through the vector path (paired depthwise, paper §IV-A).
        elems = out_h * W * C * (2 if k in ("add", "mul") else 1)
        cyc = math.ceil(elems * act_eb / (cfg.bus_bytes * engines))
        macs = out_h * W * C
        bound = "operand-bw"
    elif k in ("maxpool", "avgpool"):
        kk = a.get("k", 2) or max(H, W)  # global -> full reduce
        elems = out_h * W * C * (kk * kk if a.get("k", 2) else 1)
        if a.get("k", 2) == 0:
            ih = g.act_inputs(op)[0].shape[0]
            iw = g.act_inputs(op)[0].shape[1]
            elems = ih * iw * C
        cyc = math.ceil(elems * act_eb / (cfg.bus_bytes * engines))
        macs = elems
        bound = "operand-bw"
    elif k == "resize":
        cyc = math.ceil(out_h * W * C * out_eb
                        / (cfg.bus_bytes * engines))
        macs = 0
        bound = "output-bw"
    elif k in ("format", "reshape"):
        cyc = math.ceil(out_bytes / cfg.tcm_bytes_per_cycle)
        macs = 0
        bound = "output-bw"
    elif k == "matmul":
        # row-wise linear over (S,1,C) tokens: fc-shaped dot engine work
        # with out_h token rows as the pixel axis
        wt = params[0]
        oc, _, _, ic = wt.shape
        pixels = out_h * W
        if fmt == "depth":
            cyc, bound = _dot_engine_cycles(cfg, pixels, C, ic, engines,
                                            weights_stationary=True,
                                            act_eb=act_eb, w_eb=w_eb,
                                            rate=rate)
        else:
            pix_e = math.ceil(out_h / engines) * W
            cyc, bound = _dot_engine_cycles(cfg, pix_e, C, ic, 1,
                                            weights_stationary=True,
                                            act_eb=act_eb, w_eb=w_eb,
                                            rate=rate)
        macs = pixels * C * ic
    elif k in ("layernorm", "softmax"):
        # per-token normalization: three vector passes over the row
        # (statistics, transform, write) through the TCM buses
        elems = out_h * W * C
        cyc = math.ceil(3 * elems * act_eb / (cfg.bus_bytes * engines))
        macs = 2 * elems
        bound = "operand-bw"
    elif k == "attention":
        # context-length-aware (arxiv 2509.25155): both GEMMs and the
        # softmax scale with the KV bucket length in op.attrs — which is
        # in the cost-memo key and the graph fingerprint, so every
        # sequence-position bucket is priced (and cached) separately.
        kv = int(a["kv_len"])
        heads, hd = int(a["heads"]), int(a["head_dim"])
        pixels = out_h * W * heads
        qk_cyc, _ = _dot_engine_cycles(cfg, pixels, kv, hd, engines,
                                       weights_stationary=False,
                                       act_eb=act_eb, w_eb=act_eb,
                                       rate=rate)
        pv_cyc, _ = _dot_engine_cycles(cfg, pixels, hd, kv, engines,
                                       weights_stationary=False,
                                       act_eb=act_eb, w_eb=act_eb,
                                       rate=rate)
        sm_cyc = math.ceil(3 * pixels * kv * 4.0
                           / (cfg.bus_bytes * engines))
        cyc = qk_cyc + pv_cyc + sm_cyc
        macs = 2 * pixels * kv * hd
        bound = "compute" if qk_cyc + pv_cyc >= sm_cyc else "operand-bw"
        # every row tile streams the whole KV cache (not an out_h slice)
        kv_bytes = sum(t.bytes for t in acts[1:3])
        q_bytes = math.ceil(acts[0].bytes * out_h / max(H, 1))
        in_bytes = q_bytes + kv_bytes
    elif k == "kvappend":
        # cache copy-through + appended rows: pure data movement
        cyc = math.ceil(out_bytes / (cfg.bus_bytes * engines))
        macs = 0
        bound = "output-bw"
    else:  # pragma: no cover
        raise NotImplementedError(k)

    # result write-back shares the third bus
    cyc = max(cyc, math.ceil(out_bytes / (cfg.bus_bytes * engines)))
    cyc += cfg.job_setup_cycles
    return JobCost(int(cyc), int(macs), int(in_bytes), int(w_bytes),
                   int(out_bytes), bound)


# --------------------------------------------------------------------------
# Data-mover cost model
# --------------------------------------------------------------------------


@lru_cache(maxsize=1 << 16)
def _dma_cost_cached(cfg: NPUConfig, nbytes: int, kind: str) -> int:
    rate = cfg.ddr_bytes_per_cycle if kind == "ddr" \
        else cfg.tcm_bytes_per_cycle
    return int(cfg.dma_setup_cycles + math.ceil(nbytes / rate))


def dma_cost(cfg: NPUConfig, nbytes: int, kind: str = "ddr") -> int:
    """Cycles for one DMA job.  kind: ddr (DDR<->TCM) or tcm (TCM<->TCM,
    used for line-format expansion copies, paper §IV-A)."""
    if nbytes <= 0:
        return 0
    if _COST_MEMO_ENABLED:
        return _dma_cost_cached(cfg, nbytes, kind)
    rate = cfg.ddr_bytes_per_cycle if kind == "ddr" \
        else cfg.tcm_bytes_per_cycle
    return int(cfg.dma_setup_cycles + math.ceil(nbytes / rate))


def cross_window_spill_cost(cfg: NPUConfig, nbytes: int,
                            round_trip: bool = True) -> int:
    """Price, in the fusion CP's bank-tick objective units, of a tile
    crossing a fusion-window boundary through DDR.

    The windowed fusion CP (:mod:`repro.core.tiling`) trades "hold a
    tile resident" (``tile.banks`` per tick) against "let it go and
    bring it back from DDR" (this constant).  ``round_trip=True`` is an
    activation crossing the boundary (push + refetch);
    ``round_trip=False`` is a parameter or model input, which still
    lives in DRAM and only costs the refetch.  The exchange rate
    normalizes the DDR traffic by the DMA cost of one TCM bank, so a
    tile is worth keeping resident for roughly ``cost / banks`` ticks —
    which also makes per-window objectives comparable when they are
    summed across the stitched windows of one region."""
    if nbytes <= 0:
        return 0
    per_bank = max(1, dma_cost(cfg, cfg.bank_bytes))
    trips = 2 if round_trip else 1
    return max(1, math.ceil(trips * dma_cost(cfg, nbytes) / per_bank))


def cycles_to_ms(cfg: NPUConfig, cycles: float) -> float:
    return cycles / cfg.freq_hz * 1e3


def effective_tops(cfg: NPUConfig, macs: int, cycles: float) -> float:
    """ops/latency — the paper's 'effective TOPS' (Table I)."""
    secs = cycles / cfg.freq_hz
    return 2 * macs / secs / 1e12 if secs > 0 else 0.0
