"""Graph IR for the eIQ-Neutron compiler mid-end.

The paper's compiler front-end ingests a LiteRT model and lowers it to an
internal IR of *tensors* and *operators* (paper §IV).  This module is that
IR: a static, batch-1, HWC-layout dataflow graph with

  * shape inference for every operator the vision benchmarks need,
  * MAC/byte accounting (drives the cost model and Table IV checks),
  * a pure-numpy reference executor (the functional oracle every compiled
    NPU program is validated against),
  * topological utilities used by the tiling / fusion / scheduling passes.

Activations use (H, W, C) layout; parameters use (outC, fH, fW, inC) — the
exact layouts of paper Algorithm 1.  Batch is always 1 (edge inference).

Tensors carry an explicit ``dtype`` (float32 by default) plus optional
affine quantization parameters (:class:`QParams`).  A freshly built graph
is float32 end to end; the PTQ pass in :mod:`repro.quant` annotates it
with int8/int4 dtypes and qparams, which changes every byte-accounted
quantity downstream (tile sizes, DMA volume, TCM occupancy) and the MAC
throughput of the cost model — the paper's INT8 deployment.  Both dtype
and qparams are part of :meth:`Graph.fingerprint`, so quantized and float
variants of a model never alias in the compiled-program cache.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Tensors
# --------------------------------------------------------------------------

ACT_KINDS = ("input", "activation", "output")

#: storage bytes per element; int4 is nibble-packed (2 values/byte).
DTYPE_BYTES = {"int4": 0.5, "int8": 1.0, "int16": 2.0,
               "int32": 4.0, "float32": 4.0}


@dataclass
class QParams:
    """Affine quantization parameters: ``float = scale * (q - zero_point)``.

    ``scale``/``zero_point`` are scalars for per-tensor quantization or
    1-D arrays for per-channel quantization along ``axis`` (axis 0 ==
    outC for conv/fc weights).  ``bits`` is the integer width of the
    stored values (8 for int8, 4 for nibble-packed int4, 32 for the
    int32 bias convention).  Attached to :class:`Tensor` by the PTQ pass
    in :mod:`repro.quant`; participates in :meth:`Graph.fingerprint`.
    """

    scale: np.ndarray
    zero_point: np.ndarray
    bits: int = 8
    axis: Optional[int] = None

    @property
    def per_channel(self) -> bool:
        return self.axis is not None

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    def payload(self) -> list:
        """Canonical JSON-serializable form for graph fingerprinting."""
        return [self.bits, self.axis,
                [float(s) for s in np.atleast_1d(self.scale)],
                [int(z) for z in np.atleast_1d(self.zero_point)]]


@dataclass
class Tensor:
    """A logical tensor in the graph.

    kind:
      - "input":      model input (starts in DRAM, paper Fig. 5)
      - "activation": intermediate feature map (starts N/E)
      - "output":     model output (must end in DRAM)
      - "parameter":  weights/bias (starts in DRAM)
    shape: activations (H, W, C); parameters (outC, fH, fW, inC) or (C,) bias.
    """

    name: str
    shape: Tuple[int, ...]
    kind: str = "activation"
    dtype: str = "float32"
    producer: Optional[str] = None          # op name, None for inputs/params
    consumers: List[str] = field(default_factory=list)
    scale: float = 1.0                      # legacy scalar scale (float ref)
    qparams: Optional[QParams] = None       # set by the PTQ pass

    @property
    def elems(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        return int(math.ceil(self.elems * DTYPE_BYTES[self.dtype]))

    @property
    def is_param(self) -> bool:
        return self.kind == "parameter"

    @property
    def hwc(self) -> Tuple[int, int, int]:
        assert self.kind in ACT_KINDS and len(self.shape) == 3, self
        return self.shape  # type: ignore[return-value]


# --------------------------------------------------------------------------
# Operators
# --------------------------------------------------------------------------

#: op kinds understood by the lowering / cost model.
OP_KINDS = (
    "conv",        # conv2d; attrs: stride, pad (explicit 4-tuple), act
    "dwconv",      # depthwise conv2d (groups == C)
    "fc",          # fully connected == 1x1 conv on (1,1,C) (paper §IV-A)
    "add",         # elementwise add (paired depthwise, paper §IV-A)
    "mul",         # Hadamard
    "scalar",      # op with a constant scalar (1x1 depthwise, paper §IV-A)
    "act",         # standalone activation
    "maxpool",     # attrs: k, stride, pad
    "avgpool",     # attrs: k, stride, pad (k == 0 -> global)
    "resize",      # nearest-neighbour upsample; attrs: factor
    "concat",      # channel concat
    "split",       # channel split; attrs: sections -> multiple outputs
    "pad",         # spatial zero-pad
    "format",      # TCM format conversion (inserted by the compiler)
    "reshape",     # logical reshape (free at runtime, kept for heads)
    # ---- causal / transformer operators (LM decode path) --------------
    # LM activations are (S, 1, d_model): the sequence axis maps onto the
    # H (row) axis, so the row-tiling machinery tiles over tokens.
    "matmul",      # row-wise linear: y[s] = W @ x[s] (+ b); W (outC,1,1,inC)
    "layernorm",   # per-token layer norm over channels; params gamma, beta
    "softmax",     # per-token softmax over channels
    "attention",   # fused QK^T -> softmax -> V against a KV cache;
                   # inputs [q, k_cache, v_cache, pos]; attrs heads,
                   # head_dim, scale, causal, kv_len (static cache bucket
                   # — the context-length-aware cost-model knob)
    "kvappend",    # write S new rows into a KV cache at dynamic offset
                   # pos; inputs [cache, new, pos]
)

ACTIVATIONS = ("none", "relu", "relu6", "hswish", "hsigmoid", "silu",
               "sigmoid", "gelu", "mish", "sqrelu", "leaky")


@dataclass
class Op:
    name: str
    kind: str
    inputs: List[str]                 # tensor names (activations first)
    outputs: List[str]                # tensor names
    attrs: Dict = field(default_factory=dict)

    @property
    def output(self) -> str:
        return self.outputs[0]


# --------------------------------------------------------------------------
# Graph
# --------------------------------------------------------------------------


class Graph:
    def __init__(self, name: str):
        self.name = name
        self.tensors: Dict[str, Tensor] = {}
        self.ops: List[Op] = []
        self._op_index: Dict[str, Op] = {}

    # -- construction -------------------------------------------------------
    def add_tensor(self, t: Tensor) -> Tensor:
        if t.name in self.tensors:
            raise ValueError(f"duplicate tensor {t.name}")
        self.tensors[t.name] = t
        return t

    def add_op(self, op: Op) -> Op:
        if op.name in self._op_index:
            raise ValueError(f"duplicate op {op.name}")
        for i in op.inputs:
            self.tensors[i].consumers.append(op.name)
        for o in op.outputs:
            self.tensors[o].producer = op.name
        self.ops.append(op)
        self._op_index[op.name] = op
        return op

    def op(self, name: str) -> Op:
        return self._op_index[name]

    # -- queries ------------------------------------------------------------
    @property
    def inputs(self) -> List[Tensor]:
        return [t for t in self.tensors.values() if t.kind == "input"]

    @property
    def outputs(self) -> List[Tensor]:
        return [t for t in self.tensors.values() if t.kind == "output"]

    @property
    def params(self) -> List[Tensor]:
        return [t for t in self.tensors.values() if t.is_param]

    def act_inputs(self, op: Op) -> List[Tensor]:
        return [self.tensors[i] for i in op.inputs
                if not self.tensors[i].is_param]

    def param_inputs(self, op: Op) -> List[Tensor]:
        return [self.tensors[i] for i in op.inputs if self.tensors[i].is_param]

    def topo_ops(self) -> List[Op]:
        """Topologically ordered ops (graph build order is already topo,
        but verify — the passes rely on it)."""
        ready: set = {t.name for t in self.tensors.values()
                      if t.producer is None}
        out: List[Op] = []
        pending = list(self.ops)
        guard = 0
        while pending:
            guard += 1
            if guard > len(self.ops) + 2:
                raise RuntimeError(f"graph {self.name} has a cycle")
            rest = []
            for op in pending:
                if all(i in ready for i in op.inputs):
                    out.append(op)
                    ready.update(op.outputs)
                else:
                    rest.append(op)
            pending = rest
        return out

    # -- accounting ---------------------------------------------------------
    def op_macs(self, op: Op) -> int:
        """Multiply-accumulate count of one op (for Table IV / cost model)."""
        k = op.kind
        if k in ("conv", "fc"):
            w = self.param_inputs(op)[0]
            oh, ow, oc = self.tensors[op.output].hwc
            outc, fh, fw, inc = w.shape
            return oh * ow * oc * fh * fw * inc
        if k == "dwconv":
            w = self.param_inputs(op)[0]
            oh, ow, oc = self.tensors[op.output].hwc
            _, fh, fw, _ = w.shape
            return oh * ow * oc * fh * fw
        if k in ("add", "mul", "scalar", "act"):
            return self.tensors[op.output].elems
        if k in ("maxpool", "avgpool"):
            kk = op.attrs.get("k", 2) or 2
            return self.tensors[op.output].elems * kk * kk
        if k == "matmul":
            w = self.param_inputs(op)[0]
            s, _, oc = self.tensors[op.output].hwc
            return s * oc * w.shape[-1]
        if k in ("layernorm", "softmax"):
            # multi-pass normalization: ~2 flops/element dominate
            return 2 * self.tensors[op.output].elems
        if k == "attention":
            # context-length-aware: QK^T and PV both scale with the KV
            # bucket (arxiv 2509.25155), not with a fixed operand shape
            s = self.tensors[op.output].hwc[0]
            kv = int(op.attrs["kv_len"])
            return 2 * s * op.attrs["heads"] * op.attrs["head_dim"] * kv
        return 0

    def total_macs(self) -> int:
        return sum(self.op_macs(op) for op in self.ops)

    def total_param_bytes(self) -> int:
        return sum(t.bytes for t in self.params)

    def stats(self) -> Dict[str, float]:
        return {
            "ops": len(self.ops),
            "gmacs": self.total_macs() / 1e9,
            "params_m": sum(t.elems for t in self.params) / 1e6,
            "param_bytes": self.total_param_bytes(),
        }

    def fingerprint(self) -> str:
        """Canonical content hash of the graph *structure* — everything
        the compiler reads (tensor shapes/kinds/dtypes, op topology and
        attributes), nothing it doesn't (weight values).  Two graphs with
        equal fingerprints compile to identical programs under identical
        (NPUConfig, CompilerOptions), which is what keys the
        compiled-program cache in pipeline.py."""
        import hashlib
        import json
        payload = {
            "name": self.name,
            "tensors": [
                [t.name, list(t.shape), t.kind, t.dtype, t.producer,
                 list(t.consumers), t.scale,
                 t.qparams.payload() if t.qparams is not None else None]
                for t in sorted(self.tensors.values(),
                                key=lambda t: t.name)],
            "ops": [[op.name, op.kind, list(op.inputs), list(op.outputs),
                     op.attrs] for op in self.ops],
        }
        blob = json.dumps(payload, sort_keys=True, default=list)
        return hashlib.sha256(blob.encode()).hexdigest()

    def __repr__(self) -> str:  # pragma: no cover
        s = self.stats()
        return (f"Graph({self.name}: {s['ops']} ops, {s['gmacs']:.2f} GMACs,"
                f" {s['params_m']:.1f}M params)")


def graph_precision(g: Graph) -> str:
    """Activation precision of a graph: 'float32', 'int8', or 'mixed'."""
    dts = {t.dtype for t in g.tensors.values() if not t.is_param}
    if dts == {"int8"}:
        return "int8"
    if dts == {"float32"}:
        return "float32"
    return "mixed"


# --------------------------------------------------------------------------
# Builder — shape-inferring convenience layer
# --------------------------------------------------------------------------


def _pair(v) -> Tuple[int, int]:
    return (v, v) if isinstance(v, int) else tuple(v)  # type: ignore


def conv_out_dim(inp: int, k: int, s: int, p0: int, p1: int) -> int:
    return (inp + p0 + p1 - k) // s + 1


def same_pad(inp: int, k: int, s: int) -> Tuple[int, int]:
    """TF 'SAME' padding split (left/top gets the smaller half)."""
    out = math.ceil(inp / s)
    total = max(0, (out - 1) * s + k - inp)
    return total // 2, total - total // 2


class GraphBuilder:
    """Fluent builder; returns tensor names.  Weights are created as
    deterministic pseudo-random parameters so the reference executor is
    reproducible without any external data."""

    def __init__(self, name: str, seed: int = 0):
        self.g = Graph(name)
        self._ctr = 0
        self._rng = np.random.default_rng(seed)
        self._weights: Dict[str, np.ndarray] = {}

    # ---- naming ----
    def _n(self, prefix: str) -> str:
        self._ctr += 1
        return f"{prefix}_{self._ctr}"

    # ---- tensors ----
    def input(self, shape: Tuple[int, int, int], name: str = "input") -> str:
        self.g.add_tensor(Tensor(name, shape, kind="input"))
        return name

    def mark_output(self, name: str) -> str:
        self.g.tensors[name].kind = "output"
        return name

    def _act_tensor(self, shape, prefix="t") -> str:
        nm = self._n(prefix)
        self.g.add_tensor(Tensor(nm, tuple(int(x) for x in shape)))
        return nm

    def _param(self, shape, prefix="w") -> str:
        nm = self._n(prefix)
        self.g.add_tensor(Tensor(nm, tuple(int(x) for x in shape),
                                 kind="parameter"))
        # deterministic small-int weights (int8-representable)
        self._weights[nm] = (
            self._rng.integers(-4, 5, size=shape).astype(np.float32) / 16.0)
        return nm

    def weight_array(self, name: str) -> np.ndarray:
        return self._weights[name]

    # ---- ops ----
    def conv(self, x: str, out_c: int, k: int = 3, s: int = 1,
             act: str = "none", pad: str = "same", bias: bool = True,
             groups: int = 1) -> str:
        h, w, c = self.g.tensors[x].hwc
        kh, kw = _pair(k)
        if pad == "same":
            pt, pb = same_pad(h, kh, s)
            pl, pr = same_pad(w, kw, s)
        elif pad == "valid":
            pt = pb = pl = pr = 0
        else:
            pt, pb, pl, pr = pad  # explicit
        oh = conv_out_dim(h, kh, s, pt, pb)
        ow = conv_out_dim(w, kw, s, pl, pr)
        if groups == c and out_c == c:
            wshape = (out_c, kh, kw, 1)
            kind = "dwconv"
        elif groups == 1:
            wshape = (out_c, kh, kw, c)
            kind = "conv"
        else:
            raise NotImplementedError("only dense or depthwise groups")
        wt = self._param(wshape)
        ins = [x, wt]
        if bias:
            ins.append(self._param((out_c,), prefix="b"))
        out = self._act_tensor((oh, ow, out_c))
        self.g.add_op(Op(self._n(kind), kind, ins, [out], {
            "stride": s, "k": (kh, kw), "pad": (pt, pb, pl, pr), "act": act,
        }))
        return out

    def dwconv(self, x: str, k: int = 3, s: int = 1, act: str = "none",
               pad: str = "same", bias: bool = True) -> str:
        c = self.g.tensors[x].hwc[2]
        return self.conv(x, c, k=k, s=s, act=act, pad=pad, bias=bias,
                         groups=c)

    def fc(self, x: str, out_c: int, act: str = "none",
           bias: bool = True) -> str:
        shp = self.g.tensors[x].shape
        c = shp[-1] if len(shp) == 1 else shp[2]
        if len(shp) == 3 and shp[:2] != (1, 1):
            raise ValueError("fc expects (1,1,C) — use global pool first")
        wt = self._param((out_c, 1, 1, c))
        ins = [x, wt]
        if bias:
            ins.append(self._param((out_c,), prefix="b"))
        out = self._act_tensor((1, 1, out_c))
        self.g.add_op(Op(self._n("fc"), "fc", ins, [out], {"act": act}))
        return out

    def add(self, a: str, b: str, act: str = "none") -> str:
        sa = self.g.tensors[a].hwc
        assert sa == self.g.tensors[b].hwc, (sa, self.g.tensors[b].hwc)
        out = self._act_tensor(sa)
        self.g.add_op(Op(self._n("add"), "add", [a, b], [out], {"act": act}))
        return out

    def mul(self, a: str, b: str) -> str:
        sa = self.g.tensors[a].hwc
        sb = self.g.tensors[b].hwc
        # broadcast (1,1,C) * (H,W,C) for SE blocks
        out_shape = tuple(max(x, y) for x, y in zip(sa, sb))
        out = self._act_tensor(out_shape)
        self.g.add_op(Op(self._n("mul"), "mul", [a, b], [out], {}))
        return out

    def activation(self, x: str, act: str) -> str:
        assert act in ACTIVATIONS, act
        out = self._act_tensor(self.g.tensors[x].hwc)
        self.g.add_op(Op(self._n("act"), "act", [x], [out], {"act": act}))
        return out

    def maxpool(self, x: str, k: int = 2, s: Optional[int] = None,
                pad: str = "valid") -> str:
        s = s or k
        h, w, c = self.g.tensors[x].hwc
        if pad == "same":
            pt, pb = same_pad(h, k, s)
            pl, pr = same_pad(w, k, s)
        else:
            pt = pb = pl = pr = 0
        oh = conv_out_dim(h, k, s, pt, pb)
        ow = conv_out_dim(w, k, s, pl, pr)
        out = self._act_tensor((oh, ow, c))
        self.g.add_op(Op(self._n("maxpool"), "maxpool", [x], [out],
                         {"k": k, "stride": s, "pad": (pt, pb, pl, pr)}))
        return out

    def global_avgpool(self, x: str) -> str:
        c = self.g.tensors[x].hwc[2]
        out = self._act_tensor((1, 1, c))
        self.g.add_op(Op(self._n("gap"), "avgpool", [x], [out],
                         {"k": 0, "stride": 1, "pad": (0, 0, 0, 0)}))
        return out

    def resize(self, x: str, factor: int = 2) -> str:
        h, w, c = self.g.tensors[x].hwc
        out = self._act_tensor((h * factor, w * factor, c))
        self.g.add_op(Op(self._n("resize"), "resize", [x], [out],
                         {"factor": factor}))
        return out

    def concat(self, xs: Sequence[str]) -> str:
        shapes = [self.g.tensors[x].hwc for x in xs]
        h, w = shapes[0][:2]
        assert all(s[:2] == (h, w) for s in shapes), shapes
        out = self._act_tensor((h, w, sum(s[2] for s in shapes)))
        self.g.add_op(Op(self._n("concat"), "concat", list(xs), [out], {}))
        return out

    def split(self, x: str, sections: int) -> List[str]:
        h, w, c = self.g.tensors[x].hwc
        assert c % sections == 0
        outs = [self._act_tensor((h, w, c // sections))
                for _ in range(sections)]
        self.g.add_op(Op(self._n("split"), "split", [x], outs,
                         {"sections": sections}))
        return outs

    def scalar(self, x: str, op: str, value: float) -> str:
        out = self._act_tensor(self.g.tensors[x].hwc)
        self.g.add_op(Op(self._n("scalar"), "scalar", [x], [out],
                         {"op": op, "value": value}))
        return out

    # ---- causal / transformer ops (LM decode path) ----
    def matmul(self, x: str, out_c: int, act: str = "none",
               bias: bool = True) -> str:
        """Row-wise linear over a (S, 1, C) sequence activation."""
        s, w, c = self.g.tensors[x].hwc
        wt = self._param((out_c, 1, 1, c))
        ins = [x, wt]
        if bias:
            ins.append(self._param((out_c,), prefix="b"))
        out = self._act_tensor((s, w, out_c))
        self.g.add_op(Op(self._n("matmul"), "matmul", ins, [out],
                         {"act": act}))
        return out

    def layernorm(self, x: str, eps: float = 1e-5) -> str:
        shp = self.g.tensors[x].hwc
        gamma = self._param((shp[2],), prefix="g")
        beta = self._param((shp[2],), prefix="b")
        # center the random gamma around 1 (a zero-mean gain would
        # collapse the signal the downstream layers see)
        self._weights[gamma] = self._weights[gamma] + 1.0
        out = self._act_tensor(shp)
        self.g.add_op(Op(self._n("layernorm"), "layernorm",
                         [x, gamma, beta], [out], {"eps": float(eps)}))
        return out

    def softmax(self, x: str) -> str:
        out = self._act_tensor(self.g.tensors[x].hwc)
        self.g.add_op(Op(self._n("softmax"), "softmax", [x], [out], {}))
        return out

    def kvappend(self, cache: str, new: str, pos: str) -> str:
        """Write the S rows of ``new`` into ``cache`` at the dynamic row
        offset held by the (1,1,1) ``pos`` tensor; returns the updated
        cache (same shape) so caches thread through the static graph."""
        cs = self.g.tensors[cache].hwc
        ns = self.g.tensors[new].hwc
        assert cs[1:] == ns[1:] and ns[0] <= cs[0], (cs, ns)
        out = self._act_tensor(cs, prefix="kv")
        self.g.add_op(Op(self._n("kvappend"), "kvappend",
                         [cache, new, pos], [out], {"rows": ns[0]}))
        return out

    def attention(self, q: str, k: str, v: str, pos: str, heads: int,
                  causal: bool = True,
                  scale: Optional[float] = None) -> str:
        """Fused QK^T -> softmax -> V against KV caches.  Query row i
        (global position pos+i) attends cache rows j < pos+S and, when
        causal, j <= pos+i — one definition covers prefill (pos=0) and
        single-token decode (S=1)."""
        qs = self.g.tensors[q].hwc
        ks = self.g.tensors[k].hwc
        assert ks == self.g.tensors[v].hwc, (ks, self.g.tensors[v].hwc)
        assert qs[2] == ks[2] and qs[2] % heads == 0, (qs, ks, heads)
        hd = qs[2] // heads
        out = self._act_tensor(qs, prefix="attn")
        self.g.add_op(Op(self._n("attention"), "attention",
                         [q, k, v, pos], [out],
                         {"heads": int(heads), "head_dim": int(hd),
                          "scale": float(scale or 1.0 / math.sqrt(hd)),
                          "causal": bool(causal),
                          "kv_len": int(ks[0])}))
        return out

    def build(self) -> "Graph":
        # verify topological consistency once at build time
        self.g.topo_ops()
        return self.g


# --------------------------------------------------------------------------
# Reference executor (numpy, float32) — the functional oracle
# --------------------------------------------------------------------------


def _apply_act(x: np.ndarray, act: str) -> np.ndarray:
    if act in ("none", None):
        return x
    if act == "relu":
        return np.maximum(x, 0)
    if act == "relu6":
        return np.clip(x, 0, 6)
    if act == "hswish":
        return x * np.clip(x + 3, 0, 6) / 6
    if act == "hsigmoid":
        return np.clip(x + 3, 0, 6) / 6
    if act == "silu":
        return x / (1 + np.exp(-np.clip(x, -30, 30)))
    if act == "sigmoid":
        return 1 / (1 + np.exp(-np.clip(x, -30, 30)))
    if act == "gelu":
        return 0.5 * x * (1 + np.tanh(np.sqrt(2 / np.pi)
                                      * (x + 0.044715 * x ** 3)))
    if act == "mish":
        sp = np.log1p(np.exp(-np.abs(x))) + np.maximum(x, 0)  # softplus
        return x * np.tanh(sp)
    if act == "sqrelu":
        r = np.maximum(x, 0)
        return r * r
    if act == "leaky":
        return np.where(x > 0, x, 0.1 * x)
    raise ValueError(act)


#: memoized einsum contraction paths.  ``np.einsum(optimize=True)``
#: re-derives the path on *every* call (~0.1 ms of pure Python) — the
#: path depends only on the subscripts and operand shapes, and passing
#: the precomputed path back executes the identical contraction, so the
#: numerical result is bit-for-bit unchanged.
_EINSUM_PATHS: Dict[tuple, list] = {}


def cached_einsum(subs: str, *ops: np.ndarray) -> np.ndarray:
    key = (subs,) + tuple(op.shape for op in ops)
    path = _EINSUM_PATHS.get(key)
    if path is None:
        path = np.einsum_path(subs, *ops, optimize=True)[0]
        _EINSUM_PATHS[key] = path
    return np.einsum(subs, *ops, optimize=path)


def _conv2d_ref(x: np.ndarray, w: np.ndarray, stride: int,
                pad: Tuple[int, int, int, int], depthwise: bool
                ) -> np.ndarray:
    """x (H,W,C); w (outC,fh,fw,inC).  Straight sliding-window conv."""
    pt, pb, pl, pr = pad
    xp = np.pad(x, ((pt, pb), (pl, pr), (0, 0)))
    H, W, C = xp.shape
    oc, fh, fw, ic = w.shape
    oh = (H - fh) // stride + 1
    ow = (W - fw) // stride + 1
    # im2col
    cols = np.empty((oh, ow, fh, fw, C), dtype=np.float32)
    for i in range(fh):
        for j in range(fw):
            cols[:, :, i, j, :] = xp[i:i + oh * stride:stride,
                                     j:j + ow * stride:stride, :]
    if depthwise:
        # w (C, fh, fw, 1)
        ker = np.transpose(w[:, :, :, 0], (1, 2, 0))  # (fh, fw, C)
        return cached_einsum("hwijc,ijc->hwc", cols, ker)
    return cached_einsum("hwijc,oijc->hwo",
                         cols.reshape(oh, ow, fh, fw, ic), w)


#: attention mask fill — finite (exp() underflows to exactly 0) so fully
#: masked columns never produce NaNs, matching kernels/flash_attention.py
NEG_INF = np.float32(-1e30)


def _pos_index(pos, smax: int, s: int) -> int:
    """Decode the dynamic (1,1,1) position tensor into a row offset,
    clamped so the S new rows always fit the cache bucket (random
    calibration feeds therefore stay well-defined)."""
    v = int(round(float(np.asarray(pos).reshape(-1)[0])))
    return min(max(v, 0), max(smax - s, 0))


def _c32(x: np.ndarray) -> np.ndarray:
    """Contiguous float32 canonical form.  The interpreter hands these
    helpers strided TCM views while the plan hands contiguous arena
    slices — BLAS/einsum summation order depends on layout, so both
    engines canonicalize before computing (this is what makes the
    engines bit-identical, not merely close)."""
    return np.ascontiguousarray(x, dtype=np.float32)


def _matmul_ref(x: np.ndarray, w: np.ndarray,
                b: Optional[np.ndarray], act: str) -> np.ndarray:
    """x (s,1,inC) row slice; w (outC,inC).  Row-independent, so tiled
    replays of any row range are bit-identical to the full pass."""
    y = cached_einsum("swc,oc->swo", _c32(x), _c32(w))
    if b is not None:
        y = y + b
    return _apply_act(y, act).astype(np.float32)


def _layernorm_ref(x: np.ndarray, gamma: np.ndarray, beta: np.ndarray,
                   eps: float) -> np.ndarray:
    x = _c32(x)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x - mu) / np.sqrt(var + eps) * gamma
            + beta).astype(np.float32)


def _softmax_ref(x: np.ndarray) -> np.ndarray:
    x = _c32(x)
    e = np.exp(x - x.max(axis=-1, keepdims=True))
    return (e / e.sum(axis=-1, keepdims=True)).astype(np.float32)


def _attention_ref(q: np.ndarray, kc: np.ndarray, vc: np.ndarray,
                   pos, attrs: Dict, q0: int = 0,
                   s_total: Optional[int] = None) -> np.ndarray:
    """Fused QK^T -> softmax -> V.  ``q`` may be a row slice starting at
    global query row ``q0`` of an op with ``s_total`` query rows; the
    mask uses global positions so tiled replays match the full pass."""
    s, _, c = q.shape
    smax = kc.shape[0]
    heads, hd = attrs["heads"], attrs["head_dim"]
    s_total = s if s_total is None else s_total
    p0 = _pos_index(pos, smax, s_total)
    qh = _c32(q).reshape(s, heads, hd).transpose(1, 0, 2)
    kh = _c32(kc).reshape(smax, heads, hd).transpose(1, 0, 2)
    vh = _c32(vc).reshape(smax, heads, hd).transpose(1, 0, 2)
    sc = cached_einsum("hsd,htd->hst", qh, kh) * np.float32(attrs["scale"])
    j = np.arange(smax)[None, None, :]
    valid = j < p0 + s_total
    if attrs.get("causal", True):
        gi = (q0 + np.arange(s))[None, :, None]
        valid = valid & (j <= p0 + gi)
    sc = np.where(valid, sc, NEG_INF)
    e = np.exp(sc - sc.max(axis=-1, keepdims=True))
    p = e / e.sum(axis=-1, keepdims=True)
    y = cached_einsum("hst,htd->hsd", p, vh)
    return y.transpose(1, 0, 2).reshape(s, 1, c).astype(np.float32)


def _kvappend_ref(cache: np.ndarray, new: np.ndarray, pos) -> np.ndarray:
    smax, s = cache.shape[0], new.shape[0]
    p0 = _pos_index(pos, smax, s)
    out = cache.astype(np.float32).copy()
    out[p0:p0 + s] = new
    return out


def reference_execute(g: Graph, inputs: Dict[str, np.ndarray],
                      weights: Dict[str, np.ndarray]
                      ) -> Dict[str, np.ndarray]:
    """Execute the graph in float32.  Returns every tensor's value."""
    vals: Dict[str, np.ndarray] = {}
    for t in g.tensors.values():
        if t.kind == "input":
            vals[t.name] = np.asarray(inputs[t.name], dtype=np.float32)
        elif t.is_param:
            vals[t.name] = np.asarray(weights[t.name], dtype=np.float32)
    for op in g.topo_ops():
        k = op.kind
        a = op.attrs
        if k in ("conv", "dwconv"):
            x = vals[op.inputs[0]]
            w = vals[op.inputs[1]]
            y = _conv2d_ref(x, w, a["stride"], a["pad"], k == "dwconv")
            if len(op.inputs) > 2:
                y = y + vals[op.inputs[2]]
            vals[op.output] = _apply_act(y, a.get("act", "none"))
        elif k == "fc":
            x = vals[op.inputs[0]].reshape(-1)
            w = vals[op.inputs[1]][:, 0, 0, :]
            y = w @ x
            if len(op.inputs) > 2:
                y = y + vals[op.inputs[2]]
            vals[op.output] = _apply_act(y, a.get("act", "none")
                                         ).reshape(1, 1, -1)
        elif k == "add":
            vals[op.output] = _apply_act(
                vals[op.inputs[0]] + vals[op.inputs[1]], a.get("act", "none"))
        elif k == "mul":
            vals[op.output] = vals[op.inputs[0]] * vals[op.inputs[1]]
        elif k == "scalar":
            x = vals[op.inputs[0]]
            v = a["value"]
            vals[op.output] = {"add": x + v, "mul": x * v,
                               "div": x / v}[a["op"]]
        elif k == "act":
            vals[op.output] = _apply_act(vals[op.inputs[0]], a["act"])
        elif k == "maxpool":
            x = vals[op.inputs[0]]
            pt, pb, pl, pr = a["pad"]
            xp = np.pad(x, ((pt, pb), (pl, pr), (0, 0)),
                        constant_values=-np.inf)
            kk, s = a["k"], a["stride"]
            H, W, C = xp.shape
            oh = (H - kk) // s + 1
            ow = (W - kk) // s + 1
            y = np.full((oh, ow, C), -np.inf, dtype=np.float32)
            for i in range(kk):
                for j in range(kk):
                    y = np.maximum(y, xp[i:i + oh * s:s, j:j + ow * s:s, :])
            vals[op.output] = y
        elif k == "avgpool":
            x = vals[op.inputs[0]]
            if a["k"] == 0:  # global
                vals[op.output] = x.mean(axis=(0, 1), keepdims=True)
            else:
                kk, s = a["k"], a["stride"]
                pt, pb, pl, pr = a["pad"]
                xp = np.pad(x, ((pt, pb), (pl, pr), (0, 0)))
                H, W, C = xp.shape
                oh = (H - kk) // s + 1
                ow = (W - kk) // s + 1
                y = np.zeros((oh, ow, C), dtype=np.float32)
                for i in range(kk):
                    for j in range(kk):
                        y += xp[i:i + oh * s:s, j:j + ow * s:s, :]
                vals[op.output] = y / (kk * kk)
        elif k == "resize":
            f = a["factor"]
            vals[op.output] = np.repeat(np.repeat(vals[op.inputs[0]], f,
                                                  axis=0), f, axis=1)
        elif k == "concat":
            vals[op.output] = np.concatenate([vals[i] for i in op.inputs],
                                             axis=2)
        elif k == "split":
            parts = np.split(vals[op.inputs[0]], a["sections"], axis=2)
            for o, p in zip(op.outputs, parts):
                vals[o] = p
        elif k == "matmul":
            b = vals[op.inputs[2]] if len(op.inputs) > 2 else None
            vals[op.output] = _matmul_ref(
                vals[op.inputs[0]], vals[op.inputs[1]][:, 0, 0, :],
                b, a.get("act", "none"))
        elif k == "layernorm":
            vals[op.output] = _layernorm_ref(
                vals[op.inputs[0]], vals[op.inputs[1]],
                vals[op.inputs[2]], a["eps"])
        elif k == "softmax":
            vals[op.output] = _softmax_ref(vals[op.inputs[0]])
        elif k == "attention":
            vals[op.output] = _attention_ref(
                vals[op.inputs[0]], vals[op.inputs[1]],
                vals[op.inputs[2]], vals[op.inputs[3]], a)
        elif k == "kvappend":
            vals[op.output] = _kvappend_ref(
                vals[op.inputs[0]], vals[op.inputs[1]],
                vals[op.inputs[2]])
        else:
            raise NotImplementedError(k)
    return vals
