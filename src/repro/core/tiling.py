"""Temporal tiling + layer fusion (paper §IV-C).

Feature maps can exceed the TCM, so tensors are split into line-range
tiles processed at different times; interleaving tiles across layers
(*layer fusion*) shrinks the live working set so intermediate maps never
round-trip through DRAM.  Following the paper:

  * **two tile-size options per tensor** (`LS_{k,i}` selection variables):
    the largest tile whose working set fits the TCM, and that size reduced
    by a fixed factor;
  * a **single-memory-level CP** whose objective minimizes the summed
    over-capacity memory profile ``sum_t MemTh_t`` (Eq. 9-12) — with
    ``MemTh_t`` tight at optimum this equals the linear form
    ``sum_t sum_j banks_j * TCM(j,t)`` used here;
  * **region decomposition**: fusion is attempted only inside regions
    whose activations cannot all be held on-chip; everything else is
    scheduled layer-by-layer (the paper's scalability lever, Table II);
  * ops whose parameters exceed a TCM fraction are partitioned **by
    output channels** ("sub-problems with fewer output features" so
    weights stream set-by-set, paper §III-B) — their outputs are
    channel-tiled and each step consumes only its own weight chunk.

The output is (a) the per-tensor tiling and (b) a global, tile-granular
compute order consumed by the scheduler.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..obs import trace as _trace
from . import cpsolver
from .formats import FormatPlan
from .ir import Graph, Op, Tensor
from .npu import NPUConfig, cross_window_spill_cost
from .program import TileRef

# --------------------------------------------------------------------------
# Receptive-field helpers (shared with the executor)
# --------------------------------------------------------------------------


def in_row_range(op: Op, out_r0: int, out_r1: int, in_h: int
                 ) -> Tuple[int, int]:
    """Input rows [r0, r1) needed to produce output rows [out_r0, out_r1).
    Clipped to the valid input range (padding supplies the rest)."""
    k = op.kind
    a = op.attrs
    if k in ("conv", "dwconv", "maxpool", "avgpool"):
        if k == "avgpool" and a.get("k", 1) == 0:
            return (0, in_h)  # global pool needs everything
        kh = a["k"][0] if isinstance(a.get("k"), tuple) else a.get("k", 1)
        s = a.get("stride", 1)
        pt = a.get("pad", (0, 0, 0, 0))[0]
        r0 = out_r0 * s - pt
        r1 = (out_r1 - 1) * s - pt + kh
        lo = max(0, min(r0, in_h))
        hi = min(in_h, max(0, r1))
        return (min(lo, hi), hi)
    if k == "resize":
        f = a["factor"]
        return (out_r0 // f, min(in_h, (out_r1 + f - 1) // f))
    if k in ("fc",):
        return (0, in_h)
    if k in ("attention", "kvappend"):
        # attention reads the whole KV cache for any query-row tile;
        # kvappend's write offset is dynamic (the pos tensor), so every
        # output tile may need any input row.  matmul / layernorm /
        # softmax are per-token and use the 1:1 default below.
        return (0, in_h)
    if in_h == 1:
        return (0, 1)  # broadcast input (e.g. SE-block (1,1,C) scale)
    # elementwise / concat / split / act / scalar: 1:1 rows
    return (out_r0, min(in_h, out_r1))


# --------------------------------------------------------------------------
# Tiling data model
# --------------------------------------------------------------------------


@dataclass
class TensorTiles:
    tensor: str
    tiles: List[TileRef]

    @property
    def n(self) -> int:
        return len(self.tiles)

    @property
    def axis(self) -> str:
        return self.tiles[0].axis if self.tiles else "rows"

    def covering(self, r0: int, r1: int) -> List[TileRef]:
        """Tiles overlapping output-row range [r0, r1).  Channel-tiled
        tensors span all rows, so every tile overlaps."""
        if self.axis == "chan":
            return list(self.tiles)
        return [t for t in self.tiles if t.r0 < r1 and t.r1 > r0]

    def covering_chan(self, c0: int, c1: int) -> List[TileRef]:
        if self.axis != "chan":
            return list(self.tiles)
        return [t for t in self.tiles if t.r0 < c1 and t.r1 > c0]


@dataclass
class ComputeStep:
    """One tile-granular compute: `op` producing rows (axis == "rows") or
    channels (axis == "chan") [r0, r1) of each of its outputs."""

    op_name: str
    r0: int
    r1: int
    axis: str = "rows"

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.op_name}[{self.r0}:{self.r1}@{self.axis}]"


@dataclass
class TilingResult:
    tiles: Dict[str, TensorTiles]           # tensor -> tiles
    order: List[ComputeStep]                # global tile compute order
    regions: List[List[str]]                # op-name regions (diagnostics)
    fusion_objective: float = 0.0           # CP objective (memory-ticks)
    stats: Dict = field(default_factory=dict)
    #: alternate plan with every windowed region's order replaced by its
    #: greedy order — set only when they differ.  The compile ladder
    #: races both through the scheduler and keeps the better program
    #: (the window CP objective is a proxy; the never-worse-than-greedy
    #: guarantee comes from this race).  Never serialized.
    fallback: Optional["TilingResult"] = None

    def tile_of(self, tensor: str, idx: int) -> TileRef:
        return self.tiles[tensor].tiles[idx]


def _mk_tiles(t: Tensor, n: int, bank_bytes: int,
              axis: str = "rows") -> List[TileRef]:
    """Split tensor into `n` tiles along rows/channels (params: outC)."""
    if t.is_param:
        oc = t.shape[0]
        n = min(n, max(oc, 1))
        per = [oc // n + (1 if i < oc % n else 0) for i in range(n)]
        refs, c0 = [], 0
        bytes_per_oc = t.bytes / max(oc, 1)
        for i, p in enumerate(per):
            nb = max(1, math.ceil(p * bytes_per_oc))
            refs.append(TileRef(t.name, i, c0, c0 + p, nb,
                                max(1, math.ceil(nb / bank_bytes)), "chan"))
            c0 += p
        return refs
    if axis == "chan":
        C = t.shape[-1]
        n = min(n, max(C, 1))
        per = [C // n + (1 if i < C % n else 0) for i in range(n)]
        refs, c0 = [], 0
        bytes_per_c = t.bytes / max(C, 1)
        for i, p in enumerate(per):
            nb = max(1, math.ceil(p * bytes_per_c))
            refs.append(TileRef(t.name, i, c0, c0 + p, nb,
                                max(1, math.ceil(nb / bank_bytes)), "chan"))
            c0 += p
        return refs
    H = t.shape[0] if len(t.shape) == 3 else 1
    n = min(n, max(H, 1))
    rows = [H // n + (1 if i < H % n else 0) for i in range(n)]
    refs, r0 = [], 0
    bytes_per_row = t.bytes / max(H, 1)
    for i, rr in enumerate(rows):
        nb = max(1, math.ceil(rr * bytes_per_row))
        refs.append(TileRef(t.name, i, r0, r0 + rr, nb,
                            max(1, math.ceil(nb / bank_bytes)), "rows"))
        r0 += rr
    return refs


# --------------------------------------------------------------------------
# Tile-size options (the paper's LS_{k,i}, two options per tensor)
# --------------------------------------------------------------------------


def _param_bytes(g: Graph, op: Op) -> int:
    return sum(p.bytes for p in g.param_inputs(op))


def _chan_split(cfg: NPUConfig, g: Graph, op: Op) -> int:
    """#channel sub-problems for a huge-parameter op (0 = not needed).

    The compute steps of a channel-split op follow its *weight* chunks
    (so only one chunk streams through TCM at a time); its output is
    tiled separately at whole-bank granularity (see _tile_options) and
    written channel-slice by channel-slice into resident tiles — output
    co-residency therefore costs the tensor's true footprint, not one
    bank per weight chunk."""
    pb = _param_bytes(g, op)
    if op.kind in ("conv", "fc", "matmul") and pb > cfg.tcm_bytes // 4:
        return min(int(math.ceil(pb / (cfg.tcm_bytes / 8))),
                   g.tensors[op.output].shape[-1])
    return 0


def _tile_options(cfg: NPUConfig, g: Graph, budget_frac: float = 0.5,
                  naive: bool = False
                  ) -> Dict[str, Tuple[int, int, str]]:
    """tensor -> (n_tiles option A, option B, axis).

    ``naive=True`` reproduces the reference-stack behaviour the paper
    describes in §IV-C: the tile bound only ensures the tile itself fits
    the TCM — it ignores the dependencies that must be co-resident, so
    adjacent layers' buffers thrash through DRAM.  This is the
    eNPU-A/B-style baseline tiling."""
    budget = int(cfg.tcm_bytes * budget_frac)
    opts: Dict[str, Tuple[int, int, str]] = {}
    for t in g.tensors.values():
        if t.is_param:
            n = 1
            while t.bytes / n > cfg.tcm_bytes / 8 and n < max(t.shape[0], 1):
                n *= 2
            opts[t.name] = (n, n, "chan")
            continue
        prod = t.producer
        if prod is not None:
            cs = _chan_split(cfg, g, g.op(prod))
            if cs:
                # bank-clamped: each output chunk fills >= 1 bank, so a
                # consumer gathering the whole tensor holds its true
                # byte footprint, not one bank per weight chunk
                n_out = max(1, min(cs, math.ceil(t.bytes
                                                 / cfg.bank_bytes)))
                opts[t.name] = (n_out, n_out, "chan")
                continue
        H = t.shape[0] if len(t.shape) == 3 else 1
        if naive:
            # naive upper bound: the tile alone fits — dependencies are
            # NOT accounted (shrinks along the retry ladder via
            # budget_frac so the baseline still always compiles)
            frac = min(0.45, budget_frac * 0.9)
            n = 1
            while t.bytes / n > cfg.tcm_bytes * frac and n < max(H, 1):
                n *= 2
            opts[t.name] = (n, n, "rows")
            continue
        n = 1
        while n < max(H, 1):
            rows = math.ceil(H / n)
            ws = math.ceil(t.bytes / n)
            if prod is not None:
                op = g.op(prod)
                for x in g.act_inputs(op):
                    ih = x.shape[0] if len(x.shape) == 3 else 1
                    a, b = in_row_range(op, 0, rows, ih)
                    ws += math.ceil(x.bytes * (b - a) / max(ih, 1))
                ws += sum(min(p.bytes, budget // 4)
                          for p in g.param_inputs(op))
            if ws <= budget:
                break
            n *= 2
        opts[t.name] = (n, min(2 * n, max(H, 1)), "rows")
    return opts


# --------------------------------------------------------------------------
# Region decomposition
# --------------------------------------------------------------------------


def _regions(cfg: NPUConfig, g: Graph,
             opts: Dict[str, Tuple[int, int, str]]) -> List[List[Op]]:
    """Maximal runs of row-tiled ops whose activation working set exceeds
    the TCM — fusion candidates; channel-partitioned ops and cold ops form
    singleton regions (paper §IV-C)."""
    thresh = cfg.tcm_bytes // 2
    regions: List[List[Op]] = []
    cur: List[Op] = []
    cur_hot = False
    for op in g.topo_ops():
        acts = [g.tensors[o] for o in op.outputs] + g.act_inputs(op)
        chan = any(opts[o][2] == "chan" for o in op.outputs)
        hot = (not chan) and sum(t.bytes for t in acts) > thresh
        if hot and cur_hot:
            cur.append(op)
        else:
            if cur:
                regions.append(cur)
            cur = [op]
            cur_hot = hot
    if cur:
        regions.append(cur)
    return regions


# --------------------------------------------------------------------------
# Greedy fused order (warm start + large-region fallback)
# --------------------------------------------------------------------------


def _greedy_order(g: Graph, region: List[Op],
                  tiles: Dict[str, TensorTiles]) -> List[ComputeStep]:
    """Depth-first fusion: emit each op's tiles as soon as the input rows
    they need have been produced — classic cascaded/fused execution."""
    region_ops = {op.name for op in region}
    produced_rows: Dict[str, int] = {}   # tensor -> rows available
    for t in g.tensors.values():
        if t.producer is None or t.producer not in region_ops:
            produced_rows[t.name] = t.shape[0] if len(t.shape) == 3 else 1
    emitted: Dict[str, int] = {op.name: 0 for op in region}
    order: List[ComputeStep] = []
    progress = True
    while progress:
        progress = False
        for op in region:
            out0 = g.tensors[op.outputs[0]]
            otiles = tiles[out0.name].tiles
            while emitted[op.name] < len(otiles):
                tl = otiles[emitted[op.name]]
                ok = True
                for x in g.act_inputs(op):
                    ih = x.shape[0] if len(x.shape) == 3 else 1
                    _, need = in_row_range(op, tl.r0, tl.r1, ih)
                    if produced_rows.get(x.name, 0) < need:
                        ok = False
                        break
                if not ok:
                    break
                order.append(ComputeStep(op.name, tl.r0, tl.r1, tl.axis))
                emitted[op.name] += 1
                for o in op.outputs:
                    produced_rows[o] = tl.r1 \
                        if len(g.tensors[o].shape) == 3 else 1
                progress = True
    _emit_leftovers(g, region, tiles, emitted, order)
    return order


def _emit_leftovers(g: Graph, region: List[Op],
                    tiles: Dict[str, TensorTiles],
                    emitted: Dict[str, int],
                    order: List[ComputeStep]) -> None:
    """Safety net for tiles the fixpoint loop could not place (e.g. a
    region handed over in non-topological order).  Leftovers are emitted
    op-by-op in *topological* order, which is row-dependency-sound: by
    the time an op's remaining tiles are appended, every region-internal
    producer has its full output in `order` — either from the fixpoint
    loop or appended earlier in this sweep."""
    left = [op for op in region
            if emitted[op.name]
            < len(tiles[g.tensors[op.outputs[0]].name].tiles)]
    if not left:
        return
    rank = {op.name: i for i, op in enumerate(g.topo_ops())}
    for op in sorted(left, key=lambda o: rank[o.name]):
        out0 = g.tensors[op.outputs[0]]
        otiles = tiles[out0.name].tiles
        for tl in otiles[emitted[op.name]:]:
            order.append(ComputeStep(op.name, tl.r0, tl.r1, tl.axis))
        emitted[op.name] = len(otiles)


def validate_order(g: Graph, region: List[Op],
                   tiles: Dict[str, TensorTiles],
                   order: Sequence[ComputeStep]) -> List[str]:
    """Row-dependency audit of one region's compute order.

    Checks, tile-granularly (what the scheduler and executor require):
      * every step names a region op and no step repeats;
      * every tile of every op's primary output is produced exactly once;
      * when a step runs, every region-internal input tile overlapping
        its receptive field (:func:`in_row_range`) was already produced.

    Returns human-readable violations (empty list == sound).  Shared by
    the windowed-fusion stitcher (seam safety net) and the property
    tests in ``tests/test_fusion_windows.py``.
    """
    errs: List[str] = []
    region_ops = {op.name for op in region}
    produced: Dict[str, set] = {}
    for op in region:
        for o in op.outputs:
            produced[o] = set()
    seen: set = set()
    for pos, st in enumerate(order):
        if st.op_name not in region_ops:
            errs.append(f"step {pos}: {st.op_name} not in region")
            continue
        op = g.op(st.op_name)
        skey = (st.op_name, st.r0, st.r1, st.axis)
        if skey in seen:
            errs.append(f"step {pos}: duplicate {st!r}")
        seen.add(skey)
        for x in g.act_inputs(op):
            if x.producer not in region_ops:
                continue
            ih = x.shape[0] if len(x.shape) == 3 else 1
            if st.axis == "chan":
                a, b = 0, ih
            else:
                a, b = in_row_range(op, st.r0, st.r1, ih)
            for tl in tiles[x.name].covering(a, b):
                if tl.index not in produced[x.name]:
                    errs.append(
                        f"step {pos}: {st!r} needs {x.name}#{tl.index} "
                        f"(rows [{a},{b})) before it is produced")
        for o in op.outputs:
            tt = tiles[o]
            cov = tt.covering_chan(st.r0, st.r1) if st.axis == "chan" \
                else tt.covering(st.r0, st.r1)
            for tl in cov:
                if tl.r0 >= st.r0 and tl.r1 <= st.r1:
                    produced[o].add(tl.index)
    for op in region:
        o0 = op.outputs[0]
        missing = [tl.index for tl in tiles[o0].tiles
                   if tl.index not in produced[o0]]
        if missing:
            errs.append(f"{op.name}: output tiles {missing} never computed")
    return errs


# --------------------------------------------------------------------------
# Fusion CP (per region)
# --------------------------------------------------------------------------


@dataclass
class _FusionCP:
    """One region's fusion CP: model + var maps + greedy fallback.

    Regions share no CP variables, so the models of every fusion-eligible
    region are built first and the batch is solved concurrently
    (cpsolver.solve_many) before the solutions are read back in region
    order."""

    region: List[Op]
    cand: Dict[str, List[List[TileRef]]]
    LS: Dict[Tuple[str, int], int]
    comp: Dict[Tuple[str, int, int, int], int]
    model: CPModel
    warm: Dict[int, int]
    greedy: List[ComputeStep]

    def extract(self, g: Graph, sol: cpsolver.Solution
                ) -> Tuple[Dict[str, int], List[ComputeStep], float]:
        if not sol.feasible:  # fall back to the greedy warm start
            chosen = {onm: len(self.cand[onm][0]) for onm in self.cand}
            return chosen, self.greedy, float("inf")
        chosen: Dict[str, int] = {}
        for oname, variants in self.cand.items():
            for k in range(len(variants)):
                if sol[self.LS[(oname, k)]]:
                    chosen[oname] = len(variants[k])
        steps: List[Tuple[int, ComputeStep]] = []
        for (opn, k, j, t), v in self.comp.items():
            if sol[v]:
                oname = g.op(opn).outputs[0]
                if sol[self.LS[(oname, k)]]:
                    tl = self.cand[oname][k][j]
                    steps.append((t, ComputeStep(opn, tl.r0, tl.r1,
                                                 tl.axis)))
        steps.sort(key=lambda x: x[0])
        return chosen, [s for _, s in steps], sol.objective


def _build_fusion_cp(cfg: NPUConfig, g: Graph, region: List[Op],
                     opts: Dict[str, Tuple[int, int, str]]) -> _FusionCP:
    """Build the CP choosing LS (tiles-per-tensor) and tile order for one
    region."""
    region_ops = {op.name for op in region}
    bank = cfg.bank_bytes

    # candidate tilings per produced tensor (option A / B)
    cand: Dict[str, List[List[TileRef]]] = {}
    for op in region:
        for oname in op.outputs:
            t = g.tensors[oname]
            a, b, axis = opts[oname]
            variants = [_mk_tiles(t, a, bank, axis)]
            if b != a:
                variants.append(_mk_tiles(t, b, bank, axis))
            cand[oname] = variants

    m = cpsolver.CPModel(f"fusion:{region[0].name}")
    LS: Dict[Tuple[str, int], int] = {}
    for oname, variants in cand.items():
        vs = [m.bool(f"LS[{oname},{k}]") for k in range(len(variants))]
        for k, v in enumerate(vs):
            LS[(oname, k)] = v
        m.add_exactly_one(vs, f"one-size:{oname}")

    # T ticks = total tiles of the *larger* option per op
    T = sum(max(len(v) for v in cand[op.outputs[0]]) for op in region)
    T = max(T, 1)

    comp: Dict[Tuple[str, int, int, int], int] = {}
    state: Dict[Tuple[str, int, int, int], int] = {}
    for op in region:
        oname = op.outputs[0]
        for k, variant in enumerate(cand[oname]):
            for j, tl in enumerate(variant):
                cvars = []
                for t in range(T):
                    cv = m.bool(f"c[{op.name},{k},{j},{t}]")
                    comp[(op.name, k, j, t)] = cv
                    cvars.append(cv)
                # computed exactly once iff option selected
                m.add([(cv, 1) for cv in cvars]
                      + [(LS[(oname, k)], -1)], "==", 0,
                      f"once:{op.name}/{k}/{j}")
                # state chain (single-level model: enter only via compute)
                prev = None
                for t in range(T):
                    sv = m.bool(f"s[{oname},{k},{j},{t}]")
                    state[(oname, k, j, t)] = sv
                    terms = [(sv, 1), (comp[(op.name, k, j, t)], -1)]
                    if prev is not None:
                        terms.append((prev, -1))
                    m.add(terms, "<=", 0, f"persist:{oname}/{k}/{j}/{t}")
                    prev = sv

    # at most one compute per tick
    for t in range(T):
        m.add([(v, 1) for (onm, k, j, tt), v in comp.items() if tt == t],
              "<=", 1, f"one-comp:{t}")

    # dependency: computing a tile needs covering region-internal input
    # tiles resident (under whichever option of the input is selected)
    for op in region:
        oname = op.outputs[0]
        for k, variant in enumerate(cand[oname]):
            for j, tl in enumerate(variant):
                for x in g.act_inputs(op):
                    if x.producer not in region_ops:
                        continue
                    ih = x.shape[0] if len(x.shape) == 3 else 1
                    a, b = in_row_range(op, tl.r0, tl.r1, ih)
                    for k2, variant2 in enumerate(cand[x.name]):
                        for j2, tl2 in enumerate(variant2):
                            if tl2.r0 < b and tl2.r1 > a:
                                for t in range(T):
                                    m.add([(comp[(op.name, k, j, t)], 1),
                                           (LS[(x.name, k2)], 1),
                                           (state[(x.name, k2, j2, t)], -1)],
                                          "<=", 1)

    # objective: sum_t sum_j banks_j * state  (== sum_t MemTh_t at optimum)
    obj = [(sv, cand[oname][k][j].banks)
           for (oname, k, j, t), sv in state.items()]
    m.minimize(obj)

    # ---- warm start: option A everywhere + greedy DFS order ----
    ws_tiles = {oname: TensorTiles(oname, cand[oname][0]) for oname in cand}
    greedy = _greedy_order(g, region, ws_tiles)
    ws: Dict[int, int] = {v: 0 for v in range(m.n_vars)}
    for oname in cand:
        ws[LS[(oname, 0)]] = 1
    tick = 0
    step_tick: Dict[Tuple[str, int], int] = {}
    for st in greedy:
        op = g.op(st.op_name)
        oname = op.outputs[0]
        for j, tl in enumerate(cand[oname][0]):
            if tl.r0 == st.r0:
                ws[comp[(op.name, 0, j, tick)]] = 1
                step_tick[(op.name, j)] = tick
        tick += 1
    for op in region:
        oname = op.outputs[0]
        for j, tl in enumerate(cand[oname][0]):
            t0 = step_tick.get((op.name, j))
            if t0 is None:
                continue
            last = t0
            for cons_name in g.tensors[oname].consumers:
                if cons_name not in region_ops:
                    last = T - 1
                    break
                cop = g.op(cons_name)
                c_out = cop.outputs[0]
                ih = g.tensors[oname].shape[0] \
                    if len(g.tensors[oname].shape) == 3 else 1
                for j2, tl2 in enumerate(cand[c_out][0]):
                    a, b = in_row_range(cop, tl2.r0, tl2.r1, ih)
                    if tl.r0 < b and tl.r1 > a:
                        t2 = step_tick.get((cons_name, j2))
                        if t2 is not None:
                            last = max(last, t2)
            for t in range(t0, last + 1):
                ws[state[(oname, 0, j, t)]] = 1

    return _FusionCP(region, cand, LS, comp, m, ws, greedy)


# --------------------------------------------------------------------------
# Windowed fusion CP (oversized regions)
# --------------------------------------------------------------------------
#
# The full fusion CP is O(ops x options x tiles x T) variables, so it is
# only tractable up to ~max_cp_tiles tiles per region — yet the regions
# with the largest working sets (and the most DDR traffic to save) are
# exactly the ones over that cap.  Instead of dropping them onto the
# greedy order wholesale, an oversized region is split into overlapping
# *windows* over its greedy step sequence:
#
#   * tile sizes are fixed at option A (the fused default) — windows
#     optimize the *order* of compute steps plus the residency of
#     boundary tiles, not LS;
#   * each window is a small CP (<= max_cp_window_tiles steps): one
#     compute per tick, tile-granular row dependencies, and a state
#     chain per consumed tile.  Tiles produced before the window enter
#     as *boundary state*: a `carry` precondition (fixed via
#     cpsolver's fixed-assignment support) plus per-tick entry vars
#     priced at npu.cross_window_spill_cost — the window trades "hold
#     the tile resident" (banks per tick) against "refetch it from DDR";
#   * windows share no variables, so the whole batch — across all
#     oversized regions — solves concurrently through
#     cpsolver.solve_many, each window warm-started from its greedy
#     slice (the CP never returns an order worse than greedy under the
#     memory objective);
#   * stitching: emit each window's solved order in window sequence,
#     dropping steps an earlier window already emitted (the overlap),
#     then re-validate the seam with validate_order.  Any violation —
#     or an infeasible window — falls back to the greedy order.


#: objective scaling of the windowed fusion CP — one bank-tick of
#: residency costs 1, so DDR prices (integer multiples of a bank's DMA
#: cost) are scaled up to keep "hold a tile a few more ticks" cheaper
#: than "bounce it through DDR" under capacity.
_SPILL_SCALE = 16


def _est_region_tiles(opts: Dict[str, Tuple[int, int, str]],
                      region: List[Op]) -> int:
    """Upper-bound tile count of a region's fusion-CP model: the larger
    tile-size option of **every** output of every op (multi-output ops
    contribute all their outputs — the candidate sets the model builds)."""
    return sum(max(opts[o][0], opts[o][1])
               for op in region for o in op.outputs)


def _window_bounds(T: int, size: int, overlap: int) -> List[Tuple[int, int]]:
    """Overlapping [a, b) windows covering greedy steps [0, T)."""
    size = max(2, int(size))
    overlap = max(0, min(int(overlap), size - 1))
    bounds: List[Tuple[int, int]] = []
    a = 0
    while True:
        b = min(a + size, T)
        bounds.append((a, b))
        if b >= T:
            return bounds
        a = b - overlap


def _step_products(g: Graph, tiles: Dict[str, TensorTiles],
                   st: ComputeStep) -> List[Tuple[str, TileRef]]:
    """Output tiles (of every output) fully covered by one compute step."""
    op = g.op(st.op_name)
    out: List[Tuple[str, TileRef]] = []
    for oname in op.outputs:
        for tl in tiles[oname].tiles:
            if tl.axis == st.axis and tl.r0 >= st.r0 and tl.r1 <= st.r1:
                out.append((oname, tl))
    return out


def _step_needs(g: Graph, region_ops: set, tiles: Dict[str, TensorTiles],
                st: ComputeStep, internal: bool = True
                ) -> List[Tuple[str, TileRef]]:
    """Input tiles a step's receptive field touches — region-internal
    producers (``internal=True``) or external ones (model inputs and
    other regions' outputs, ``internal=False``)."""
    op = g.op(st.op_name)
    out: List[Tuple[str, TileRef]] = []
    for x in g.act_inputs(op):
        if (x.producer in region_ops) != internal:
            continue
        ih = x.shape[0] if len(x.shape) == 3 else 1
        a, b = in_row_range(op, st.r0, st.r1, ih)
        for tl in tiles[x.name].covering(a, b):
            out.append((x.name, tl))
    return out


@dataclass
class _WindowCP:
    """One window of an oversized fusion region: model + greedy slice."""

    lo: int                              # slice start in the greedy order
    steps: List[ComputeStep]
    model: CPModel
    comp: Dict[Tuple[int, int], int]     # (local step, tick) -> var
    warm: Dict[int, int]
    hi: int = 0                          # slice end in the greedy order
    prefix: frozenset = frozenset()      # tiles produced before ``lo``
    # key -> residency var at the window's last tick; the sequential
    # refinement reads the adopted solution here to learn which tiles
    # this window hands its successor still resident
    state_last: Dict[Tuple[str, int], int] = field(default_factory=dict)

    def order(self, sol: cpsolver.Solution
              ) -> Tuple[List[ComputeStep], float]:
        if not sol.feasible:             # fall back to the greedy slice
            return list(self.steps), float("inf")
        placed = sorted((t, i) for (i, t), v in self.comp.items()
                        if sol[v])
        return [self.steps[i] for _, i in placed], sol.objective


def _wavefront_perm(steps: List[ComputeStep],
                    needs: List[set], prods: List[set],
                    produced_before: set,
                    depth: Dict[str, int]) -> List[int]:
    """Demand-driven permutation of one window's steps: repeatedly emit
    the next tile of the *deepest* op whose dependencies are met.  The
    layer-wise greedy slice keeps whole intermediate tensors live; the
    wavefront interleaves producer/consumer tiles so each lives only a
    few ticks — a far better basin for the window CP's small node budget
    to polish than to find."""
    remaining: Dict[str, List[int]] = {}
    for i, st in enumerate(steps):
        remaining.setdefault(st.op_name, []).append(i)
    names = sorted(remaining, key=lambda n: -depth.get(n, 0))
    produced = set(produced_before)
    out: List[int] = []
    while len(out) < len(steps):
        for name in names:
            q = remaining[name]
            if q and needs[q[0]] <= produced:
                i = q.pop(0)
                out.append(i)
                produced |= prods[i]
                break
        else:   # stuck (cannot happen for a valid greedy slice): finish
            rest = sorted(i for q in remaining.values() for i in q)
            out.extend(rest)
            break
    return out


def _build_window_fusion_cp(cfg: NPUConfig, g: Graph, region: List[Op],
                            tiles: Dict[str, TensorTiles],
                            greedy: List[ComputeStep], lo: int, hi: int,
                            produced_before: set,
                            held: frozenset = frozenset()
                            ) -> Optional[_WindowCP]:
    """CP re-ordering greedy steps [lo, hi) of one region.

    ``produced_before`` is the boundary state threaded in from the
    preceding windows: the (tensor, tile-index) keys the greedy prefix
    [0, lo) has produced.  Returns None when a needed tile is neither in
    the window nor in the prefix (invariant break — caller goes greedy).

    ``held`` is the sequential-refinement input: tiles the *previous*
    window's adopted solution keeps resident at its last tick.  Those
    get their carry fixed to 1 — first-tick residency without paying a
    DDR re-entry — while everything else keeps the concurrent-solve
    assumption (carry 0, the window starts from DDR).
    """
    region_ops = {op.name for op in region}
    ws = greedy[lo:hi]
    Tw = len(ws)
    m = cpsolver.CPModel(f"fusion-win:{g.name}[{lo}:{hi})")

    comp: Dict[Tuple[int, int], int] = {}
    for i in range(Tw):
        vs = [m.bool(f"c[{i},{t}]") for t in range(Tw)]
        for t, v in enumerate(vs):
            comp[(i, t)] = v
        m.add_exactly_one(vs, f"once:{i}")
    for t in range(Tw):
        m.add([(comp[(i, t)], 1) for i in range(Tw)], "<=", 1,
              f"one-comp:{t}")

    producers: Dict[Tuple[str, int], List[int]] = {}
    refs: Dict[Tuple[str, int], TileRef] = {}
    prods: List[set] = []
    for i, st in enumerate(ws):
        p = set()
        for oname, tl in _step_products(g, tiles, st):
            key = (oname, tl.index)
            producers.setdefault(key, []).append(i)
            refs[key] = tl
            p.add(key)
        prods.append(p)
    # a step needs resident: its region-internal input tiles, its
    # region-external input tiles (the model input / other regions'
    # outputs) and its op's weight tiles.  Leaving weights or external
    # inputs out of the model lets the CP interleave many ops and thrash
    # exactly those tensors through DDR.
    needs: List[set] = []
    consumed: Dict[Tuple[str, int], List[int]] = {}
    always_keys: set = set()      # available from DDR at any tick
    for i, st in enumerate(ws):
        row = set()
        for xname, tl in _step_needs(g, region_ops, tiles, st):
            key = (xname, tl.index)
            refs[key] = tl
            row.add(key)
        for xname, tl in _step_needs(g, region_ops, tiles, st,
                                     internal=False):
            key = (xname, tl.index)
            refs[key] = tl
            row.add(key)
            always_keys.add(key)
        for p in g.param_inputs(g.op(st.op_name)):
            for tl in tiles[p.name].tiles:
                key = (p.name, tl.index)
                refs[key] = tl
                row.add(key)
                always_keys.add(key)
        for key in row:
            consumed.setdefault(key, []).append(i)
        needs.append(row)

    boundary = [k for k in consumed
                if k not in producers and k not in always_keys]
    if any(k not in produced_before for k in boundary):
        return None

    # boundary/param tiles start the window in DDR — the windows of a
    # batch solve concurrently, so no window may assume its predecessor
    # left a tile resident.  The sequential refinement pass rebuilds the
    # window with ``held`` populated and fixes carry to 1 for exactly
    # those tiles, letting them start the window resident for free.
    carry = carry_held = None
    if boundary or always_keys:
        carry = m.bool("carry")
        m.fix(carry, 0)
        if held:
            carry_held = m.bool("carry_held")
            m.fix(carry_held, 1)

    # Objective, all in units of (bank-tick / _SPILL_SCALE):
    #   * DDR re-entry of a non-window tile: its DMA cost normalized to
    #     one bank's DMA (npu.cross_window_spill_cost) x _SPILL_SCALE;
    #   * per-tick over-capacity occupancy (the paper's Eq. 9 MemTh_t):
    #     every bank above the cap costs ~ one bank round trip — over
    #     the cap the scheduler *will* spill, so overflow and explicit
    #     re-entries are priced on the same scale;
    #   * a 1-per-bank-tick residency tie-break, so under-capacity
    #     solutions still prefer compact live sets (the unmodeled rest
    #     of the program competes for the same banks).
    # Holding a tile under capacity is therefore ~free relative to
    # refetching it — matching what the DAE scheduler actually does.
    state: Dict[Tuple[Tuple[str, int], int], int] = {}
    entry: Dict[Tuple[Tuple[str, int], int], int] = {}
    obj: List[Tuple[int, int]] = []
    tick_terms: List[List[Tuple[int, int]]] = [[] for _ in range(Tw)]
    for key in sorted(consumed):
        tl = refs[key]
        in_window = key in producers
        if in_window:
            spill = 0
        else:
            # params and model inputs still live in DRAM — a re-entry is
            # one fetch; activations must round-trip (push + refetch)
            t = g.tensors[key[0]]
            one_way = t.is_param or t.kind == "input"
            spill = _SPILL_SCALE * cross_window_spill_cost(
                cfg, tl.nbytes, round_trip=not one_way)
        prev = None
        for t in range(Tw):
            sv = m.bool(f"s[{key[0]}#{key[1]},{t}]")
            state[(key, t)] = sv
            terms = [(sv, 1)]
            if prev is not None:
                terms.append((prev, -1))
            if in_window:
                terms += [(comp[(p, t)], -1) for p in producers[key]]
            else:
                ev = m.bool(f"e[{key[0]}#{key[1]},{t}]")
                entry[(key, t)] = ev
                terms.append((ev, -1))
                if prev is None:
                    terms.append((carry_held if key in held else carry,
                                  -1))
                obj.append((ev, spill))
            m.add(terms, "<=", 0, f"persist:{key}/{t}")
            obj.append((sv, tl.banks))
            tick_terms[t].append((sv, tl.banks))
            prev = sv

    for i, row in enumerate(needs):
        for key in row:
            for t in range(Tw):
                m.add([(comp[(i, t)], 1), (state[(key, t)], -1)],
                      "<=", 0, f"dep:{i}/{key}/{t}")
    over_w = _SPILL_SCALE * cross_window_spill_cost(cfg, cfg.bank_bytes)
    cap = max(4, (cfg.tcm_banks * 3) // 4)
    mts = [cpsolver.MaxTerm([(0, []),
                             (-cap * over_w,
                              [(sv, b * over_w) for sv, b in terms])])
           for terms in tick_terms if terms]
    m.minimize(obj, max_terms=mts)

    def _warm_from(pos: Dict[int, int]) -> Dict[int, int]:
        """Full warm assignment from a step -> tick placement."""
        w: Dict[int, int] = {}
        for i, t in pos.items():
            w[comp[(i, t)]] = 1
        for key, users in consumed.items():
            last = max(pos[i] for i in users)
            if key in producers:
                first = min(pos[p] for p in producers[key])
            else:
                first = min(pos[i] for i in users)
                w[entry[(key, first)]] = 1
            for t in range(first, last + 1):
                w[state[(key, t)]] = 1
        return w

    def _objective(w: Dict[int, int]) -> float:
        vals = [0] * m.n_vars
        for v, val in w.items():
            vals[v] = val
        for v, val in m.fixed.items():
            vals[v] = val
        if m.check(vals):
            return float("inf")
        return m.objective_value(vals)

    # two warm-start candidates: the greedy slice (step i at tick i) and
    # the wavefront interleaving — the incumbent is whichever the model
    # scores lower, so the CP solution is never worse than either
    depth = {op.name: i for i, op in enumerate(region)}
    greedy_warm = _warm_from({i: i for i in range(Tw)})
    perm = _wavefront_perm(ws, needs, prods,
                           produced_before | always_keys, depth)
    wave_warm = _warm_from({i: t for t, i in enumerate(perm)})
    warm = min((greedy_warm, wave_warm), key=_objective)
    if _objective(warm) == float("inf"):     # defensive: greedy must fit
        warm = greedy_warm
    state_last = {key: state[(key, Tw - 1)] for key in consumed}
    return _WindowCP(lo, list(ws), m, comp, warm, hi=hi,
                     prefix=frozenset(produced_before),
                     state_last=state_last)


@dataclass
class _WindowedFusion:
    """An oversized region's window batch + stitcher."""

    region: List[Op]
    tiles: Dict[str, TensorTiles]
    greedy: List[ComputeStep]
    windows: List[_WindowCP]

    def refine(self, cfg: NPUConfig, g: Graph,
               sols: Sequence[Optional[cpsolver.Solution]], *,
               time_limit_s: float, stall_limit_s: Optional[float],
               stall_limit_nodes: Optional[int], engine: str
               ) -> Tuple[List[Optional[cpsolver.Solution]], int]:
        """Sequential second pass over the window chain.

        The concurrent batch solve prices every boundary tile as a DDR
        re-entry because no window may assume anything about its
        neighbours.  Stitched execution *is* sequential though, so after
        the batch lands each window (except the first) is rebuilt with
        ``held`` = the tiles the previous window's adopted solution
        keeps resident at its last tick — their carry is fixed to 1 and
        the phantom re-entry cost disappears.  Adopted refinements chain
        forward: window ``i+1`` reads residency from the *refined*
        window ``i``.  Returns the updated solution list and how many
        windows adopted a refined order."""
        sols = list(sols)
        refined = 0
        for wi in range(1, len(self.windows)):
            prev, psol = self.windows[wi - 1], sols[wi - 1]
            if psol is None or not psol.feasible:
                continue
            held = frozenset(k for k, sv in prev.state_last.items()
                             if psol[sv])
            if not held:
                continue
            w = self.windows[wi]
            w2 = _build_window_fusion_cp(cfg, g, self.region, self.tiles,
                                         self.greedy, w.lo, w.hi,
                                         set(w.prefix), held=held)
            if w2 is None:
                continue
            [sol2] = cpsolver.solve_many(
                [cpsolver.SolveTask(w2.model,
                                    time_limit_s=time_limit_s,
                                    warm_start=w2.warm,
                                    stall_limit_s=stall_limit_s,
                                    stall_limit_nodes=stall_limit_nodes,
                                    engine=engine)],
                parallel=False)
            if not sol2.feasible:
                continue
            self.windows[wi] = w2
            sols[wi] = sol2
            refined += 1
        return sols, refined

    def stitch(self, g: Graph, sols: Sequence[cpsolver.Solution]
               ) -> Tuple[List[ComputeStep], float, Dict[str, int]]:
        """Merge per-window orders: emit windows in sequence, dropping
        the overlap steps an earlier window already emitted, then
        re-validate row-dependency feasibility of the seam.  Returns
        (order, objective, info); any violation falls back to greedy."""
        emitted: set = set()
        order: List[ComputeStep] = []
        objective = 0.0
        solved = fallbacks = 0
        for w, sol in zip(self.windows, sols):
            worder, obj = w.order(sol)
            if obj == float("inf"):
                fallbacks += 1
            else:
                solved += 1
                objective += obj
            for st in worder:
                key = (st.op_name, st.r0, st.r1, st.axis)
                if key in emitted:
                    continue             # overlap duplicate
                emitted.add(key)
                order.append(st)
        info = {"windows": len(self.windows), "window_cp": solved,
                "window_fallbacks": fallbacks}
        if solved == 0 or validate_order(g, self.region, self.tiles, order):
            return list(self.greedy), float("inf"), dict(info, stitched=0)
        return order, objective, dict(info, stitched=1)


def _build_windowed_fusion(cfg: NPUConfig, g: Graph, region: List[Op],
                           opts: Dict[str, Tuple[int, int, str]],
                           window_tiles: int, overlap: int
                           ) -> Optional[_WindowedFusion]:
    bank = cfg.bank_bytes
    tiles: Dict[str, TensorTiles] = {}
    for op in region:
        for oname in op.outputs:
            t = g.tensors[oname]
            tiles[oname] = TensorTiles(
                oname, _mk_tiles(t, opts[oname][0], bank, opts[oname][2]))
        # weight and region-external input tiles also enter the windows
        # (their residency/refetch pressure is part of the objective)
        for p in g.param_inputs(op):
            if p.name not in tiles:
                tiles[p.name] = TensorTiles(
                    p.name, _mk_tiles(p, opts[p.name][0], bank,
                                      opts[p.name][2]))
        for x in g.act_inputs(op):
            if x.name not in tiles:
                tiles[x.name] = TensorTiles(
                    x.name, _mk_tiles(x, opts[x.name][0], bank,
                                      opts[x.name][2]))
    greedy = _greedy_order(g, region, tiles)
    if not greedy or validate_order(g, region, tiles, greedy):
        return None
    windows: List[_WindowCP] = []
    prefix: set = set()
    done = 0
    for a, b in _window_bounds(len(greedy), window_tiles, overlap):
        while done < a:                  # thread boundary state forward
            for oname, tl in _step_products(g, tiles, greedy[done]):
                prefix.add((oname, tl.index))
            done += 1
        w = _build_window_fusion_cp(cfg, g, region, tiles, greedy,
                                    a, b, prefix)
        if w is None:
            return None
        windows.append(w)
    return _WindowedFusion(region, tiles, greedy, windows)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------


def plan_tiling(cfg: NPUConfig, g: Graph, plan: FormatPlan,
                fusion: bool = True, cp_time_limit_s: float = 1.0,
                max_cp_tiles: int = 36,
                budget_frac: float = 0.5,
                naive: bool = False,
                cp_stall_s: Optional[float] = None,
                cp_stall_nodes: Optional[int] =
                cpsolver.DEFAULT_STALL_NODES,
                parallel_cp: bool = True,
                cp_engine: str = "incremental",
                max_cp_window_tiles: int = 24,
                region_overlap: int = 6,
                window_refine: bool = True) -> TilingResult:
    opts = _tile_options(cfg, g, budget_frac=budget_frac, naive=naive)
    bank = cfg.bank_bytes
    regions = _regions(cfg, g, opts)

    n_tiles: Dict[str, int] = {nm: o[0] for nm, o in opts.items()}

    # Build the fusion CP of every eligible region up front — the joint
    # tile-size + order model when the region fits max_cp_tiles, the
    # windowed decomposition otherwise — then solve the whole batch
    # (regions *and* windows are variable-disjoint) concurrently and
    # read solutions back in region order.  Regions containing
    # multi-output ops always take the windowed path: its tile-granular
    # state model handles secondary outputs, the joint-LS model does not.
    cps: Dict[int, _FusionCP] = {}
    wins: Dict[int, _WindowedFusion] = {}
    est: Dict[int, int] = {}
    for ri, region in enumerate(regions):
        if not (len(region) > 1 and fusion):
            continue
        est[ri] = _est_region_tiles(opts, region)
        multi_out = any(len(op.outputs) > 1 for op in region)
        if est[ri] <= max_cp_tiles and not multi_out:
            cps[ri] = _build_fusion_cp(cfg, g, region, opts)
        elif max_cp_window_tiles > 0:
            wf = _build_windowed_fusion(cfg, g, region, opts,
                                        max_cp_window_tiles,
                                        region_overlap)
            if wf is not None:
                wins[ri] = wf

    # windows are small and start from a strong (wavefront) incumbent,
    # so they get a much tighter stall cutoff than the joint models —
    # there are many more of them, and most of the win is the warm start
    win_stall = None if cp_stall_nodes is None \
        else max(1_000, cp_stall_nodes // 8)
    tasks: List[cpsolver.SolveTask] = []
    slots: List[Tuple[str, int, int]] = []
    for ri, fc in cps.items():
        tasks.append(cpsolver.SolveTask(fc.model,
                                        time_limit_s=cp_time_limit_s,
                                        warm_start=fc.warm,
                                        stall_limit_s=cp_stall_s,
                                        stall_limit_nodes=cp_stall_nodes,
                                        engine=cp_engine))
        slots.append(("cp", ri, 0))
    for ri, wf in wins.items():
        for wi, w in enumerate(wf.windows):
            tasks.append(cpsolver.SolveTask(w.model,
                                            time_limit_s=cp_time_limit_s,
                                            warm_start=w.warm,
                                            stall_limit_s=cp_stall_s,
                                            stall_limit_nodes=win_stall,
                                            engine=cp_engine))
            slots.append(("win", ri, wi))
    sols: Dict[int, cpsolver.Solution] = {}
    win_sols: Dict[int, List[Optional[cpsolver.Solution]]] = {
        ri: [None] * len(wf.windows) for ri, wf in wins.items()}
    if tasks:
        with _trace.maybe_span("fusion_cp_solve", "compile",
                               tasks=len(tasks), regions=len(cps),
                               windows=len(slots) - len(cps)):
            for (kind, ri, wi), sol in zip(
                    slots, cpsolver.solve_many(tasks,
                                               parallel=parallel_cp)):
                if kind == "cp":
                    sols[ri] = sol
                else:
                    win_sols[ri][wi] = sol

    # sequential refinement: re-solve each window chain front-to-back
    # with carry fixed to 1 for the tiles its predecessor's adopted
    # solution holds at its last tick (stitched execution is sequential,
    # so the batch solve's start-from-DDR assumption over-prices the
    # seams)
    window_refined = 0
    if window_refine and wins:
        with _trace.maybe_span("window_refine", "compile",
                               regions=len(wins)):
            for ri, wf in wins.items():
                win_sols[ri], n = wf.refine(
                    cfg, g, win_sols[ri],
                    time_limit_s=cp_time_limit_s,
                    stall_limit_s=cp_stall_s,
                    stall_limit_nodes=win_stall,
                    engine=cp_engine)
                window_refined += n

    _t_stitch = time.monotonic() if _trace.active() is not None else None
    order: List[ComputeStep] = []
    objective = 0.0
    counts = {"cp": 0, "windowed": 0, "greedy": 0, "layerwise": 0}
    windows_total = window_cp = window_fallbacks = 0
    fused_steps = 0
    detail: List[Dict] = []
    seg: List[Tuple[int, int]] = []         # order slice per region
    win_alt: Dict[int, Tuple[List[ComputeStep], float]] = {}
    for ri, region in enumerate(regions):
        big = len(region) > 1 and fusion
        mode = "layerwise"
        n0 = len(order)
        if ri in cps:
            chosen, steps, obj = cps[ri].extract(g, sols[ri])
            n_tiles.update(chosen)
            order.extend(steps)
            if obj != float("inf"):
                objective += obj
                mode = "cp"
            else:
                mode = "greedy"
        elif ri in wins:
            steps, obj, info = wins[ri].stitch(g, win_sols[ri])
            order.extend(steps)
            windows_total += info["windows"]
            window_cp += info["window_cp"]
            window_fallbacks += info["window_fallbacks"]
            if info["stitched"] and obj != float("inf"):
                objective += obj
                mode = "windowed"
                if steps != wins[ri].greedy:
                    win_alt[ri] = (wins[ri].greedy, obj)
            else:
                mode = "greedy"
        else:
            tiles_now = {
                t.name: TensorTiles(t.name, _mk_tiles(
                    t, n_tiles[t.name], bank, opts[t.name][2]))
                for t in g.tensors.values()}
            if big:
                order.extend(_greedy_order(g, region, tiles_now))
                mode = "greedy"
            else:
                for op in region:
                    out0 = g.tensors[op.outputs[0]]
                    otiles = tiles_now[out0.name]
                    if otiles.axis == "chan" and g.param_inputs(op):
                        # channel-split op: one step per *weight* chunk
                        # (weights stream set-by-set, paper §III-B);
                        # each step writes its channel slice into the
                        # covering (bank-granular) output tile
                        wt = g.param_inputs(op)[0]
                        for tl in tiles_now[wt.name].tiles:
                            order.append(ComputeStep(op.name, tl.r0,
                                                     tl.r1, "chan"))
                        continue
                    for tl in otiles.tiles:
                        order.append(ComputeStep(op.name, tl.r0, tl.r1,
                                                 tl.axis))
        counts[mode] += 1
        n_steps = len(order) - n0
        seg.append((n0, len(order)))
        if big:
            fused_steps += n_steps
        detail.append({"ops": len(region), "steps": n_steps,
                       "est_tiles": est.get(ri, 0), "mode": mode})

    if _t_stitch is not None:
        tr = _trace.active()
        if tr is not None:
            tr.complete("window_stitch", "compile", _t_stitch,
                        args={"regions": len(regions),
                              "windows": windows_total,
                              "window_fallbacks": window_fallbacks})

    tiles = {t.name: TensorTiles(
        t.name, _mk_tiles(t, n_tiles[t.name], bank, opts[t.name][2]))
        for t in g.tensors.values()}
    region_names = [[op.name for op in r] for r in regions]

    def _stats(cnt: Dict[str, int], det: List[Dict], n_order: int,
               windowed_active: bool) -> Dict:
        return {"regions": len(regions),
                "cp_regions": cnt["cp"],
                "windowed_regions": cnt["windowed"],
                "greedy_regions": cnt["greedy"],
                "layerwise_regions": cnt["layerwise"],
                "windows": windows_total if windowed_active else 0,
                "window_cp_solved": window_cp if windowed_active else 0,
                "window_fallbacks":
                    window_fallbacks if windowed_active else 0,
                "window_refined":
                    window_refined if windowed_active else 0,
                "fused_steps": fused_steps,
                "fused_steps_cp": sum(
                    d["steps"] for d in det
                    if d["mode"] in ("cp", "windowed") and d["ops"] > 1),
                "steps": n_order,
                "region_detail": det}

    fallback = None
    if win_alt:
        # same plan with every (changed) windowed region's order swapped
        # back to greedy — the caller races both through the scheduler
        fb_order: List[ComputeStep] = []
        fb_detail: List[Dict] = []
        fb_counts = dict(counts)
        fb_objective = objective
        for ri, (a, b) in enumerate(seg):
            d = dict(detail[ri])
            if ri in win_alt:
                steps, obj = win_alt[ri]
                fb_order.extend(steps)
                fb_objective -= obj
                d["mode"] = "greedy"
                d["steps"] = len(steps)
                fb_counts["windowed"] -= 1
                fb_counts["greedy"] += 1
            else:
                fb_order.extend(order[a:b])
            fb_detail.append(d)
        fallback = TilingResult(
            tiles=tiles, order=fb_order, regions=region_names,
            fusion_objective=fb_objective,
            stats=_stats(fb_counts, fb_detail, len(fb_order),
                         windowed_active=False))

    return TilingResult(
        tiles=tiles, order=order, regions=region_names,
        fusion_objective=objective,
        stats=_stats(counts, detail, len(order), windowed_active=True),
        fallback=fallback,
    )
