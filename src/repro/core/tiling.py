"""Temporal tiling + layer fusion (paper §IV-C).

Feature maps can exceed the TCM, so tensors are split into line-range
tiles processed at different times; interleaving tiles across layers
(*layer fusion*) shrinks the live working set so intermediate maps never
round-trip through DRAM.  Following the paper:

  * **two tile-size options per tensor** (`LS_{k,i}` selection variables):
    the largest tile whose working set fits the TCM, and that size reduced
    by a fixed factor;
  * a **single-memory-level CP** whose objective minimizes the summed
    over-capacity memory profile ``sum_t MemTh_t`` (Eq. 9-12) — with
    ``MemTh_t`` tight at optimum this equals the linear form
    ``sum_t sum_j banks_j * TCM(j,t)`` used here;
  * **region decomposition**: fusion is attempted only inside regions
    whose activations cannot all be held on-chip; everything else is
    scheduled layer-by-layer (the paper's scalability lever, Table II);
  * ops whose parameters exceed a TCM fraction are partitioned **by
    output channels** ("sub-problems with fewer output features" so
    weights stream set-by-set, paper §III-B) — their outputs are
    channel-tiled and each step consumes only its own weight chunk.

The output is (a) the per-tensor tiling and (b) a global, tile-granular
compute order consumed by the scheduler.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from . import cpsolver
from .formats import FormatPlan
from .ir import Graph, Op, Tensor
from .npu import NPUConfig
from .program import TileRef

# --------------------------------------------------------------------------
# Receptive-field helpers (shared with the executor)
# --------------------------------------------------------------------------


def in_row_range(op: Op, out_r0: int, out_r1: int, in_h: int
                 ) -> Tuple[int, int]:
    """Input rows [r0, r1) needed to produce output rows [out_r0, out_r1).
    Clipped to the valid input range (padding supplies the rest)."""
    k = op.kind
    a = op.attrs
    if k in ("conv", "dwconv", "maxpool", "avgpool"):
        if k == "avgpool" and a.get("k", 1) == 0:
            return (0, in_h)  # global pool needs everything
        kh = a["k"][0] if isinstance(a.get("k"), tuple) else a.get("k", 1)
        s = a.get("stride", 1)
        pt = a.get("pad", (0, 0, 0, 0))[0]
        r0 = out_r0 * s - pt
        r1 = (out_r1 - 1) * s - pt + kh
        lo = max(0, min(r0, in_h))
        hi = min(in_h, max(0, r1))
        return (min(lo, hi), hi)
    if k == "resize":
        f = a["factor"]
        return (out_r0 // f, min(in_h, (out_r1 + f - 1) // f))
    if k in ("fc",):
        return (0, in_h)
    if in_h == 1:
        return (0, 1)  # broadcast input (e.g. SE-block (1,1,C) scale)
    # elementwise / concat / split / act / scalar: 1:1 rows
    return (out_r0, min(in_h, out_r1))


# --------------------------------------------------------------------------
# Tiling data model
# --------------------------------------------------------------------------


@dataclass
class TensorTiles:
    tensor: str
    tiles: List[TileRef]

    @property
    def n(self) -> int:
        return len(self.tiles)

    @property
    def axis(self) -> str:
        return self.tiles[0].axis if self.tiles else "rows"

    def covering(self, r0: int, r1: int) -> List[TileRef]:
        """Tiles overlapping output-row range [r0, r1).  Channel-tiled
        tensors span all rows, so every tile overlaps."""
        if self.axis == "chan":
            return list(self.tiles)
        return [t for t in self.tiles if t.r0 < r1 and t.r1 > r0]

    def covering_chan(self, c0: int, c1: int) -> List[TileRef]:
        if self.axis != "chan":
            return list(self.tiles)
        return [t for t in self.tiles if t.r0 < c1 and t.r1 > c0]


@dataclass
class ComputeStep:
    """One tile-granular compute: `op` producing rows (axis == "rows") or
    channels (axis == "chan") [r0, r1) of each of its outputs."""

    op_name: str
    r0: int
    r1: int
    axis: str = "rows"

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.op_name}[{self.r0}:{self.r1}@{self.axis}]"


@dataclass
class TilingResult:
    tiles: Dict[str, TensorTiles]           # tensor -> tiles
    order: List[ComputeStep]                # global tile compute order
    regions: List[List[str]]                # op-name regions (diagnostics)
    fusion_objective: float = 0.0           # CP objective (memory-ticks)
    stats: Dict = field(default_factory=dict)

    def tile_of(self, tensor: str, idx: int) -> TileRef:
        return self.tiles[tensor].tiles[idx]


def _mk_tiles(t: Tensor, n: int, bank_bytes: int,
              axis: str = "rows") -> List[TileRef]:
    """Split tensor into `n` tiles along rows/channels (params: outC)."""
    if t.is_param:
        oc = t.shape[0]
        n = min(n, max(oc, 1))
        per = [oc // n + (1 if i < oc % n else 0) for i in range(n)]
        refs, c0 = [], 0
        bytes_per_oc = t.bytes / max(oc, 1)
        for i, p in enumerate(per):
            nb = max(1, math.ceil(p * bytes_per_oc))
            refs.append(TileRef(t.name, i, c0, c0 + p, nb,
                                max(1, math.ceil(nb / bank_bytes)), "chan"))
            c0 += p
        return refs
    if axis == "chan":
        C = t.shape[-1]
        n = min(n, max(C, 1))
        per = [C // n + (1 if i < C % n else 0) for i in range(n)]
        refs, c0 = [], 0
        bytes_per_c = t.bytes / max(C, 1)
        for i, p in enumerate(per):
            nb = max(1, math.ceil(p * bytes_per_c))
            refs.append(TileRef(t.name, i, c0, c0 + p, nb,
                                max(1, math.ceil(nb / bank_bytes)), "chan"))
            c0 += p
        return refs
    H = t.shape[0] if len(t.shape) == 3 else 1
    n = min(n, max(H, 1))
    rows = [H // n + (1 if i < H % n else 0) for i in range(n)]
    refs, r0 = [], 0
    bytes_per_row = t.bytes / max(H, 1)
    for i, rr in enumerate(rows):
        nb = max(1, math.ceil(rr * bytes_per_row))
        refs.append(TileRef(t.name, i, r0, r0 + rr, nb,
                            max(1, math.ceil(nb / bank_bytes)), "rows"))
        r0 += rr
    return refs


# --------------------------------------------------------------------------
# Tile-size options (the paper's LS_{k,i}, two options per tensor)
# --------------------------------------------------------------------------


def _param_bytes(g: Graph, op: Op) -> int:
    return sum(p.bytes for p in g.param_inputs(op))


def _chan_split(cfg: NPUConfig, g: Graph, op: Op) -> int:
    """#channel sub-problems for a huge-parameter op (0 = not needed).

    The compute steps of a channel-split op follow its *weight* chunks
    (so only one chunk streams through TCM at a time); its output is
    tiled separately at whole-bank granularity (see _tile_options) and
    written channel-slice by channel-slice into resident tiles — output
    co-residency therefore costs the tensor's true footprint, not one
    bank per weight chunk."""
    pb = _param_bytes(g, op)
    if op.kind in ("conv", "fc") and pb > cfg.tcm_bytes // 4:
        return min(int(math.ceil(pb / (cfg.tcm_bytes / 8))),
                   g.tensors[op.output].shape[-1])
    return 0


def _tile_options(cfg: NPUConfig, g: Graph, budget_frac: float = 0.5,
                  naive: bool = False
                  ) -> Dict[str, Tuple[int, int, str]]:
    """tensor -> (n_tiles option A, option B, axis).

    ``naive=True`` reproduces the reference-stack behaviour the paper
    describes in §IV-C: the tile bound only ensures the tile itself fits
    the TCM — it ignores the dependencies that must be co-resident, so
    adjacent layers' buffers thrash through DRAM.  This is the
    eNPU-A/B-style baseline tiling."""
    budget = int(cfg.tcm_bytes * budget_frac)
    opts: Dict[str, Tuple[int, int, str]] = {}
    for t in g.tensors.values():
        if t.is_param:
            n = 1
            while t.bytes / n > cfg.tcm_bytes / 8 and n < max(t.shape[0], 1):
                n *= 2
            opts[t.name] = (n, n, "chan")
            continue
        prod = t.producer
        if prod is not None:
            cs = _chan_split(cfg, g, g.op(prod))
            if cs:
                # bank-clamped: each output chunk fills >= 1 bank, so a
                # consumer gathering the whole tensor holds its true
                # byte footprint, not one bank per weight chunk
                n_out = max(1, min(cs, math.ceil(t.bytes
                                                 / cfg.bank_bytes)))
                opts[t.name] = (n_out, n_out, "chan")
                continue
        H = t.shape[0] if len(t.shape) == 3 else 1
        if naive:
            # naive upper bound: the tile alone fits — dependencies are
            # NOT accounted (shrinks along the retry ladder via
            # budget_frac so the baseline still always compiles)
            frac = min(0.45, budget_frac * 0.9)
            n = 1
            while t.bytes / n > cfg.tcm_bytes * frac and n < max(H, 1):
                n *= 2
            opts[t.name] = (n, n, "rows")
            continue
        n = 1
        while n < max(H, 1):
            rows = math.ceil(H / n)
            ws = math.ceil(t.bytes / n)
            if prod is not None:
                op = g.op(prod)
                for x in g.act_inputs(op):
                    ih = x.shape[0] if len(x.shape) == 3 else 1
                    a, b = in_row_range(op, 0, rows, ih)
                    ws += math.ceil(x.bytes * (b - a) / max(ih, 1))
                ws += sum(min(p.bytes, budget // 4)
                          for p in g.param_inputs(op))
            if ws <= budget:
                break
            n *= 2
        opts[t.name] = (n, min(2 * n, max(H, 1)), "rows")
    return opts


# --------------------------------------------------------------------------
# Region decomposition
# --------------------------------------------------------------------------


def _regions(cfg: NPUConfig, g: Graph,
             opts: Dict[str, Tuple[int, int, str]]) -> List[List[Op]]:
    """Maximal runs of row-tiled ops whose activation working set exceeds
    the TCM — fusion candidates; channel-partitioned ops and cold ops form
    singleton regions (paper §IV-C)."""
    thresh = cfg.tcm_bytes // 2
    regions: List[List[Op]] = []
    cur: List[Op] = []
    cur_hot = False
    for op in g.topo_ops():
        acts = [g.tensors[o] for o in op.outputs] + g.act_inputs(op)
        chan = any(opts[o][2] == "chan" for o in op.outputs)
        hot = (not chan) and sum(t.bytes for t in acts) > thresh
        if hot and cur_hot:
            cur.append(op)
        else:
            if cur:
                regions.append(cur)
            cur = [op]
            cur_hot = hot
    if cur:
        regions.append(cur)
    return regions


# --------------------------------------------------------------------------
# Greedy fused order (warm start + large-region fallback)
# --------------------------------------------------------------------------


def _greedy_order(g: Graph, region: List[Op],
                  tiles: Dict[str, TensorTiles]) -> List[ComputeStep]:
    """Depth-first fusion: emit each op's tiles as soon as the input rows
    they need have been produced — classic cascaded/fused execution."""
    region_ops = {op.name for op in region}
    produced_rows: Dict[str, int] = {}   # tensor -> rows available
    for t in g.tensors.values():
        if t.producer is None or t.producer not in region_ops:
            produced_rows[t.name] = t.shape[0] if len(t.shape) == 3 else 1
    emitted: Dict[str, int] = {op.name: 0 for op in region}
    order: List[ComputeStep] = []
    progress = True
    while progress:
        progress = False
        for op in region:
            out0 = g.tensors[op.outputs[0]]
            otiles = tiles[out0.name].tiles
            while emitted[op.name] < len(otiles):
                tl = otiles[emitted[op.name]]
                ok = True
                for x in g.act_inputs(op):
                    ih = x.shape[0] if len(x.shape) == 3 else 1
                    _, need = in_row_range(op, tl.r0, tl.r1, ih)
                    if produced_rows.get(x.name, 0) < need:
                        ok = False
                        break
                if not ok:
                    break
                order.append(ComputeStep(op.name, tl.r0, tl.r1, tl.axis))
                emitted[op.name] += 1
                for o in op.outputs:
                    produced_rows[o] = tl.r1 \
                        if len(g.tensors[o].shape) == 3 else 1
                progress = True
    for op in region:  # safety net for non-DAG-reachable leftovers
        out0 = g.tensors[op.outputs[0]]
        for tl in tiles[out0.name].tiles[emitted[op.name]:]:
            order.append(ComputeStep(op.name, tl.r0, tl.r1, tl.axis))
    return order


# --------------------------------------------------------------------------
# Fusion CP (per region)
# --------------------------------------------------------------------------


@dataclass
class _FusionCP:
    """One region's fusion CP: model + var maps + greedy fallback.

    Regions share no CP variables, so the models of every fusion-eligible
    region are built first and the batch is solved concurrently
    (cpsolver.solve_many) before the solutions are read back in region
    order."""

    region: List[Op]
    cand: Dict[str, List[List[TileRef]]]
    LS: Dict[Tuple[str, int], int]
    comp: Dict[Tuple[str, int, int, int], int]
    model: CPModel
    warm: Dict[int, int]
    greedy: List[ComputeStep]

    def extract(self, g: Graph, sol: cpsolver.Solution
                ) -> Tuple[Dict[str, int], List[ComputeStep], float]:
        if not sol.feasible:  # fall back to the greedy warm start
            chosen = {onm: len(self.cand[onm][0]) for onm in self.cand}
            return chosen, self.greedy, float("inf")
        chosen: Dict[str, int] = {}
        for oname, variants in self.cand.items():
            for k in range(len(variants)):
                if sol[self.LS[(oname, k)]]:
                    chosen[oname] = len(variants[k])
        steps: List[Tuple[int, ComputeStep]] = []
        for (opn, k, j, t), v in self.comp.items():
            if sol[v]:
                oname = g.op(opn).outputs[0]
                if sol[self.LS[(oname, k)]]:
                    tl = self.cand[oname][k][j]
                    steps.append((t, ComputeStep(opn, tl.r0, tl.r1,
                                                 tl.axis)))
        steps.sort(key=lambda x: x[0])
        return chosen, [s for _, s in steps], sol.objective


def _build_fusion_cp(cfg: NPUConfig, g: Graph, region: List[Op],
                     opts: Dict[str, Tuple[int, int, str]]) -> _FusionCP:
    """Build the CP choosing LS (tiles-per-tensor) and tile order for one
    region."""
    region_ops = {op.name for op in region}
    bank = cfg.bank_bytes

    # candidate tilings per produced tensor (option A / B)
    cand: Dict[str, List[List[TileRef]]] = {}
    for op in region:
        for oname in op.outputs:
            t = g.tensors[oname]
            a, b, axis = opts[oname]
            variants = [_mk_tiles(t, a, bank, axis)]
            if b != a:
                variants.append(_mk_tiles(t, b, bank, axis))
            cand[oname] = variants

    m = cpsolver.CPModel(f"fusion:{region[0].name}")
    LS: Dict[Tuple[str, int], int] = {}
    for oname, variants in cand.items():
        vs = [m.bool(f"LS[{oname},{k}]") for k in range(len(variants))]
        for k, v in enumerate(vs):
            LS[(oname, k)] = v
        m.add_exactly_one(vs, f"one-size:{oname}")

    # T ticks = total tiles of the *larger* option per op
    T = sum(max(len(v) for v in cand[op.outputs[0]]) for op in region)
    T = max(T, 1)

    comp: Dict[Tuple[str, int, int, int], int] = {}
    state: Dict[Tuple[str, int, int, int], int] = {}
    for op in region:
        oname = op.outputs[0]
        for k, variant in enumerate(cand[oname]):
            for j, tl in enumerate(variant):
                cvars = []
                for t in range(T):
                    cv = m.bool(f"c[{op.name},{k},{j},{t}]")
                    comp[(op.name, k, j, t)] = cv
                    cvars.append(cv)
                # computed exactly once iff option selected
                m.add([(cv, 1) for cv in cvars]
                      + [(LS[(oname, k)], -1)], "==", 0,
                      f"once:{op.name}/{k}/{j}")
                # state chain (single-level model: enter only via compute)
                prev = None
                for t in range(T):
                    sv = m.bool(f"s[{oname},{k},{j},{t}]")
                    state[(oname, k, j, t)] = sv
                    terms = [(sv, 1), (comp[(op.name, k, j, t)], -1)]
                    if prev is not None:
                        terms.append((prev, -1))
                    m.add(terms, "<=", 0, f"persist:{oname}/{k}/{j}/{t}")
                    prev = sv

    # at most one compute per tick
    for t in range(T):
        m.add([(v, 1) for (onm, k, j, tt), v in comp.items() if tt == t],
              "<=", 1, f"one-comp:{t}")

    # dependency: computing a tile needs covering region-internal input
    # tiles resident (under whichever option of the input is selected)
    for op in region:
        oname = op.outputs[0]
        for k, variant in enumerate(cand[oname]):
            for j, tl in enumerate(variant):
                for x in g.act_inputs(op):
                    if x.producer not in region_ops:
                        continue
                    ih = x.shape[0] if len(x.shape) == 3 else 1
                    a, b = in_row_range(op, tl.r0, tl.r1, ih)
                    for k2, variant2 in enumerate(cand[x.name]):
                        for j2, tl2 in enumerate(variant2):
                            if tl2.r0 < b and tl2.r1 > a:
                                for t in range(T):
                                    m.add([(comp[(op.name, k, j, t)], 1),
                                           (LS[(x.name, k2)], 1),
                                           (state[(x.name, k2, j2, t)], -1)],
                                          "<=", 1)

    # objective: sum_t sum_j banks_j * state  (== sum_t MemTh_t at optimum)
    obj = [(sv, cand[oname][k][j].banks)
           for (oname, k, j, t), sv in state.items()]
    m.minimize(obj)

    # ---- warm start: option A everywhere + greedy DFS order ----
    ws_tiles = {oname: TensorTiles(oname, cand[oname][0]) for oname in cand}
    greedy = _greedy_order(g, region, ws_tiles)
    ws: Dict[int, int] = {v: 0 for v in range(m.n_vars)}
    for oname in cand:
        ws[LS[(oname, 0)]] = 1
    tick = 0
    step_tick: Dict[Tuple[str, int], int] = {}
    for st in greedy:
        op = g.op(st.op_name)
        oname = op.outputs[0]
        for j, tl in enumerate(cand[oname][0]):
            if tl.r0 == st.r0:
                ws[comp[(op.name, 0, j, tick)]] = 1
                step_tick[(op.name, j)] = tick
        tick += 1
    for op in region:
        oname = op.outputs[0]
        for j, tl in enumerate(cand[oname][0]):
            t0 = step_tick.get((op.name, j))
            if t0 is None:
                continue
            last = t0
            for cons_name in g.tensors[oname].consumers:
                if cons_name not in region_ops:
                    last = T - 1
                    break
                cop = g.op(cons_name)
                c_out = cop.outputs[0]
                ih = g.tensors[oname].shape[0] \
                    if len(g.tensors[oname].shape) == 3 else 1
                for j2, tl2 in enumerate(cand[c_out][0]):
                    a, b = in_row_range(cop, tl2.r0, tl2.r1, ih)
                    if tl.r0 < b and tl.r1 > a:
                        t2 = step_tick.get((cons_name, j2))
                        if t2 is not None:
                            last = max(last, t2)
            for t in range(t0, last + 1):
                ws[state[(oname, 0, j, t)]] = 1

    return _FusionCP(region, cand, LS, comp, m, ws, greedy)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------


def plan_tiling(cfg: NPUConfig, g: Graph, plan: FormatPlan,
                fusion: bool = True, cp_time_limit_s: float = 1.0,
                max_cp_tiles: int = 36,
                budget_frac: float = 0.5,
                naive: bool = False,
                cp_stall_s: Optional[float] = None,
                cp_stall_nodes: Optional[int] =
                cpsolver.DEFAULT_STALL_NODES,
                parallel_cp: bool = True,
                cp_engine: str = "incremental") -> TilingResult:
    opts = _tile_options(cfg, g, budget_frac=budget_frac, naive=naive)
    bank = cfg.bank_bytes
    regions = _regions(cfg, g, opts)

    n_tiles: Dict[str, int] = {nm: o[0] for nm, o in opts.items()}

    # build the fusion CP of every eligible region up front, solve the
    # independent batch concurrently, then read solutions back in order
    cps: Dict[int, _FusionCP] = {}
    for ri, region in enumerate(regions):
        big = len(region) > 1 and fusion
        est_tiles = sum(max(opts[o][0], opts[o][1])
                        for op in region for o in op.outputs[:1])
        if big and est_tiles <= max_cp_tiles:
            cps[ri] = _build_fusion_cp(cfg, g, region, opts)
    sols: Dict[int, cpsolver.Solution] = {}
    if cps:
        keys = list(cps)
        tasks = [cpsolver.SolveTask(cps[ri].model,
                                    time_limit_s=cp_time_limit_s,
                                    warm_start=cps[ri].warm,
                                    stall_limit_s=cp_stall_s,
                                    stall_limit_nodes=cp_stall_nodes,
                                    engine=cp_engine)
                 for ri in keys]
        for ri, sol in zip(keys, cpsolver.solve_many(
                tasks, parallel=parallel_cp)):
            sols[ri] = sol

    order: List[ComputeStep] = []
    objective = 0.0
    cp_regions = 0
    for ri, region in enumerate(regions):
        big = len(region) > 1 and fusion
        if ri in cps:
            chosen, steps, obj = cps[ri].extract(g, sols[ri])
            n_tiles.update(chosen)
            order.extend(steps)
            if obj != float("inf"):
                objective += obj
            cp_regions += 1
        else:
            tiles_now = {
                t.name: TensorTiles(t.name, _mk_tiles(
                    t, n_tiles[t.name], bank, opts[t.name][2]))
                for t in g.tensors.values()}
            if big:
                order.extend(_greedy_order(g, region, tiles_now))
            else:
                for op in region:
                    out0 = g.tensors[op.outputs[0]]
                    otiles = tiles_now[out0.name]
                    if otiles.axis == "chan" and g.param_inputs(op):
                        # channel-split op: one step per *weight* chunk
                        # (weights stream set-by-set, paper §III-B);
                        # each step writes its channel slice into the
                        # covering (bank-granular) output tile
                        wt = g.param_inputs(op)[0]
                        for tl in tiles_now[wt.name].tiles:
                            order.append(ComputeStep(op.name, tl.r0,
                                                     tl.r1, "chan"))
                        continue
                    for tl in otiles.tiles:
                        order.append(ComputeStep(op.name, tl.r0, tl.r1,
                                                 tl.axis))

    tiles = {t.name: TensorTiles(
        t.name, _mk_tiles(t, n_tiles[t.name], bank, opts[t.name][2]))
        for t in g.tensors.values()}
    return TilingResult(
        tiles=tiles, order=order,
        regions=[[op.name for op in r] for r in regions],
        fusion_objective=objective,
        stats={"regions": len(regions), "cp_regions": cp_regions,
               "steps": len(order)},
    )
