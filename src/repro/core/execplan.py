"""Plan lowering + compiled replay engine.

The interpretive executor (:mod:`repro.core.executor`) replays a
compiled :class:`~repro.core.program.NPUProgram` tick by tick — per-step
dict lookups, tile covering/assembly, residency checks, bank ledgers.
That is exactly what makes it a *validator*: every invariant of the
compiled program is re-asserted on every request.  It is also what makes
it slow: measured serving latency is dominated by the interpreter's
Python bookkeeping, not by the modeled schedule.

This module is the deployment-speed counterpart: a **one-time lowering
pass** that compiles the already-verified program into a flat
:class:`ExecPlan` —

  * every per-request decision is made once at lowering time: input row
    windows (the ``gather_rows`` receptive-field math), output scatter
    ranges, weight/bias slices (pre-gathered, pre-cast), activation and
    requantization constants;
  * tensors live in a **preallocated contiguous arena**: one byte
    buffer per batch bucket, each tensor a view at a static offset
    assigned by a linear-scan allocator over the plan's live intervals
    (the same lifetime information the bank allocator scheduled from),
    so slots are reused exactly like TCM banks are;
  * a leading **batch dimension** runs through every kernel, so one
    replay executes N requests;
  * both value semantics lower through the same plan machinery: the
    float32 path emits one kernel per *program step* (bit-exact with
    the interpreter — same window shapes, same kernel calls), and the
    int8/int4 :class:`~repro.quant.executor.QuantSemantics` path emits
    one fused kernel per *op* (integer accumulation is order-exact, so
    coalescing a step sequence into a whole-op kernel reproduces the
    interpreter's stored integers bit for bit).

The interpretive executor stays the oracle: ``CompiledModel.verify()``
replays both engines and asserts the plan matches it (bit-exact for
float32, within one output quantization step for int8/int4 — in
practice the integers are identical).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace as _trace
from .ir import Graph
from .program import NPUProgram
from .tiling import TilingResult

#: arena slots are aligned to this many bytes (cache-line friendly).
ARENA_ALIGN = 64


class PlanError(RuntimeError):
    pass


class PlanConsts:
    """Get-or-compute store for lowering-time kernel constants.

    Lowering derives every weight-shaped constant a kernel closure
    needs — gathered/cast float slices on the float path; transposed
    float64 integer kernels, zero-point-folded biases and fused rescale
    vectors on the quantized path.  That derivation is pure in the
    execution weights, so version-3 artifacts persist the derived
    arrays and a loading process *serves* them (memory-mapped, one
    page-cache copy per fleet) instead of recomputing — a worker
    process's first ``plan_for`` never touches the raw weight pages.

    Keys are ``"<step label>/<const name>"``; both lowerers emit the
    same keys for the same program, so a store computed in one process
    replays in any other.  ``computed``/``served`` count cache misses
    and hits for observability."""

    def __init__(self,
                 arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
        self._arrays: Dict[str, np.ndarray] = dict(arrays or {})
        self.computed = 0
        self.served = 0

    def __len__(self) -> int:
        return len(self._arrays)

    def get(self, key: str, build: Callable[[], np.ndarray]) -> np.ndarray:
        arr = self._arrays.get(key)
        if arr is None:
            arr = self._arrays[key] = build()
            self.computed += 1
        else:
            self.served += 1
        return arr

    def group(self, label: str, names: Sequence[str],
              build: Callable[[], Dict[str, np.ndarray]]
              ) -> Dict[str, np.ndarray]:
        """Several constants derived by one computation (e.g. a conv's
        kernel/bias/rescale, whose dtypes depend on each other): all
        served or all rebuilt together."""
        keys = [f"{label}/{n}" for n in names]
        if all(k in self._arrays for k in keys):
            self.served += len(keys)
            return {n: self._arrays[k] for n, k in zip(names, keys)}
        got = build()
        for n, k in zip(names, keys):
            self._arrays[k] = got[n]
        self.computed += len(keys)
        return got

    def as_arrays(self) -> Dict[str, np.ndarray]:
        return dict(self._arrays)


@dataclass
class PlanStep:
    """One lowered kernel: ``run(bufs, n)`` reads/writes the first ``n``
    batch rows of the arena views in ``bufs`` (indexed by tensor id).
    ``reads``/``writes`` drive the arena's live-interval analysis."""

    label: str
    reads: Tuple[int, ...]
    writes: Tuple[int, ...]
    run: Callable[[List[np.ndarray], int], None]


# --------------------------------------------------------------------------
# Arena: static slot offsets from live intervals (linear scan)
# --------------------------------------------------------------------------


def _align(n: int) -> int:
    return (n + ARENA_ALIGN - 1) // ARENA_ALIGN * ARENA_ALIGN


def assign_slots(sizes: Sequence[int],
                 intervals: Sequence[Tuple[int, int]]) -> Tuple[List[int],
                                                                int]:
    """First-fit linear-scan slot assignment.

    ``sizes[i]`` bytes must be resident over step interval
    ``intervals[i] = (start, end)`` inclusive; two tensors may share
    bytes only if their intervals are disjoint.  Returns (offsets,
    total_bytes)."""
    order = sorted(range(len(sizes)), key=lambda i: intervals[i][0])
    active: List[Tuple[int, int, int]] = []   # (offset, size, end)
    offsets = [0] * len(sizes)
    total = 0
    for i in order:
        start, end = intervals[i]
        active = [a for a in active if a[2] >= start]
        size = _align(max(1, sizes[i]))
        # first-fit into the lowest gap between active allocations
        off = 0
        for a_off, a_size, _ in sorted(active):
            if off + size <= a_off:
                break
            off = max(off, _align(a_off + a_size))
        offsets[i] = off
        active.append((off, size, end))
        total = max(total, off + size)
    return offsets, total


# --------------------------------------------------------------------------
# ExecPlan
# --------------------------------------------------------------------------


class ExecPlan:
    """A lowered, batch-vectorized replay of one compiled program.

    Built once per ``(model, semantics, batch bucket)`` by
    :func:`lower_plan`; ``run()`` executes up to ``capacity`` requests
    in one pass.  Not thread-safe: the arena is owned by the plan."""

    def __init__(self, name: str, graph: Graph, program: NPUProgram,
                 semantics, steps: List[PlanStep],
                 ids: Dict[str, int], capacity: int,
                 build_s: float = 0.0, granularity: str = "step"):
        self.name = name
        self.graph = graph
        self.program = program
        self.semantics = semantics
        self.steps = steps
        self.ids = ids
        self.capacity = int(capacity)
        self.granularity = granularity
        #: modeled DDR traffic of one request (the schedule's fetch/push
        #: bytes); batched runs report this per request, not per batch,
        #: so BENCH_* DDR columns stay comparable across executors.
        self.ddr_bytes_per_request = program.ddr_bytes()
        self.ticks = len(program.ticks)

        names = [None] * len(ids)
        for nm, i in ids.items():
            names[i] = nm
        self._names: List[str] = names

        # -- live intervals over the step sequence --------------------------
        n_steps = len(steps)
        first = [0] * len(ids)
        last = [n_steps] * len(ids)
        seen = [False] * len(ids)
        for si, st in enumerate(steps):
            for t in st.reads + st.writes:
                if not seen[t]:
                    first[t] = si
                    seen[t] = True
                last[t] = si
        for t in graph.inputs:          # encoded before step 0
            first[ids[t.name]] = -1
        for t in graph.outputs:         # decoded after the last step
            last[ids[t.name]] = n_steps

        # -- static slot offsets + one contiguous arena per plan ------------
        dtypes = [np.dtype(semantics.plan_dtype(graph.tensors[nm]))
                  for nm in names]
        shapes = [graph.tensors[nm].shape for nm in names]
        sizes = [int(np.prod(shp)) * dt.itemsize
                 for shp, dt in zip(shapes, dtypes)]
        offsets, total = assign_slots(
            sizes, [(first[i], last[i]) for i in range(len(ids))])
        self.arena_bytes = total
        self._arena = np.empty((self.capacity, max(1, total)),
                               dtype=np.uint8)
        self._views: List[np.ndarray] = []
        for i in range(len(ids)):
            flat = self._arena[:, offsets[i]:offsets[i] + sizes[i]]
            self._views.append(
                flat.view(dtypes[i]).reshape((self.capacity,) + shapes[i]))
        self.build_s = build_s

    # -- execution ----------------------------------------------------------
    def run(self, feed: Dict[str, np.ndarray], n: Optional[int] = None,
            decode: bool = True, trace_id: Optional[int] = None,
            step_times: Optional[list] = None) -> Dict[str, np.ndarray]:
        """Replay ``n`` stacked requests.  ``feed`` maps every graph
        input to an ``(n, *shape)`` array (or ``(*shape,)`` when
        ``n`` is None/1).  Returns each model output as ``(n, *shape)``
        — decoded to float via the semantics, or the raw stored values
        with ``decode=False``.

        ``step_times`` (a caller-supplied list) collects one
        ``(label, seconds)`` entry per lowered kernel — the profiler's
        per-op attribution.  When the tracer is armed (and its
        ``plan_steps`` flag set), each kernel also lands as one span in
        the ring, tagged with ``trace_id`` for request attribution."""
        sem = self.semantics
        ids = self.ids
        bufs = self._views
        squeeze = n is None
        n = 1 if n is None else int(n)
        if not 1 <= n <= self.capacity:
            raise PlanError(
                f"{self.name}: batch {n} outside plan capacity "
                f"[1, {self.capacity}]")
        for t in self.graph.inputs:
            arr = np.asarray(feed[t.name])
            if squeeze and arr.shape == t.shape:
                arr = arr[None]
            if arr.shape != (n,) + t.shape:
                raise PlanError(
                    f"{self.name}: input {t.name} has shape {arr.shape}, "
                    f"expected {(n,) + t.shape}")
            bufs[ids[t.name]][:n] = sem.encode_input(t.name, arr)
        # hoist the tracer/profiler check out of the kernel loop: the
        # common case (neither armed) must stay the two-opcode loop
        tracer = _trace.active()
        if tracer is not None and not tracer.plan_steps:
            tracer = None
        st = None
        try:
            if tracer is None and step_times is None:
                for st in self.steps:
                    st.run(bufs, n)
            else:
                clock = time.monotonic
                for st in self.steps:
                    t0 = clock()
                    st.run(bufs, n)
                    t1 = clock()
                    if step_times is not None:
                        step_times.append((st.label, t1 - t0))
                    if tracer is not None:
                        tracer.complete(st.label, "plan", t0, t1,
                                        trace_id=trace_id)
        except Exception as e:
            # typed, attributable kernel failure: the serving layer's
            # circuit breaker keys off PlanError, and the label tells a
            # human (and the re-lower probe) exactly which lowered
            # kernel went bad — poisoned plan, corrupted arena slot,
            # decode error alike
            raise PlanError(
                f"{self.name}: lowered kernel "
                f"{st.label if st is not None else '?'} failed: "
                f"{type(e).__name__}: {e}") from e
        outs: Dict[str, np.ndarray] = {}
        for t in self.graph.outputs:
            raw = bufs[ids[t.name]][:n]
            if decode:
                dec = sem.decode(t.name, raw)
                out = dec.copy() if dec is raw else dec
            else:
                out = raw.copy()
            outs[t.name] = out[0] if squeeze else out
        return outs

    def execution_report(self, outputs: Dict[str, np.ndarray],
                         n: int = 1):
        """An :class:`~repro.core.executor.ExecutionReport` for one plan
        replay.  ``ticks``/``ddr_bytes`` are the schedule's modeled
        **per-request** quantities — a batch-N replay does not multiply
        them, so DDR columns stay comparable across executors."""
        from .executor import ExecutionReport
        return ExecutionReport(outputs, 0.0, self.ticks,
                               self.ddr_bytes_per_request,
                               batch=int(n), engine="plan")

    # -- reporting ----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "semantics": self.semantics.name,
            "granularity": self.granularity,
            "capacity": self.capacity,
            "kernels": len(self.steps),
            "tensors": len(self.ids),
            "arena_bytes": int(self.arena_bytes),
            "arena_total_bytes": int(self.arena_bytes * self.capacity),
            "build_s": self.build_s,
            "ddr_bytes_per_request": int(self.ddr_bytes_per_request),
        }


# --------------------------------------------------------------------------
# Lowering entry point
# --------------------------------------------------------------------------


def lower_steps(program: NPUProgram, graph: Graph, tiling: TilingResult,
                weights: Dict[str, np.ndarray], semantics,
                consts: Optional[PlanConsts] = None
                ) -> Tuple[List[PlanStep], Dict[str, int], str]:
    """Semantics-driven step lowering: ``(steps, tensor ids,
    granularity)``.  Step closures are batch-capacity-independent
    (they read ``n`` at run time), so one lowered step list — with its
    pre-gathered, pre-cast weight constants — is shared by every batch
    bucket's :class:`ExecPlan`; only the arena is per-bucket.

    ``consts`` is the get-or-compute :class:`PlanConsts` store the
    kernel constants go through — pass a persisted store (version-3
    artifacts) to serve the derived arrays instead of recomputing."""
    ids: Dict[str, int] = {}
    for t in graph.tensors.values():
        if not t.is_param:
            ids[t.name] = len(ids)
    lowerer = semantics.plan_lowerer()
    steps, granularity = lowerer(graph, tiling, program, weights, ids,
                                 consts=consts)
    return steps, ids, granularity


def lower_plan(program: NPUProgram, graph: Graph, tiling: TilingResult,
               weights: Dict[str, np.ndarray], semantics,
               capacity: int = 1,
               lowered: Optional[Tuple[List[PlanStep], Dict[str, int],
                                       str]] = None) -> ExecPlan:
    """Lower one scheduled program into an :class:`ExecPlan`.

    The value semantics object picks the lowering (float32 emits one
    kernel per program step; quantized semantics coalesce to one fused
    integer kernel per op); this function owns everything semantics-
    independent: tensor ids, live intervals, the arena, the runner.
    Pass ``lowered`` (from :func:`lower_steps`) to share one step list
    across several batch buckets instead of re-gathering the kernel
    constants per bucket."""
    t0 = time.monotonic()
    if lowered is None:
        lowered = lower_steps(program, graph, tiling, weights, semantics)
    steps, ids, granularity = lowered
    return ExecPlan(program.name, graph, program, semantics, steps, ids,
                    capacity, build_s=time.monotonic() - t0,
                    granularity=granularity)


# --------------------------------------------------------------------------
# float32 lowering — one kernel per program step, bit-exact with the
# interpreter (same window contents, same kernel calls)
# --------------------------------------------------------------------------


def _step_geometry(g: Graph, op, r0: int, r1: int, axis: str):
    """(c0, c1, rr0, rr1) exactly as executor._run_step derives them."""
    out0 = g.tensors[op.outputs[0]]
    H = out0.shape[0] if len(out0.shape) == 3 else 1
    if axis == "chan":
        return r0, r1, 0, H
    return 0, out0.shape[-1], r0, r1


def _scatter(out_buf: np.ndarray, y: np.ndarray, n: int, axis: str,
             r0: int, r1: int) -> None:
    """Write a step result into the output buffer over [r0, r1) of the
    tiled axis — the union of the interpreter's per-tile ``put``s
    (tile-relative indexing included)."""
    if axis == "chan":
        out_buf[:n, ..., r0:r1] = y[..., 0:r1 - r0]
    else:
        out_buf[:n, r0:r1] = y[:, 0:r1 - r0]


def lower_float_steps(g: Graph, tiling: TilingResult, program: NPUProgram,
                      weights: Dict[str, np.ndarray],
                      ids: Dict[str, int],
                      consts: Optional[PlanConsts] = None
                      ) -> Tuple[List[PlanStep], str]:
    """Per-step float32 lowering.

    Convolution/fc/pooling reductions loop over the batch calling the
    *identical* single-sample kernels the interpreter calls on the
    identical row windows, so every float reduction sees the same
    operands in the same order — the plan's float outputs are
    bit-identical to the interpretive replay.  Purely elementwise steps
    (add/mul/act/scalar/resize/concat/split, max-pooling) vectorize the
    batch axis directly."""
    from .ir import (_apply_act, _attention_ref, _conv2d_ref,
                     _kvappend_ref, _layernorm_ref, _matmul_ref,
                     _softmax_ref)
    from .tiling import in_row_range
    from numpy.lib.stride_tricks import sliding_window_view

    cs = consts if consts is not None else PlanConsts()
    steps: List[PlanStep] = []

    for cj, r0, r1, axis in program.compute_steps():
        op = g.op(cj.op_name)
        a = op.attrs
        k = op.kind
        c0, c1, rr0, rr1 = _step_geometry(g, op, r0, r1, axis)
        oid = ids[op.outputs[0]]
        label = f"{op.name}[{r0}:{r1}@{axis}]"

        def gather_param(name: str, lo: int, hi: int,
                         _label: str = label) -> np.ndarray:
            return cs.get(f"{_label}/{name}", lambda: np.ascontiguousarray(
                np.asarray(weights[name], dtype=np.float32)[lo:hi]))

        if k in ("conv", "dwconv"):
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            kh = a["k"][0]
            s = a["stride"]
            pt, pb, pl, pr = a["pad"]
            ih = x.shape[0]
            u0 = rr0 * s - pt
            u1 = (rr1 - 1) * s - pt + kh
            lo, hi = max(0, u0), min(ih, u1)
            pads = (max(0, -u0), max(0, u1 - ih), pl, pr)
            w = gather_param(op.inputs[1], c0, c1)
            bias = gather_param(op.inputs[2], c0, c1) \
                if len(op.inputs) > 2 else None
            act = a.get("act", "none")
            dw = k == "dwconv"
            dw_chan = dw and axis == "chan"

            def run(bufs, n, xid=xid, oid=oid, lo=lo, hi=hi, w=w,
                    bias=bias, act=act, s=s, pads=pads, dw=dw,
                    dw_chan=dw_chan, c0=c0, c1=c1, axis=axis,
                    r0=r0, r1=r1):
                win = bufs[xid][:n, lo:hi]
                if dw_chan:
                    win = win[:, :, :, c0:c1]
                out = bufs[oid]
                for b in range(n):
                    y = _conv2d_ref(win[b], w, s, pads, dw)
                    if bias is not None:
                        y = y + bias
                    y = _apply_act(y, act)
                    if axis == "chan":
                        out[b, ..., r0:r1] = y[..., 0:r1 - r0]
                    else:
                        out[b, r0:r1] = y[0:r1 - r0]
            reads = (ids[x.name],)
        elif k == "fc":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            w2 = gather_param(op.inputs[1], c0, c1)[:, 0, 0, :]
            bias = gather_param(op.inputs[2], c0, c1) \
                if len(op.inputs) > 2 else None
            act = a.get("act", "none")

            def run(bufs, n, xid=xid, oid=oid, w2=w2, bias=bias, act=act,
                    axis=axis, r0=r0, r1=r1):
                out = bufs[oid]
                for b in range(n):
                    y = w2 @ bufs[xid][b].reshape(-1)
                    if bias is not None:
                        y = y + bias
                    y = _apply_act(y, act).reshape(1, 1, -1)
                    if axis == "chan":
                        out[b, ..., r0:r1] = y[..., 0:r1 - r0]
                    else:
                        out[b, r0:r1] = y[0:r1 - r0]
            reads = (ids[x.name],)
        elif k in ("add", "mul"):
            xs = g.act_inputs(op)
            ranges = []
            for x in xs:
                ih = x.shape[0] if len(x.shape) == 3 else 1
                ranges.append(in_row_range(op, rr0, rr1, ih))
            act = a.get("act", "none")
            i0, i1 = ids[xs[0].name], ids[xs[1].name]
            (l0, h0), (l1, h1) = ranges
            is_add = k == "add"

            def run(bufs, n, i0=i0, i1=i1, l0=l0, h0=h0, l1=l1, h1=h1,
                    act=act, is_add=is_add, oid=oid, axis=axis,
                    r0=r0, r1=r1):
                a0 = bufs[i0][:n, l0:h0]
                a1 = bufs[i1][:n, l1:h1]
                y = _apply_act(a0 + a1, act) if is_add else a0 * a1
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (i0, i1)
        elif k == "scalar":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            v = a["value"]
            sop = a["op"]

            def run(bufs, n, xid=xid, v=v, sop=sop, oid=oid, axis=axis,
                    r0=r0, r1=r1, rr0=rr0, rr1=rr1):
                xw = bufs[xid][:n, rr0:rr1]
                y = {"add": xw + v, "mul": xw * v, "div": xw / v}[sop]
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (xid,)
        elif k == "act":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            act = a["act"]

            def run(bufs, n, xid=xid, act=act, oid=oid, axis=axis,
                    r0=r0, r1=r1, rr0=rr0, rr1=rr1):
                y = _apply_act(bufs[xid][:n, rr0:rr1], act)
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (xid,)
        elif k == "maxpool":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            kk, s = a["k"], a["stride"]
            pt, pb, pl, pr = a["pad"]
            ih = x.shape[0]
            u0 = rr0 * s - pt
            u1 = (rr1 - 1) * s - pt + kk
            lo, hi = max(0, u0), min(ih, u1)
            top, bot = max(0, -u0), max(0, u1 - ih)

            def run(bufs, n, xid=xid, lo=lo, hi=hi, top=top, bot=bot,
                    pl=pl, pr=pr, kk=kk, s=s, oid=oid, axis=axis,
                    r0=r0, r1=r1):
                win = bufs[xid][:n, lo:hi]
                xp = np.pad(win, ((0, 0), (top, bot), (pl, pr), (0, 0)),
                            constant_values=-np.inf)
                wins = sliding_window_view(xp, (kk, kk), axis=(1, 2))
                y = wins[:, ::s, ::s].max(axis=(-2, -1))
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (xid,)
        elif k == "avgpool":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            ih = x.shape[0]
            if a["k"] == 0:
                def run(bufs, n, xid=xid, ih=ih, oid=oid, axis=axis,
                        r0=r0, r1=r1):
                    win = bufs[xid][:n, 0:ih]
                    out = bufs[oid]
                    for b in range(n):
                        y = win[b].mean(axis=(0, 1), keepdims=True)
                        if axis == "chan":
                            out[b, ..., r0:r1] = y[..., 0:r1 - r0]
                        else:
                            out[b, r0:r1] = y[0:r1 - r0]
            else:
                kk, s = a["k"], a["stride"]
                pt, pb, pl, pr = a["pad"]
                u0 = rr0 * s - pt
                u1 = (rr1 - 1) * s - pt + kk
                lo, hi = max(0, u0), min(ih, u1)
                top, bot = max(0, -u0), max(0, u1 - ih)

                def run(bufs, n, xid=xid, lo=lo, hi=hi, top=top, bot=bot,
                        pl=pl, pr=pr, kk=kk, s=s, oid=oid, axis=axis,
                        r0=r0, r1=r1):
                    win = bufs[xid][:n, lo:hi]
                    out = bufs[oid]
                    for b in range(n):
                        xp = np.pad(win[b], ((top, bot), (pl, pr), (0, 0)))
                        wins = sliding_window_view(xp, (kk, kk),
                                                   axis=(0, 1))
                        y = wins[::s, ::s].sum(axis=(-2, -1),
                                               dtype=np.float32) / (kk * kk)
                        if axis == "chan":
                            out[b, ..., r0:r1] = y[..., 0:r1 - r0]
                        else:
                            out[b, r0:r1] = y[0:r1 - r0]
            reads = (xid,)
        elif k == "resize":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            f = a["factor"]
            lo, hi = rr0 // f, (rr1 + f - 1) // f

            def run(bufs, n, xid=xid, lo=lo, hi=hi, f=f, rr0=rr0,
                    rr1=rr1, oid=oid, axis=axis, r0=r0, r1=r1):
                win = bufs[xid][:n, lo:hi]
                y = np.repeat(np.repeat(win, f, axis=1), f, axis=2)
                y = y[:, rr0 - lo * f: rr1 - lo * f]
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (xid,)
        elif k == "concat":
            xids = tuple(ids[x.name] for x in g.act_inputs(op))

            def run(bufs, n, xids=xids, rr0=rr0, rr1=rr1, oid=oid,
                    axis=axis, r0=r0, r1=r1):
                y = np.concatenate([bufs[i][:n, rr0:rr1] for i in xids],
                                   axis=-1)
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = xids
        elif k == "split":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            oids = tuple(ids[o] for o in op.outputs)
            sections = a["sections"]

            def run(bufs, n, xid=xid, oids=oids, sections=sections,
                    rr0=rr0, rr1=rr1, axis=axis, r0=r0, r1=r1):
                parts = np.split(bufs[xid][:n, rr0:rr1], sections, axis=-1)
                for o, p in zip(oids, parts):
                    _scatter(bufs[o], p, n, axis, r0, r1)
            steps.append(PlanStep(label, (xid,), oids, run))
            continue
        elif k == "matmul":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            w2 = gather_param(op.inputs[1], c0, c1)[:, 0, 0, :]
            bias = gather_param(op.inputs[2], c0, c1) \
                if len(op.inputs) > 2 else None
            act = a.get("act", "none")

            def run(bufs, n, xid=xid, oid=oid, w2=w2, bias=bias, act=act,
                    rr0=rr0, rr1=rr1, axis=axis, r0=r0, r1=r1):
                out = bufs[oid]
                for b in range(n):
                    y = _matmul_ref(bufs[xid][b, rr0:rr1], w2, bias, act)
                    if axis == "chan":
                        out[b, ..., r0:r1] = y[..., 0:r1 - r0]
                    else:
                        out[b, r0:r1] = y[0:r1 - r0]
            reads = (xid,)
        elif k == "layernorm":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            cc = g.tensors[op.inputs[1]].shape[0]
            gamma = gather_param(op.inputs[1], 0, cc)
            beta = gather_param(op.inputs[2], 0, cc)
            eps = a["eps"]

            def run(bufs, n, xid=xid, gamma=gamma, beta=beta, eps=eps,
                    rr0=rr0, rr1=rr1, oid=oid, axis=axis, r0=r0, r1=r1):
                y = _layernorm_ref(bufs[xid][:n, rr0:rr1], gamma, beta,
                                   eps)
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (xid,)
        elif k == "softmax":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]

            def run(bufs, n, xid=xid, rr0=rr0, rr1=rr1, oid=oid,
                    axis=axis, r0=r0, r1=r1):
                y = _softmax_ref(bufs[xid][:n, rr0:rr1])
                _scatter(bufs[oid], y, n, axis, r0, r1)
            reads = (xid,)
        elif k == "attention":
            q, kc, vc, ps = g.act_inputs(op)
            qid, kid = ids[q.name], ids[kc.name]
            vid, pid = ids[vc.name], ids[ps.name]
            attrs = dict(a)
            s_total = q.shape[0]

            # fused QK^T -> softmax -> V kernel, per batch sample on the
            # identical row slice the interpreter computes — bit-exact
            def run(bufs, n, qid=qid, kid=kid, vid=vid, pid=pid,
                    attrs=attrs, rr0=rr0, rr1=rr1, s_total=s_total,
                    oid=oid, axis=axis, r0=r0, r1=r1):
                out = bufs[oid]
                for b in range(n):
                    y = _attention_ref(bufs[qid][b, rr0:rr1],
                                       bufs[kid][b], bufs[vid][b],
                                       bufs[pid][b], attrs,
                                       q0=rr0, s_total=s_total)
                    if axis == "chan":
                        out[b, ..., r0:r1] = y[..., 0:r1 - r0]
                    else:
                        out[b, r0:r1] = y[0:r1 - r0]
            reads = (qid, kid, vid, pid)
        elif k == "kvappend":
            cache, new, ps = g.act_inputs(op)
            cid, nid = ids[cache.name], ids[new.name]
            pid = ids[ps.name]

            def run(bufs, n, cid=cid, nid=nid, pid=pid, rr0=rr0,
                    rr1=rr1, oid=oid, r0=r0, r1=r1):
                out = bufs[oid]
                for b in range(n):
                    y = _kvappend_ref(bufs[cid][b], bufs[nid][b],
                                      bufs[pid][b])[rr0:rr1]
                    out[b, r0:r1] = y[0:r1 - r0]
            reads = (cid, nid, pid)
        else:  # pragma: no cover
            raise NotImplementedError(k)

        steps.append(PlanStep(label, reads, (oid,), run))

    return steps, "step"
