"""Stable on-disk serialization for compiled NPU artifacts.

The paper's compiler is a deployment product: a workload is compiled
once and the resulting program ships to millions of edge devices.  This
module gives every compiler artifact a canonical, *versioned* byte form
so a compiled program can leave the process that solved the CPs:

  * component codecs — :class:`~repro.core.ir.Graph` (with dtypes and
    qparams), :class:`~repro.core.program.NPUProgram` (ticks, jobs,
    tiles, meta), :class:`~repro.core.tiling.TilingResult`,
    :class:`~repro.core.allocation.Allocation`,
    :class:`~repro.core.formats.FormatPlan` and
    :class:`~repro.core.npu.NPUConfig` each round-trip through a
    JSON-able payload plus a dict of numpy arrays (arrays never pass
    through JSON, so float32/int8 values are bit-exact);
  * a container format — a single zip file holding ``meta.json``, one
    ``<component>.json`` per payload and one *stored* (uncompressed)
    ``arrays/<name>.npy`` member per array, with a per-entry sha256
    manifest in the meta.  Stored members sit at fixed byte offsets, so
    loaders can memory-map weights copy-on-write straight out of the
    artifact (``read_artifact(mmap_arrays=True)``) — a fleet of serving
    processes shares one page-cache copy per weight.  A flipped byte, a
    truncated file or a hand-edited entry fails the manifest check and
    raises :class:`ArtifactError` — a bad artifact is rejected, never
    replayed.  Version-1 artifacts (one deflated ``arrays.npz``) still
    load.

Consumers: the two-tier compiled-program cache in
:mod:`repro.core.pipeline` (program-only artifacts) and the public
``repro.api`` deployment surface (full ``CompiledModel`` artifacts that
add the graph, weights and quantization state).
"""
from __future__ import annotations

import hashlib
import io
import json
import struct
import zipfile
from dataclasses import asdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .allocation import Allocation
from .formats import FormatPlan
from .ir import Graph, Op, QParams, Tensor
from .npu import NPUConfig
from .program import ComputeJob, DmaJob, NPUProgram, Tick, TileRef, V2PJob
from .tiling import ComputeStep, TensorTiles, TilingResult

#: bump when any payload layout changes incompatibly.  Version 2 stores
#: each numpy array as its own *uncompressed* ``arrays/<name>.npy`` zip
#: member (v1 bundled them in one deflated ``arrays.npz``): stored
#: members sit at a fixed byte offset inside the file, so weights can be
#: memory-mapped copy-on-write straight out of the artifact — a fleet of
#: serving processes shares one page-cache copy per weight instead of
#: each copying every array into RAM.  Version 3 additionally persists
#: the lowered-plan kernel constants (``arrays/pl/…`` members plus a
#: ``planconsts.json`` key index), so a loading worker's first
#: ``plan_for`` serves the derived arrays straight off the map instead
#: of re-gathering/re-casting them from the weights.  Versions 1 and 2
#: still load (they simply recompute the constants).
ARTIFACT_VERSION = 3
_SUPPORTED_VERSIONS = (1, 2, 3)
ARTIFACT_MAGIC = "repro-npu-artifact"


class ArtifactError(RuntimeError):
    """A persisted artifact is corrupted, truncated, from an
    incompatible format version, or stale for the requested key."""


# --------------------------------------------------------------------------
# Small helpers
# --------------------------------------------------------------------------


def _tuplify(v: Any) -> Any:
    """JSON arrays back to tuples (op attrs are built with tuples; the
    executors unpack them positionally)."""
    if isinstance(v, list):
        return tuple(_tuplify(x) for x in v)
    return v


def _tile_to_list(tl: TileRef) -> list:
    return [tl.tensor, tl.index, tl.r0, tl.r1, tl.nbytes, tl.banks, tl.axis]


def _tile_from_list(v: list) -> TileRef:
    return TileRef(v[0], int(v[1]), int(v[2]), int(v[3]), int(v[4]),
                   int(v[5]), v[6])


# --------------------------------------------------------------------------
# NPUConfig
# --------------------------------------------------------------------------


def config_to_payload(cfg: NPUConfig) -> dict:
    return asdict(cfg)


def config_from_payload(p: dict) -> NPUConfig:
    return NPUConfig(**p)


# --------------------------------------------------------------------------
# Graph (tensors + qparams + ops)
# --------------------------------------------------------------------------


def graph_to_payload(g: Graph) -> Tuple[dict, Dict[str, np.ndarray]]:
    arrays: Dict[str, np.ndarray] = {}
    tensors = []
    for t in sorted(g.tensors.values(), key=lambda t: t.name):
        qp = None
        if t.qparams is not None:
            qp = {"bits": t.qparams.bits, "axis": t.qparams.axis}
            arrays[f"qp.scale/{t.name}"] = np.asarray(t.qparams.scale)
            arrays[f"qp.zp/{t.name}"] = np.asarray(t.qparams.zero_point)
        tensors.append({
            "name": t.name, "shape": list(t.shape), "kind": t.kind,
            "dtype": t.dtype, "producer": t.producer,
            "consumers": list(t.consumers), "scale": t.scale, "qparams": qp,
        })
    ops = [{"name": op.name, "kind": op.kind, "inputs": list(op.inputs),
            "outputs": list(op.outputs), "attrs": op.attrs}
           for op in g.ops]
    return {"name": g.name, "tensors": tensors, "ops": ops}, arrays


def graph_from_payload(p: dict, arrays: Dict[str, np.ndarray]) -> Graph:
    g = Graph(p["name"])
    for tp in p["tensors"]:
        qp = None
        if tp["qparams"] is not None:
            axis = tp["qparams"]["axis"]
            s = arrays[f"qp.scale/{tp['name']}"]
            z = arrays[f"qp.zp/{tp['name']}"]
            if axis is None and s.size == 1:
                # restore the scalar form per-tensor params were built
                # with (older artifacts stored them as shape (1,)): a
                # 1-element scale array knocks quantize() off its scalar
                # hot path, and the int32 zero-point *array* add then
                # promotes the whole activation chain to float64 —
                # measurably slower replay, same values
                s = s.reshape(())[()]
                z = np.asarray(z).reshape(())[()]
            qp = QParams(s, z,
                         bits=int(tp["qparams"]["bits"]),
                         axis=axis)
        g.tensors[tp["name"]] = Tensor(
            tp["name"], tuple(tp["shape"]), tp["kind"], tp["dtype"],
            tp["producer"], list(tp["consumers"]), tp["scale"], qp)
    for op_p in p["ops"]:
        # "pad"/"k" attrs are tuples in builder-made graphs; JSON returns
        # lists, and the executors unpack them positionally either way,
        # but fingerprint stability and isinstance(k, tuple) checks in
        # in_row_range need the original tuple form back.
        attrs = {k: _tuplify(v) for k, v in op_p["attrs"].items()}
        op = Op(op_p["name"], op_p["kind"], list(op_p["inputs"]),
                list(op_p["outputs"]), attrs)
        g.ops.append(op)
        g._op_index[op.name] = op
    return g


# --------------------------------------------------------------------------
# NPUProgram
# --------------------------------------------------------------------------


def program_to_payload(prog: NPUProgram) -> dict:
    ticks = []
    for t in prog.ticks:
        cj = None
        if t.compute:
            c = t.compute
            cj = {"op": c.op_name,
                  "out": [_tile_to_list(x) for x in c.out_tiles],
                  "in": [_tile_to_list(x) for x in c.in_tiles],
                  "fmt": c.fmt, "cycles": c.cycles, "macs": c.macs,
                  "r0": c.r0, "r1": c.r1, "axis": c.axis}
        ticks.append({
            "index": t.index,
            "compute": cj,
            "dma": [[j.kind, _tile_to_list(j.tile), j.nbytes, j.cycles]
                    for j in t.dma],
            "v2p": [[_tile_to_list(j.tile), list(j.banks), j.cycles]
                    for j in t.v2p],
        })
    meta = dict(prog.meta)
    dead = meta.pop("dead_after_tick", {})
    return {
        "name": prog.name,
        "cfg": config_to_payload(prog.cfg),
        "dm_penalty": prog.dm_penalty,
        "ticks": ticks,
        "meta": meta,
        "dead_after_tick": {str(k): [[n, i] for (n, i) in v]
                            for k, v in dead.items()},
    }


def program_from_payload(p: dict) -> NPUProgram:
    ticks: List[Tick] = []
    for tp in p["ticks"]:
        cj = None
        if tp["compute"] is not None:
            c = tp["compute"]
            cj = ComputeJob(c["op"],
                            [_tile_from_list(x) for x in c["out"]],
                            [_tile_from_list(x) for x in c["in"]],
                            c["fmt"], int(c["cycles"]), int(c["macs"]),
                            r0=c["r0"], r1=c["r1"], axis=c["axis"])
        ticks.append(Tick(
            int(tp["index"]), cj,
            [DmaJob(j[0], _tile_from_list(j[1]), int(j[2]), int(j[3]))
             for j in tp["dma"]],
            [V2PJob(_tile_from_list(j[0]), [int(b) for b in j[1]],
                    int(j[2])) for j in tp["v2p"]],
        ))
    meta = dict(p["meta"])
    meta["dead_after_tick"] = {
        int(k): [(n, int(i)) for n, i in v]
        for k, v in p["dead_after_tick"].items()}
    return NPUProgram(p["name"], config_from_payload(p["cfg"]), ticks,
                      dm_penalty=int(p["dm_penalty"]), meta=meta)


# --------------------------------------------------------------------------
# TilingResult / Allocation / FormatPlan
# --------------------------------------------------------------------------


def tiling_to_payload(tiling: TilingResult) -> dict:
    # ``stats`` round-trips as plain JSON and now carries the fusion
    # coverage record (cp/windowed/greedy/layer-wise region counts,
    # window counts and per-region detail) that CompiledModel.report()
    # surfaces.  ``tiling.fallback`` — the greedy-order race variant the
    # compile ladder may hold transiently — is deliberately NOT
    # persisted: artifacts store only the chosen plan.
    return {
        "tiles": [[name, [_tile_to_list(tl) for tl in tt.tiles]]
                  for name, tt in tiling.tiles.items()],
        "order": [[s.op_name, s.r0, s.r1, s.axis] for s in tiling.order],
        "regions": [list(r) for r in tiling.regions],
        "fusion_objective": tiling.fusion_objective,
        "stats": json.loads(json.dumps(tiling.stats, default=list)),
    }


def tiling_from_payload(p: dict) -> TilingResult:
    tiles = {name: TensorTiles(name, [_tile_from_list(v) for v in tls])
             for name, tls in p["tiles"]}
    order = [ComputeStep(o, int(r0), int(r1), axis)
             for o, r0, r1, axis in p["order"]]
    return TilingResult(tiles, order, [list(r) for r in p["regions"]],
                        p["fusion_objective"], dict(p["stats"]))


def allocation_to_payload(alloc: Allocation) -> dict:
    return {
        "banks": [[n, i, list(b)] for (n, i), b in alloc.banks.items()],
        "tiles": [[n, i, _tile_to_list(tl)]
                  for (n, i), tl in alloc.tiles.items()],
        "peak_banks": alloc.peak_banks,
        "v2p_updates": alloc.v2p_updates,
        "repair_spills": alloc.repair_spills,
        # spill_events are compile-time diagnostics; not persisted
    }


def allocation_from_payload(p: dict) -> Allocation:
    return Allocation(
        banks={(n, int(i)): [int(x) for x in b]
               for n, i, b in p["banks"]},
        tiles={(n, int(i)): _tile_from_list(tl)
               for n, i, tl in p["tiles"]},
        peak_banks=int(p["peak_banks"]),
        v2p_updates=int(p["v2p_updates"]),
        repair_spills=int(p["repair_spills"]),
    )


def plan_to_payload(plan: FormatPlan) -> dict:
    return {"fmt": dict(plan.fmt), "cost_cycles": dict(plan.cost_cycles)}


def plan_from_payload(p: dict) -> FormatPlan:
    return FormatPlan(dict(p["fmt"]),
                      {k: int(v) for k, v in p["cost_cycles"].items()})


# --------------------------------------------------------------------------
# Container: zip of json payloads + arrays.npz with a sha256 manifest
# --------------------------------------------------------------------------


def _json_bytes(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    # ascontiguousarray promotes 0-d to shape (1,) — keep scalar members
    # (per-tensor qparams) 0-d so they round-trip exactly
    a = np.asarray(arr)
    if a.ndim:
        a = np.ascontiguousarray(a)
    np.lib.format.write_array(buf, a, allow_pickle=False)
    return buf.getvalue()


#: mmap alignment for stored array members; matches numpy's own
#: ARRAY_ALIGN so the npy header padding lands array data on the same
#: boundary.
_MEMBER_ALIGN = 64

#: private zip extra-field id for alignment padding (any id unknown to
#: extractors is carried opaquely; the data offset math in
#: ``_member_data_offset`` reads the local header's real extra length).
_PAD_EXTRA_ID = 0xD935


def _aligned_zinfo(zf: zipfile.ZipFile, name: str) -> zipfile.ZipInfo:
    """ZipInfo for a STORED member whose *data* starts 64-byte aligned.

    ``np.lib.format`` pads the npy header so array data sits at a
    64-byte offset within the blob; padding the zip local header with
    an extra field aligns the blob itself, so memory-mapped arrays come
    out SIMD-aligned instead of landing wherever the previous member
    ended (misaligned loads measurably slow elementwise-heavy replay)."""
    zi = zipfile.ZipInfo(name, date_time=(1980, 1, 1, 0, 0, 0))
    zi.compress_type = zipfile.ZIP_STORED
    data_off = zf.start_dir + 30 + len(name.encode("utf-8"))
    pad = -data_off % _MEMBER_ALIGN
    if 0 < pad < 4:                # an extra block is at least 4 bytes
        pad += _MEMBER_ALIGN
    if pad:
        zi.extra = struct.pack("<HH", _PAD_EXTRA_ID, pad - 4) \
            + b"\0" * (pad - 4)
    return zi


def write_artifact(path: str, key: dict, payloads: Dict[str, Any],
                   arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    """Write one artifact file.  ``key`` is the caller's identity record
    (fingerprint / config / options digest / precision …); ``payloads``
    maps component name -> JSON-able payload; ``arrays`` holds every
    numpy array referenced by the payloads.

    JSON payloads are deflated; arrays are **stored** (uncompressed) as
    individual ``arrays/<name>.npy`` members so loaders can memory-map
    them in place (see :func:`read_artifact`'s ``mmap_arrays``)."""
    entries: Dict[str, bytes] = {}
    stored: set = set()
    for name, payload in payloads.items():
        entries[f"{name}.json"] = _json_bytes(payload)
    for name, arr in (arrays or {}).items():
        member = f"arrays/{name}.npy"
        entries[member] = _npy_bytes(arr)
        stored.add(member)
    meta = {
        "magic": ARTIFACT_MAGIC,
        "version": ARTIFACT_VERSION,
        "key": key,
        "manifest": {name: hashlib.sha256(blob).hexdigest()
                     for name, blob in sorted(entries.items())},
    }
    with zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED) as zf:
        zf.writestr("meta.json", _json_bytes(meta))
        for name, blob in sorted(entries.items()):
            if name in stored:
                zf.writestr(_aligned_zinfo(zf, name), blob)
            else:
                zf.writestr(name, blob,
                            compress_type=zipfile.ZIP_DEFLATED)


def _member_data_offset(path: str, zinfo: zipfile.ZipInfo) -> int:
    """Absolute byte offset of a stored member's data in the zip file.
    The local file header is 30 bytes + filename + extra (the *local*
    extra field can differ from the central directory's, so it is read
    from the header itself)."""
    with open(path, "rb") as f:
        f.seek(zinfo.header_offset)
        hdr = f.read(30)
    if len(hdr) != 30 or hdr[:4] != b"PK\x03\x04":
        raise ArtifactError(f"{path}: bad local header for "
                            f"{zinfo.filename}")
    fn_len = int.from_bytes(hdr[26:28], "little")
    extra_len = int.from_bytes(hdr[28:30], "little")
    return zinfo.header_offset + 30 + fn_len + extra_len


def _mmap_npy_member(path: str, zinfo: zipfile.ZipInfo
                     ) -> Optional[np.ndarray]:
    """Map one stored ``.npy`` member copy-on-write.  Returns None when
    the member cannot be mapped (compressed, exotic header, zero-size)
    — the caller falls back to an in-memory read."""
    if zinfo.compress_type != zipfile.ZIP_STORED:
        return None
    try:
        data_off = _member_data_offset(path, zinfo)
        with open(path, "rb") as f:
            f.seek(data_off)
            version = np.lib.format.read_magic(f)
            if version == (1, 0):
                shape, fortran, dtype = \
                    np.lib.format.read_array_header_1_0(f)
            elif version == (2, 0):
                shape, fortran, dtype = \
                    np.lib.format.read_array_header_2_0(f)
            else:
                return None
            offset = f.tell()
    except (OSError, ValueError, ArtifactError):
        return None
    if dtype.hasobject or int(np.prod(shape)) == 0:
        return None
    # mode "c" (copy-on-write): reads share the OS page cache across
    # processes; an in-place write (e.g. a spill push-back during
    # interpretive replay) dirties a private page instead of faulting
    m = np.memmap(path, dtype=dtype, mode="c", offset=offset,
                  shape=shape, order="F" if fortran else "C")
    # hand back a plain-ndarray view: the mapping stays alive through
    # ``.base``, but ufuncs no longer propagate the memmap subclass —
    # subclass dispatch on every intermediate taxes interpreted plans
    # by whole milliseconds per batch
    return m.view(np.ndarray)


def read_artifact(path: str, mmap_arrays: bool = False
                  ) -> Tuple[dict, Dict[str, Any], Dict[str, np.ndarray]]:
    """Read + integrity-check one artifact file.

    Returns ``(key, payloads, arrays)``.  Raises :class:`ArtifactError`
    on any corruption: bad zip, missing/extra entries vs the manifest,
    sha256 mismatch, wrong magic or incompatible version.

    ``mmap_arrays=True`` maps version-2 stored ``.npy`` members
    copy-on-write instead of materializing them in RAM.  Every member —
    mapped or not — is still streamed through the full sha256 manifest
    check first; mapping never weakens the integrity contract."""
    try:
        with zipfile.ZipFile(path, "r") as zf:
            try:
                meta = json.loads(zf.read("meta.json"))
            except KeyError:
                raise ArtifactError(f"{path}: no meta.json")
            if meta.get("magic") != ARTIFACT_MAGIC:
                raise ArtifactError(f"{path}: not a repro NPU artifact")
            version = meta.get("version")
            if version not in _SUPPORTED_VERSIONS:
                raise ArtifactError(
                    f"{path}: artifact version {version} "
                    f"incompatible with {ARTIFACT_VERSION}")
            manifest = meta.get("manifest", {})
            names = set(zf.namelist()) - {"meta.json"}
            if names != set(manifest):
                raise ArtifactError(
                    f"{path}: entry set {sorted(names)} does not match "
                    f"manifest {sorted(manifest)}")
            payloads: Dict[str, Any] = {}
            arrays: Dict[str, np.ndarray] = {}
            for name, want in manifest.items():
                is_array = name.startswith("arrays/") \
                    and name.endswith(".npy")
                if is_array and mmap_arrays:
                    # stream the checksum; never hold the whole blob
                    h = hashlib.sha256()
                    with zf.open(name) as fh:
                        for chunk in iter(lambda: fh.read(1 << 20), b""):
                            h.update(chunk)
                    if h.hexdigest() != want:
                        raise ArtifactError(
                            f"{path}: checksum mismatch on {name}")
                    arr = _mmap_npy_member(path, zf.getinfo(name))
                    if arr is None:
                        arr = np.lib.format.read_array(
                            io.BytesIO(zf.read(name)), allow_pickle=False)
                    arrays[name[7:-4]] = arr
                    continue
                blob = zf.read(name)
                got = hashlib.sha256(blob).hexdigest()
                if got != want:
                    raise ArtifactError(
                        f"{path}: checksum mismatch on {name}")
                if is_array:
                    arrays[name[7:-4]] = np.lib.format.read_array(
                        io.BytesIO(blob), allow_pickle=False)
                elif name == "arrays.npz":           # version-1 layout
                    with np.load(io.BytesIO(blob)) as npz:
                        arrays = {k: npz[k] for k in npz.files}
                elif name.endswith(".json"):
                    payloads[name[:-5]] = json.loads(blob)
    except zipfile.BadZipFile as e:
        raise ArtifactError(f"{path}: unreadable artifact ({e})") from e
    return meta["key"], payloads, arrays


def options_digest(opts_key: tuple) -> str:
    """Stable digest of a CompilerOptions.cache_key() tuple (its repr is
    deterministic: strings, numbers, bools, None and nested tuples)."""
    return hashlib.sha256(repr(opts_key).encode()).hexdigest()


def cache_file_key(fingerprint: str, cfg: NPUConfig, opts_key: tuple) -> str:
    """Filename-safe digest of the full compiled-program cache key."""
    return cache_file_key_digest(fingerprint, config_to_payload(cfg),
                                 options_digest(opts_key))


def cache_file_key_digest(fingerprint: str, cfg_payload: dict,
                          opts_digest: str) -> str:
    """Same digest, from the already-serialized key components (what an
    artifact's own key record stores — lets auditors re-derive the
    expected filename of any artifact from its contents)."""
    blob = _json_bytes({"fp": fingerprint, "cfg": cfg_payload,
                        "opts": opts_digest})
    return hashlib.sha256(blob).hexdigest()
