"""Stable on-disk serialization for compiled NPU artifacts.

The paper's compiler is a deployment product: a workload is compiled
once and the resulting program ships to millions of edge devices.  This
module gives every compiler artifact a canonical, *versioned* byte form
so a compiled program can leave the process that solved the CPs:

  * component codecs — :class:`~repro.core.ir.Graph` (with dtypes and
    qparams), :class:`~repro.core.program.NPUProgram` (ticks, jobs,
    tiles, meta), :class:`~repro.core.tiling.TilingResult`,
    :class:`~repro.core.allocation.Allocation`,
    :class:`~repro.core.formats.FormatPlan` and
    :class:`~repro.core.npu.NPUConfig` each round-trip through a
    JSON-able payload plus a dict of numpy arrays (arrays never pass
    through JSON, so float32/int8 values are bit-exact);
  * a container format — a single zip file holding ``meta.json``, one
    ``<component>.json`` per payload and one ``arrays.npz``, with a
    per-entry sha256 manifest in the meta.  A flipped byte, a truncated
    file or a hand-edited entry fails the manifest check and raises
    :class:`ArtifactError` — a bad artifact is rejected, never replayed.

Consumers: the two-tier compiled-program cache in
:mod:`repro.core.pipeline` (program-only artifacts) and the public
``repro.api`` deployment surface (full ``CompiledModel`` artifacts that
add the graph, weights and quantization state).
"""
from __future__ import annotations

import hashlib
import io
import json
import zipfile
from dataclasses import asdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .allocation import Allocation
from .formats import FormatPlan
from .ir import Graph, Op, QParams, Tensor
from .npu import NPUConfig
from .program import ComputeJob, DmaJob, NPUProgram, Tick, TileRef, V2PJob
from .tiling import ComputeStep, TensorTiles, TilingResult

#: bump when any payload layout changes incompatibly.
ARTIFACT_VERSION = 1
ARTIFACT_MAGIC = "repro-npu-artifact"


class ArtifactError(RuntimeError):
    """A persisted artifact is corrupted, truncated, from an
    incompatible format version, or stale for the requested key."""


# --------------------------------------------------------------------------
# Small helpers
# --------------------------------------------------------------------------


def _tuplify(v: Any) -> Any:
    """JSON arrays back to tuples (op attrs are built with tuples; the
    executors unpack them positionally)."""
    if isinstance(v, list):
        return tuple(_tuplify(x) for x in v)
    return v


def _tile_to_list(tl: TileRef) -> list:
    return [tl.tensor, tl.index, tl.r0, tl.r1, tl.nbytes, tl.banks, tl.axis]


def _tile_from_list(v: list) -> TileRef:
    return TileRef(v[0], int(v[1]), int(v[2]), int(v[3]), int(v[4]),
                   int(v[5]), v[6])


# --------------------------------------------------------------------------
# NPUConfig
# --------------------------------------------------------------------------


def config_to_payload(cfg: NPUConfig) -> dict:
    return asdict(cfg)


def config_from_payload(p: dict) -> NPUConfig:
    return NPUConfig(**p)


# --------------------------------------------------------------------------
# Graph (tensors + qparams + ops)
# --------------------------------------------------------------------------


def graph_to_payload(g: Graph) -> Tuple[dict, Dict[str, np.ndarray]]:
    arrays: Dict[str, np.ndarray] = {}
    tensors = []
    for t in sorted(g.tensors.values(), key=lambda t: t.name):
        qp = None
        if t.qparams is not None:
            qp = {"bits": t.qparams.bits, "axis": t.qparams.axis}
            arrays[f"qp.scale/{t.name}"] = np.asarray(t.qparams.scale)
            arrays[f"qp.zp/{t.name}"] = np.asarray(t.qparams.zero_point)
        tensors.append({
            "name": t.name, "shape": list(t.shape), "kind": t.kind,
            "dtype": t.dtype, "producer": t.producer,
            "consumers": list(t.consumers), "scale": t.scale, "qparams": qp,
        })
    ops = [{"name": op.name, "kind": op.kind, "inputs": list(op.inputs),
            "outputs": list(op.outputs), "attrs": op.attrs}
           for op in g.ops]
    return {"name": g.name, "tensors": tensors, "ops": ops}, arrays


def graph_from_payload(p: dict, arrays: Dict[str, np.ndarray]) -> Graph:
    g = Graph(p["name"])
    for tp in p["tensors"]:
        qp = None
        if tp["qparams"] is not None:
            qp = QParams(arrays[f"qp.scale/{tp['name']}"],
                         arrays[f"qp.zp/{tp['name']}"],
                         bits=int(tp["qparams"]["bits"]),
                         axis=tp["qparams"]["axis"])
        g.tensors[tp["name"]] = Tensor(
            tp["name"], tuple(tp["shape"]), tp["kind"], tp["dtype"],
            tp["producer"], list(tp["consumers"]), tp["scale"], qp)
    for op_p in p["ops"]:
        # "pad"/"k" attrs are tuples in builder-made graphs; JSON returns
        # lists, and the executors unpack them positionally either way,
        # but fingerprint stability and isinstance(k, tuple) checks in
        # in_row_range need the original tuple form back.
        attrs = {k: _tuplify(v) for k, v in op_p["attrs"].items()}
        op = Op(op_p["name"], op_p["kind"], list(op_p["inputs"]),
                list(op_p["outputs"]), attrs)
        g.ops.append(op)
        g._op_index[op.name] = op
    return g


# --------------------------------------------------------------------------
# NPUProgram
# --------------------------------------------------------------------------


def program_to_payload(prog: NPUProgram) -> dict:
    ticks = []
    for t in prog.ticks:
        cj = None
        if t.compute:
            c = t.compute
            cj = {"op": c.op_name,
                  "out": [_tile_to_list(x) for x in c.out_tiles],
                  "in": [_tile_to_list(x) for x in c.in_tiles],
                  "fmt": c.fmt, "cycles": c.cycles, "macs": c.macs,
                  "r0": c.r0, "r1": c.r1, "axis": c.axis}
        ticks.append({
            "index": t.index,
            "compute": cj,
            "dma": [[j.kind, _tile_to_list(j.tile), j.nbytes, j.cycles]
                    for j in t.dma],
            "v2p": [[_tile_to_list(j.tile), list(j.banks), j.cycles]
                    for j in t.v2p],
        })
    meta = dict(prog.meta)
    dead = meta.pop("dead_after_tick", {})
    return {
        "name": prog.name,
        "cfg": config_to_payload(prog.cfg),
        "dm_penalty": prog.dm_penalty,
        "ticks": ticks,
        "meta": meta,
        "dead_after_tick": {str(k): [[n, i] for (n, i) in v]
                            for k, v in dead.items()},
    }


def program_from_payload(p: dict) -> NPUProgram:
    ticks: List[Tick] = []
    for tp in p["ticks"]:
        cj = None
        if tp["compute"] is not None:
            c = tp["compute"]
            cj = ComputeJob(c["op"],
                            [_tile_from_list(x) for x in c["out"]],
                            [_tile_from_list(x) for x in c["in"]],
                            c["fmt"], int(c["cycles"]), int(c["macs"]),
                            r0=c["r0"], r1=c["r1"], axis=c["axis"])
        ticks.append(Tick(
            int(tp["index"]), cj,
            [DmaJob(j[0], _tile_from_list(j[1]), int(j[2]), int(j[3]))
             for j in tp["dma"]],
            [V2PJob(_tile_from_list(j[0]), [int(b) for b in j[1]],
                    int(j[2])) for j in tp["v2p"]],
        ))
    meta = dict(p["meta"])
    meta["dead_after_tick"] = {
        int(k): [(n, int(i)) for n, i in v]
        for k, v in p["dead_after_tick"].items()}
    return NPUProgram(p["name"], config_from_payload(p["cfg"]), ticks,
                      dm_penalty=int(p["dm_penalty"]), meta=meta)


# --------------------------------------------------------------------------
# TilingResult / Allocation / FormatPlan
# --------------------------------------------------------------------------


def tiling_to_payload(tiling: TilingResult) -> dict:
    # ``stats`` round-trips as plain JSON and now carries the fusion
    # coverage record (cp/windowed/greedy/layer-wise region counts,
    # window counts and per-region detail) that CompiledModel.report()
    # surfaces.  ``tiling.fallback`` — the greedy-order race variant the
    # compile ladder may hold transiently — is deliberately NOT
    # persisted: artifacts store only the chosen plan.
    return {
        "tiles": [[name, [_tile_to_list(tl) for tl in tt.tiles]]
                  for name, tt in tiling.tiles.items()],
        "order": [[s.op_name, s.r0, s.r1, s.axis] for s in tiling.order],
        "regions": [list(r) for r in tiling.regions],
        "fusion_objective": tiling.fusion_objective,
        "stats": json.loads(json.dumps(tiling.stats, default=list)),
    }


def tiling_from_payload(p: dict) -> TilingResult:
    tiles = {name: TensorTiles(name, [_tile_from_list(v) for v in tls])
             for name, tls in p["tiles"]}
    order = [ComputeStep(o, int(r0), int(r1), axis)
             for o, r0, r1, axis in p["order"]]
    return TilingResult(tiles, order, [list(r) for r in p["regions"]],
                        p["fusion_objective"], dict(p["stats"]))


def allocation_to_payload(alloc: Allocation) -> dict:
    return {
        "banks": [[n, i, list(b)] for (n, i), b in alloc.banks.items()],
        "tiles": [[n, i, _tile_to_list(tl)]
                  for (n, i), tl in alloc.tiles.items()],
        "peak_banks": alloc.peak_banks,
        "v2p_updates": alloc.v2p_updates,
        "repair_spills": alloc.repair_spills,
        # spill_events are compile-time diagnostics; not persisted
    }


def allocation_from_payload(p: dict) -> Allocation:
    return Allocation(
        banks={(n, int(i)): [int(x) for x in b]
               for n, i, b in p["banks"]},
        tiles={(n, int(i)): _tile_from_list(tl)
               for n, i, tl in p["tiles"]},
        peak_banks=int(p["peak_banks"]),
        v2p_updates=int(p["v2p_updates"]),
        repair_spills=int(p["repair_spills"]),
    )


def plan_to_payload(plan: FormatPlan) -> dict:
    return {"fmt": dict(plan.fmt), "cost_cycles": dict(plan.cost_cycles)}


def plan_from_payload(p: dict) -> FormatPlan:
    return FormatPlan(dict(p["fmt"]),
                      {k: int(v) for k, v in p["cost_cycles"].items()})


# --------------------------------------------------------------------------
# Container: zip of json payloads + arrays.npz with a sha256 manifest
# --------------------------------------------------------------------------


def _json_bytes(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def write_artifact(path: str, key: dict, payloads: Dict[str, Any],
                   arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    """Write one artifact file.  ``key`` is the caller's identity record
    (fingerprint / config / options digest / precision …); ``payloads``
    maps component name -> JSON-able payload; ``arrays`` holds every
    numpy array referenced by the payloads."""
    entries: Dict[str, bytes] = {}
    for name, payload in payloads.items():
        entries[f"{name}.json"] = _json_bytes(payload)
    if arrays:
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        entries["arrays.npz"] = buf.getvalue()
    meta = {
        "magic": ARTIFACT_MAGIC,
        "version": ARTIFACT_VERSION,
        "key": key,
        "manifest": {name: hashlib.sha256(blob).hexdigest()
                     for name, blob in sorted(entries.items())},
    }
    with zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED) as zf:
        zf.writestr("meta.json", _json_bytes(meta))
        for name, blob in sorted(entries.items()):
            zf.writestr(name, blob)


def read_artifact(path: str) -> Tuple[dict, Dict[str, Any],
                                      Dict[str, np.ndarray]]:
    """Read + integrity-check one artifact file.

    Returns ``(key, payloads, arrays)``.  Raises :class:`ArtifactError`
    on any corruption: bad zip, missing/extra entries vs the manifest,
    sha256 mismatch, wrong magic or incompatible version."""
    try:
        with zipfile.ZipFile(path, "r") as zf:
            try:
                meta = json.loads(zf.read("meta.json"))
            except KeyError:
                raise ArtifactError(f"{path}: no meta.json")
            if meta.get("magic") != ARTIFACT_MAGIC:
                raise ArtifactError(f"{path}: not a repro NPU artifact")
            if meta.get("version") != ARTIFACT_VERSION:
                raise ArtifactError(
                    f"{path}: artifact version {meta.get('version')} "
                    f"incompatible with {ARTIFACT_VERSION}")
            manifest = meta.get("manifest", {})
            entries: Dict[str, bytes] = {}
            names = set(zf.namelist()) - {"meta.json"}
            if names != set(manifest):
                raise ArtifactError(
                    f"{path}: entry set {sorted(names)} does not match "
                    f"manifest {sorted(manifest)}")
            for name, want in manifest.items():
                blob = zf.read(name)
                got = hashlib.sha256(blob).hexdigest()
                if got != want:
                    raise ArtifactError(
                        f"{path}: checksum mismatch on {name}")
                entries[name] = blob
    except zipfile.BadZipFile as e:
        raise ArtifactError(f"{path}: unreadable artifact ({e})") from e
    payloads: Dict[str, Any] = {}
    arrays: Dict[str, np.ndarray] = {}
    for name, blob in entries.items():
        if name == "arrays.npz":
            with np.load(io.BytesIO(blob)) as npz:
                arrays = {k: npz[k] for k in npz.files}
        elif name.endswith(".json"):
            payloads[name[:-5]] = json.loads(blob)
    return meta["key"], payloads, arrays


def options_digest(opts_key: tuple) -> str:
    """Stable digest of a CompilerOptions.cache_key() tuple (its repr is
    deterministic: strings, numbers, bools, None and nested tuples)."""
    return hashlib.sha256(repr(opts_key).encode()).hexdigest()


def cache_file_key(fingerprint: str, cfg: NPUConfig, opts_key: tuple) -> str:
    """Filename-safe digest of the full compiled-program cache key."""
    return cache_file_key_digest(fingerprint, config_to_payload(cfg),
                                 options_digest(opts_key))


def cache_file_key_digest(fingerprint: str, cfg_payload: dict,
                          opts_digest: str) -> str:
    """Same digest, from the already-serialized key components (what an
    artifact's own key record stores — lets auditors re-derive the
    expected filename of any artifact from its contents)."""
    blob = _json_bytes({"fp": fingerprint, "cfg": cfg_payload,
                        "opts": opts_digest})
    return hashlib.sha256(blob).hexdigest()
