"""Compiler driver: graph -> timed NPU program (paper §IV end-to-end).

``compile_graph`` chains the mid-end passes — format selection, temporal
tiling + layer fusion, tick DAE scheduling, memory allocation — and
returns the compiled program plus per-phase diagnostics.  The
:class:`CompilerOptions` knobs expose exactly the ablations the paper
evaluates:

  * ``baseline()``        — the eNPU-A-style reference stack: single
    (depth) format, layer-by-layer execution (no fusion), no DAE overlap.
    Used for the Table III speedup comparisons.
  * ``partition=False``   — monolithic CP (Table II row 1).
  * ``fusion=False``      — no layer fusion (Fig. 6 "without").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

from .allocation import Allocation, AllocationError, allocate
from .formats import FORMATS, FormatPlan, select_formats
from .ir import Graph
from .npu import NPUConfig
from .program import NPUProgram
from .scheduling import SchedOptions, schedule
from .tiling import TilingResult, plan_tiling


@dataclass
class CompilerOptions:
    formats: tuple = FORMATS          # allowed parallelism formats
    fusion: bool = True               # layer fusion CP (§IV-C)
    naive_tiling: bool = False        # reference-stack tile bounds
    overlap: bool = True              # DAE overlap (§IV-B)
    partition: bool = True            # partition the CP problems
    partition_steps: int = 12
    cp_time_limit_s: float = 1.0      # per subproblem
    monolithic_time_limit_s: float = 20.0
    dm_penalty: int = 16

    @staticmethod
    def baseline() -> "CompilerOptions":
        """The reference embedded-NPU compiler behaviour (§V eNPU-A/B)."""
        return CompilerOptions(formats=("depth",), fusion=False,
                               overlap=False, naive_tiling=True)


@dataclass
class CompileResult:
    program: NPUProgram
    plan: FormatPlan
    tiling: TilingResult
    allocation: Allocation
    compile_s: float
    phase_s: Dict[str, float] = field(default_factory=dict)

    def stats(self) -> Dict[str, float]:
        s = self.program.stats()
        s["compile_s"] = self.compile_s
        s.update({f"phase_{k}_s": v for k, v in self.phase_s.items()})
        return s


def compile_graph(g: Graph, cfg: NPUConfig,
                  opts: Optional[CompilerOptions] = None) -> CompileResult:
    opts = opts or CompilerOptions()
    phase: Dict[str, float] = {}
    t0 = time.monotonic()

    t = time.monotonic()
    plan = select_formats(cfg, g, allowed=opts.formats)
    phase["formats"] = time.monotonic() - t

    sched_opt = SchedOptions(
        overlap=opts.overlap,
        partition=opts.partition,
        partition_steps=opts.partition_steps,
        cp_time_limit_s=(opts.cp_time_limit_s if opts.partition
                         else opts.monolithic_time_limit_s),
        dm_penalty=opts.dm_penalty,
    )
    # tile-budget ladder: a working set that over-subscribes the TCM at
    # schedule or allocation time is retried with finer tiles (the
    # paper's "partitioned into smaller sub-problems" escape hatch,
    # §III-B).  Within a rung, allocation failures first retry with pure
    # JIT placement (no CP re-timing) before descending.
    t = time.monotonic()
    last_err: Optional[Exception] = None
    prog = alloc = None
    for frac in (0.5, 0.25, 0.125, 0.0625, 0.03125):
        tiling = plan_tiling(cfg, g, plan, fusion=opts.fusion,
                             cp_time_limit_s=opts.cp_time_limit_s,
                             budget_frac=frac,
                             naive=opts.naive_tiling)
        for so in (sched_opt,
                   replace(sched_opt, cp_time_limit_s=0.0)):
            try:
                prog = schedule(cfg, g, plan, tiling, so)
                alloc = allocate(prog, cfg)
                last_err = None
                break
            except (RuntimeError, AllocationError) as e:
                last_err = e
                prog = alloc = None
                continue
        if last_err is None:
            break
    if last_err is not None:
        raise last_err
    phase["schedule_allocate"] = time.monotonic() - t

    return CompileResult(prog, plan, tiling, alloc,
                         time.monotonic() - t0, phase)
