"""Compiler driver: graph -> timed NPU program (paper §IV end-to-end).

``compile_graph`` chains the mid-end passes — format selection, temporal
tiling + layer fusion, tick DAE scheduling, memory allocation — and
returns the compiled program plus per-phase diagnostics.  The
:class:`CompilerOptions` knobs expose exactly the ablations the paper
evaluates:

  * ``baseline()``        — the eNPU-A-style reference stack: single
    (depth) format, layer-by-layer execution (no fusion), no DAE overlap.
    Used for the Table III speedup comparisons.
  * ``partition=False``   — monolithic CP (Table II row 1).
  * ``fusion=False``      — no layer fusion (Fig. 6 "without").
  * ``seed_solver()``     — the original (PR-0) compiler hot path:
    full-rescan CP engine, serial partition solving, no cost memo.  The
    perf baseline timed by ``benchmarks/compile_bench.py``.

Repeated serving compiles of the same model hit the content-addressed
**compiled-program cache**: the key is (canonical ``Graph`` structure
hash, ``NPUConfig``, compile options), so a cache hit returns the
previously compiled ``NPUProgram`` without re-running any pass, and any
change to the graph topology, hardware config or options misses.
Programs are treated as immutable once allocated.

The cache is **two-tier**: a bounded in-process LRU (configurable entry
and byte caps) in front of an optional on-disk artifact directory
(``program_cache_configure(disk_dir=...)`` or the
``REPRO_PROGRAM_CACHE_DIR`` environment variable).  Disk entries are the
versioned, checksummed artifacts of :mod:`repro.core.serialize`, keyed
by a digest of the same (fingerprint, config, options) triple — a
serving fleet process that misses in memory loads the program from disk
instead of re-running the CP solver, and a corrupted or stale artifact
is rejected (and recompiled), never silently replayed.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, fields, replace
from typing import Dict, Optional, Tuple

from ..obs import trace as _trace
from . import cpsolver, serialize
from .allocation import Allocation, AllocationError, allocate
from .formats import FORMATS, FormatPlan, select_formats
from .ir import Graph, graph_precision
from .npu import NPUConfig
from .program import NPUProgram
from .scheduling import SchedOptions, schedule
from .tiling import TilingResult, plan_tiling


@dataclass
class CompilerOptions:
    formats: tuple = FORMATS          # allowed parallelism formats
    fusion: bool = True               # layer fusion CP (§IV-C)
    naive_tiling: bool = False        # reference-stack tile bounds
    overlap: bool = True              # DAE overlap (§IV-B)
    partition: bool = True            # partition the CP problems
    partition_steps: int = 12
    # the incremental engine converges far faster than the seed engine,
    # so the default per-subproblem deadline is tighter; seed_solver()
    # keeps the historical 1.0 s
    cp_time_limit_s: float = 0.6      # per subproblem
    monolithic_time_limit_s: float = 20.0
    dm_penalty: int = 16
    cp_stall_s: Optional[float] = None  # CP early exit: stall wall-time
    cp_stall_nodes: Optional[int] = \
        cpsolver.DEFAULT_STALL_NODES      # …or stall search nodes
    parallel_cp: bool = True          # solve partitions on a process pool
    cp_engine: str = "incremental"    # cpsolver.ENGINES key
    # fusion-CP scale (§IV-C): regions whose estimated tile count fits
    # max_cp_tiles get the joint tile-size + order CP; bigger regions
    # are decomposed into overlapping windows of <= max_cp_window_tiles
    # greedy steps (region_overlap steps shared between neighbours),
    # solved concurrently and stitched.  max_cp_window_tiles=0 disables
    # windowing — oversized regions then fall back to the greedy order.
    max_cp_tiles: int = 36
    max_cp_window_tiles: int = 24
    region_overlap: int = 6
    # requested execution precision.  "auto" compiles whatever the graph
    # is annotated with; "float32"/"int8" assert the graph matches (a
    # quantized request must have gone through repro.quant.quantize_graph
    # — the compiler never quantizes implicitly).  Part of the cache key.
    precision: str = "auto"

    @staticmethod
    def baseline() -> "CompilerOptions":
        """The reference embedded-NPU compiler behaviour (§V eNPU-A/B)."""
        return CompilerOptions(formats=("depth",), fusion=False,
                               overlap=False, naive_tiling=True)

    @staticmethod
    def seed_solver() -> "CompilerOptions":
        """The pre-overhaul compiler hot path (same search quality knobs,
        original full-rescan engine, serial partitions, no stall exit)."""
        return CompilerOptions(cp_engine="reference", parallel_cp=False,
                               cp_stall_s=None, cp_stall_nodes=None,
                               cp_time_limit_s=1.0)

    def cache_key(self) -> Tuple:
        return tuple(getattr(self, f.name) for f in fields(self))


@dataclass
class CompileResult:
    program: NPUProgram
    plan: FormatPlan
    tiling: TilingResult
    allocation: Allocation
    compile_s: float
    phase_s: Dict[str, float] = field(default_factory=dict)
    cache_hit: bool = False
    cache_key: Optional[str] = None
    cache_tier: Optional[str] = None     # "memory" | "disk" | None (solved)

    def stats(self) -> Dict[str, float]:
        s = self.program.stats()
        s["compile_s"] = self.compile_s
        s.update({f"phase_{k}_s": v for k, v in self.phase_s.items()})
        return s


# --------------------------------------------------------------------------
# Compiled-program cache (two tiers: in-process LRU + on-disk artifacts)
# --------------------------------------------------------------------------

_CACHE_LOCK = threading.Lock()
#: key -> (result, estimated resident bytes)
_PROGRAM_CACHE: "OrderedDict[Tuple, Tuple[CompileResult, int]]" = \
    OrderedDict()
_CACHE_MAX_ENTRIES = 64
_CACHE_MAX_BYTES: Optional[int] = None
_CACHE_BYTES = 0
_CACHE_DISK_DIR: Optional[str] = \
    os.environ.get("REPRO_PROGRAM_CACHE_DIR") or None
_CACHE_DISK_MAX_BYTES: Optional[int] = None

_STATS_ZERO = {"mem_hits": 0, "mem_misses": 0, "mem_evictions": 0,
               "disk_hits": 0, "disk_misses": 0, "disk_writes": 0,
               "disk_rejects": 0, "disk_evictions": 0}
_CACHE_STATS = dict(_STATS_ZERO)
#: graph fingerprints exempt from LRU eviction (Session admission
#: policy: pinned hot models stay resident even under cap pressure).
_PINNED_FPS: set = set()

_UNSET = object()


def _estimate_result_bytes(res: CompileResult) -> int:
    """Cheap structural estimate of a cached entry's resident footprint
    (Python object overhead dominates; tile data lives in DRAM/TCM at
    run time, not in the program)."""
    n_jobs = sum(1 + len(t.dma) + len(t.v2p) for t in res.program.ticks)
    n_tiles = sum(len(tt.tiles) for tt in res.tiling.tiles.values())
    return 400 * n_jobs + 200 * (n_tiles + len(res.tiling.order)) + 4096


def program_cache_configure(max_entries: Optional[int] = None,
                            max_bytes=_UNSET, disk_dir=_UNSET,
                            disk_max_bytes=_UNSET) -> None:
    """Reconfigure the two-tier store.  ``max_entries``/``max_bytes``
    bound the in-process LRU (None byte cap = unbounded bytes);
    ``disk_dir`` enables (a path) or disables (None) the disk tier;
    ``disk_max_bytes`` caps the disk tier's total artifact bytes (None =
    unbounded) — past the cap the least-recently-served ``.rpa`` files
    are garbage-collected, counted by ``disk_evictions`` in
    :func:`program_cache_info`."""
    global _CACHE_MAX_ENTRIES, _CACHE_MAX_BYTES, _CACHE_DISK_DIR, \
        _CACHE_DISK_MAX_BYTES
    with _CACHE_LOCK:
        if max_entries is not None:
            _CACHE_MAX_ENTRIES = int(max_entries)
        if max_bytes is not _UNSET:
            _CACHE_MAX_BYTES = None if max_bytes is None else int(max_bytes)
        if disk_dir is not _UNSET:
            _CACHE_DISK_DIR = disk_dir
        if disk_max_bytes is not _UNSET:
            _CACHE_DISK_MAX_BYTES = None if disk_max_bytes is None \
                else int(disk_max_bytes)
        _evict_locked()
    if disk_dir is not _UNSET or disk_max_bytes is not _UNSET:
        d = _disk_dir_snapshot()
        if d:
            _disk_gc(d)


def program_cache_clear(stats: bool = True) -> None:
    """Drop every in-memory entry (the disk tier is persistent by design;
    remove its directory to clear it).  ``stats=True`` also zeroes the
    hit/miss/evict counters."""
    global _CACHE_BYTES
    with _CACHE_LOCK:
        _PROGRAM_CACHE.clear()
        _CACHE_BYTES = 0
        if stats:
            _CACHE_STATS.update(_STATS_ZERO)


def program_cache_info() -> Dict[str, int]:
    with _CACHE_LOCK:
        info = {"entries": len(_PROGRAM_CACHE), "max": _CACHE_MAX_ENTRIES,
                "max_entries": _CACHE_MAX_ENTRIES,
                "bytes": _CACHE_BYTES, "max_bytes": _CACHE_MAX_BYTES,
                "disk_dir": _CACHE_DISK_DIR,
                "disk_max_bytes": _CACHE_DISK_MAX_BYTES,
                "pinned_fps": len(_PINNED_FPS),
                "pinned_entries": sum(1 for k in _PROGRAM_CACHE
                                      if k[0] in _PINNED_FPS)}
        info.update(_CACHE_STATS)
    disk_dir = info["disk_dir"]
    info["disk_entries"] = 0
    info["disk_bytes"] = 0
    if disk_dir and os.path.isdir(disk_dir):
        for f in os.listdir(disk_dir):
            if not f.endswith(".rpa"):
                continue
            info["disk_entries"] += 1
            try:
                info["disk_bytes"] += os.path.getsize(
                    os.path.join(disk_dir, f))
            except OSError:
                pass              # raced with GC / external cleanup
    return info


def _evict_locked() -> None:
    global _CACHE_BYTES
    while _PROGRAM_CACHE and (
            len(_PROGRAM_CACHE) > _CACHE_MAX_ENTRIES or
            (_CACHE_MAX_BYTES is not None and
             _CACHE_BYTES > _CACHE_MAX_BYTES)):
        # LRU order, skipping pinned entries.  If only pinned entries
        # remain the store is allowed to exceed its caps — pinning is an
        # explicit operator decision and must never be silently undone.
        victim = next((k for k in _PROGRAM_CACHE
                       if k[0] not in _PINNED_FPS), None)
        if victim is None:
            break
        _, nb = _PROGRAM_CACHE.pop(victim)
        _CACHE_BYTES -= nb
        _CACHE_STATS["mem_evictions"] += 1


def program_cache_pin(fingerprint: str) -> None:
    """Exempt every cache entry of this graph fingerprint (present or
    future) from in-process LRU eviction."""
    with _CACHE_LOCK:
        _PINNED_FPS.add(fingerprint)


def program_cache_unpin(fingerprint: str) -> None:
    with _CACHE_LOCK:
        _PINNED_FPS.discard(fingerprint)
        _evict_locked()


def _cache_get(key: Tuple) -> Optional[CompileResult]:
    with _CACHE_LOCK:
        entry = _PROGRAM_CACHE.get(key)
        if entry is not None:
            _PROGRAM_CACHE.move_to_end(key)
            _CACHE_STATS["mem_hits"] += 1
            return entry[0]
        _CACHE_STATS["mem_misses"] += 1
        return None


def _cache_put(key: Tuple, res: CompileResult) -> None:
    global _CACHE_BYTES
    nb = _estimate_result_bytes(res)
    with _CACHE_LOCK:
        old = _PROGRAM_CACHE.pop(key, None)
        if old is not None:
            _CACHE_BYTES -= old[1]
        _PROGRAM_CACHE[key] = (res, nb)
        _CACHE_BYTES += nb
        _evict_locked()


# ---- disk tier -----------------------------------------------------------
# The disk directory is snapshotted once per compile (under the lock)
# and passed down, so a concurrent program_cache_configure(disk_dir=...)
# cannot yank the global out from under an in-flight compile; counter
# updates take the lock like the memory tier's.


def _bump(counter: str, n: int = 1) -> None:
    with _CACHE_LOCK:
        _CACHE_STATS[counter] += n


#: fault-injection hook for the disk tier (see repro.runtime.chaos):
#: called with the artifact path before every disk read; raising
#: ArtifactError exercises the reject-and-recompile path.  None in
#: production.
_DISK_READ_HOOK = None


def set_disk_read_hook(fn):
    """Install (or clear, with None) the disk-read fault-injection
    hook; returns the previous hook so callers can restore it."""
    global _DISK_READ_HOOK
    prev = _DISK_READ_HOOK
    _DISK_READ_HOOK = fn
    return prev


def _disk_dir_snapshot() -> Optional[str]:
    with _CACHE_LOCK:
        return _CACHE_DISK_DIR


def _disk_gc(disk_dir: str) -> None:
    """Evict oldest artifacts once the disk tier exceeds its byte cap.

    "Oldest" is least-recently-*served*: a disk hit touches the file's
    mtime, so hot programs survive the sweep.  Unlink races (another
    process GC-ing the same shared dir) are benign — whoever loses the
    race just skips the file."""
    with _CACHE_LOCK:
        cap = _CACHE_DISK_MAX_BYTES
    if cap is None or not os.path.isdir(disk_dir):
        return
    entries = []
    for f in os.listdir(disk_dir):
        if not f.endswith(".rpa"):
            continue
        p = os.path.join(disk_dir, f)
        try:
            st = os.stat(p)
        except OSError:
            continue
        entries.append((st.st_mtime, st.st_size, p))
    total = sum(sz for _, sz, _ in entries)
    for _, sz, p in sorted(entries):
        if total <= cap:
            return
        try:
            os.unlink(p)
        except OSError:
            continue
        _bump("disk_evictions")
        total -= sz


def _disk_path(disk_dir: str, fp: str, cfg: NPUConfig,
               opts: "CompilerOptions") -> str:
    digest = serialize.cache_file_key(fp, cfg, opts.cache_key())
    return os.path.join(disk_dir, f"{digest}.rpa")


def _disk_get(disk_dir: str, fp: str, cfg: NPUConfig,
              opts: "CompilerOptions") -> Optional[CompileResult]:
    path = _disk_path(disk_dir, fp, cfg, opts)
    if not os.path.exists(path):
        _bump("disk_misses")
        return None
    t = time.monotonic()
    try:
        if _DISK_READ_HOOK is not None:
            _DISK_READ_HOOK(path)
        key, payloads, _ = serialize.read_artifact(path)
        if (key.get("fingerprint") != fp or
                key.get("cfg") != serialize.config_to_payload(cfg) or
                key.get("opts") !=
                serialize.options_digest(opts.cache_key())):
            raise serialize.ArtifactError(
                f"{path}: stale artifact (key mismatch)")
        res = CompileResult(
            serialize.program_from_payload(payloads["program"]),
            serialize.plan_from_payload(payloads["plan"]),
            serialize.tiling_from_payload(payloads["tiling"]),
            serialize.allocation_from_payload(payloads["allocation"]),
            compile_s=0.0,
            phase_s={"disk_load": time.monotonic() - t},
            cache_hit=True, cache_key=fp, cache_tier="disk")
    except (serialize.ArtifactError, OSError):
        # reject, never replay — and degrade to a recompile on any I/O
        # error (file vanished between exists() and open, permissions,
        # …): the disk tier must never fail a serving compile.  A fresh
        # compile overwrites the bad file.
        _bump("disk_rejects")
        _bump("disk_misses")
        return None
    try:
        os.utime(path)            # mark recently-served for the GC sweep
    except OSError:
        pass
    _bump("disk_hits")
    return res


def _disk_put(disk_dir: str, fp: str, cfg: NPUConfig,
              opts: "CompilerOptions", res: CompileResult) -> None:
    os.makedirs(disk_dir, exist_ok=True)
    path = _disk_path(disk_dir, fp, cfg, opts)
    key = {"fingerprint": fp, "cfg": serialize.config_to_payload(cfg),
           "opts": serialize.options_digest(opts.cache_key())}
    payloads = {
        "program": serialize.program_to_payload(res.program),
        "plan": serialize.plan_to_payload(res.plan),
        "tiling": serialize.tiling_to_payload(res.tiling),
        "allocation": serialize.allocation_to_payload(res.allocation),
    }
    fd, tmp = tempfile.mkstemp(dir=disk_dir, suffix=".tmp")
    os.close(fd)
    try:
        serialize.write_artifact(tmp, key, payloads)
        os.replace(tmp, path)     # atomic vs concurrent readers
        _bump("disk_writes")
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def compile_graph(g: Graph, cfg: NPUConfig,
                  opts: Optional[CompilerOptions] = None,
                  cache: bool = True) -> CompileResult:
    opts = opts or CompilerOptions()
    t0 = time.monotonic()

    if opts.precision != "auto":
        got = graph_precision(g)
        if got != opts.precision:
            raise ValueError(
                f"CompilerOptions(precision={opts.precision!r}) but graph "
                f"{g.name!r} is annotated {got!r} — run "
                f"repro.quant.quantize_graph (or cast_graph) first")

    key = fp = None
    if cache:
        fp = g.fingerprint()
        key = (fp, cfg, opts.cache_key())
        hit = _cache_get(key)
        if hit is not None:
            _trace.instant("program_cache", "compile",
                           args={"model": g.name, "tier": "memory"})
            # same shared (immutable) program/tiling/allocation objects;
            # fresh timing envelope for this call
            return replace(hit, compile_s=time.monotonic() - t0,
                           phase_s=dict(hit.phase_s, cache_hit=0.0),
                           cache_hit=True, cache_tier="memory")
        disk_dir = _disk_dir_snapshot()
        if disk_dir:
            disk = _disk_get(disk_dir, fp, cfg, opts)
            if disk is not None:
                _trace.instant("program_cache", "compile",
                               args={"model": g.name, "tier": "disk"})
                _cache_put(key, disk)
                return replace(disk, compile_s=time.monotonic() - t0)
    _trace.instant("program_cache", "compile",
                   args={"model": g.name,
                         "tier": "miss" if cache else "bypass"})

    phase: Dict[str, float] = {}
    tr = _trace.active()
    t = time.monotonic()
    plan = select_formats(cfg, g, allowed=opts.formats)
    phase["formats"] = time.monotonic() - t
    if tr is not None:
        tr.complete("compile:formats", "compile", t,
                    t + phase["formats"], args={"model": g.name})

    sched_opt = SchedOptions(
        overlap=opts.overlap,
        partition=opts.partition,
        partition_steps=opts.partition_steps,
        cp_time_limit_s=(opts.cp_time_limit_s if opts.partition
                         else opts.monolithic_time_limit_s),
        cp_stall_s=opts.cp_stall_s,
        cp_stall_nodes=opts.cp_stall_nodes,
        parallel_cp=opts.parallel_cp,
        cp_engine=opts.cp_engine,
        dm_penalty=opts.dm_penalty,
    )
    # tile-budget ladder: a working set that over-subscribes the TCM at
    # schedule or allocation time is retried with finer tiles (the
    # paper's "partitioned into smaller sub-problems" escape hatch,
    # §III-B).  Within a rung, allocation failures first retry with pure
    # JIT placement (no CP re-timing) before descending.
    #
    # When windowed fusion produced a stitched order that differs from
    # the greedy one, plan_tiling attaches the greedy-order variant as
    # `tiling.fallback` (same tiles, no re-solving) and the rung races
    # both through the scheduler, keeping whichever program the DAE
    # latency model scores better: the window CP's memory objective is a
    # proxy, and the guarantee that windowing never loses vs greedy
    # comes from this race, not from the proxy.
    t = time.monotonic()
    last_err: Optional[Exception] = None
    prog = alloc = tiling = None
    for frac in (0.5, 0.25, 0.125, 0.0625, 0.03125):
        ti = plan_tiling(cfg, g, plan, fusion=opts.fusion,
                         cp_time_limit_s=opts.cp_time_limit_s,
                         max_cp_tiles=opts.max_cp_tiles,
                         budget_frac=frac,
                         naive=opts.naive_tiling,
                         cp_stall_s=opts.cp_stall_s,
                         cp_stall_nodes=opts.cp_stall_nodes,
                         parallel_cp=opts.parallel_cp,
                         cp_engine=opts.cp_engine,
                         max_cp_window_tiles=opts.max_cp_window_tiles,
                         region_overlap=opts.region_overlap)
        best = None
        for cand in ([ti] if ti.fallback is None else [ti, ti.fallback]):
            got = None
            for so in (sched_opt,
                       replace(sched_opt, cp_time_limit_s=0.0)):
                try:
                    p = schedule(cfg, g, plan, cand, so)
                    a = allocate(p, cfg)
                    got = (p, a, cand)
                    last_err = None
                    break
                except (RuntimeError, AllocationError) as e:
                    last_err = e
                    continue
            if got is not None and (
                    best is None or
                    (got[0].latency_cycles(), got[0].ddr_bytes()) <
                    (best[0].latency_cycles(), best[0].ddr_bytes())):
                best = got
        if best is not None:
            prog, alloc, tiling = best
            tiling.fallback = None       # not part of the compiled result
            last_err = None
            break
    if last_err is not None:
        raise last_err
    phase["schedule_allocate"] = time.monotonic() - t
    if tr is not None:
        tr.complete("compile:schedule_allocate", "compile", t,
                    t + phase["schedule_allocate"],
                    args={"model": g.name})

    res = CompileResult(prog, plan, tiling, alloc,
                        time.monotonic() - t0, phase,
                        cache_hit=False, cache_key=fp)
    if tr is not None:
        tr.complete("compile", "compile", t0,
                    args={"model": g.name, "precision": opts.precision})
    if cache and key is not None:
        _cache_put(key, res)
        disk_dir = _disk_dir_snapshot()
        if disk_dir:
            t = time.monotonic()
            try:
                _disk_put(disk_dir, fp, cfg, opts, res)
                _disk_gc(disk_dir)
                phase["disk_store"] = time.monotonic() - t
            except OSError:
                pass              # disk tier is best-effort
    return res
