"""Compiler driver: graph -> timed NPU program (paper §IV end-to-end).

``compile_graph`` chains the mid-end passes — format selection, temporal
tiling + layer fusion, tick DAE scheduling, memory allocation — and
returns the compiled program plus per-phase diagnostics.  The
:class:`CompilerOptions` knobs expose exactly the ablations the paper
evaluates:

  * ``baseline()``        — the eNPU-A-style reference stack: single
    (depth) format, layer-by-layer execution (no fusion), no DAE overlap.
    Used for the Table III speedup comparisons.
  * ``partition=False``   — monolithic CP (Table II row 1).
  * ``fusion=False``      — no layer fusion (Fig. 6 "without").
  * ``seed_solver()``     — the original (PR-0) compiler hot path:
    full-rescan CP engine, serial partition solving, no cost memo.  The
    perf baseline timed by ``benchmarks/compile_bench.py``.

Repeated serving compiles of the same model hit the content-addressed
**compiled-program cache**: the key is (canonical ``Graph`` structure
hash, ``NPUConfig``, compile options), so a cache hit returns the
previously compiled ``NPUProgram`` without re-running any pass, and any
change to the graph topology, hardware config or options misses.
Programs are treated as immutable once allocated.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, fields, replace
from typing import Dict, Optional, Tuple

from . import cpsolver
from .allocation import Allocation, AllocationError, allocate
from .formats import FORMATS, FormatPlan, select_formats
from .ir import Graph, graph_precision
from .npu import NPUConfig
from .program import NPUProgram
from .scheduling import SchedOptions, schedule
from .tiling import TilingResult, plan_tiling


@dataclass
class CompilerOptions:
    formats: tuple = FORMATS          # allowed parallelism formats
    fusion: bool = True               # layer fusion CP (§IV-C)
    naive_tiling: bool = False        # reference-stack tile bounds
    overlap: bool = True              # DAE overlap (§IV-B)
    partition: bool = True            # partition the CP problems
    partition_steps: int = 12
    # the incremental engine converges far faster than the seed engine,
    # so the default per-subproblem deadline is tighter; seed_solver()
    # keeps the historical 1.0 s
    cp_time_limit_s: float = 0.6      # per subproblem
    monolithic_time_limit_s: float = 20.0
    dm_penalty: int = 16
    cp_stall_s: Optional[float] = None  # CP early exit: stall wall-time
    cp_stall_nodes: Optional[int] = \
        cpsolver.DEFAULT_STALL_NODES      # …or stall search nodes
    parallel_cp: bool = True          # solve partitions on a process pool
    cp_engine: str = "incremental"    # cpsolver.ENGINES key
    # requested execution precision.  "auto" compiles whatever the graph
    # is annotated with; "float32"/"int8" assert the graph matches (a
    # quantized request must have gone through repro.quant.quantize_graph
    # — the compiler never quantizes implicitly).  Part of the cache key.
    precision: str = "auto"

    @staticmethod
    def baseline() -> "CompilerOptions":
        """The reference embedded-NPU compiler behaviour (§V eNPU-A/B)."""
        return CompilerOptions(formats=("depth",), fusion=False,
                               overlap=False, naive_tiling=True)

    @staticmethod
    def seed_solver() -> "CompilerOptions":
        """The pre-overhaul compiler hot path (same search quality knobs,
        original full-rescan engine, serial partitions, no stall exit)."""
        return CompilerOptions(cp_engine="reference", parallel_cp=False,
                               cp_stall_s=None, cp_stall_nodes=None,
                               cp_time_limit_s=1.0)

    def cache_key(self) -> Tuple:
        return tuple(getattr(self, f.name) for f in fields(self))


@dataclass
class CompileResult:
    program: NPUProgram
    plan: FormatPlan
    tiling: TilingResult
    allocation: Allocation
    compile_s: float
    phase_s: Dict[str, float] = field(default_factory=dict)
    cache_hit: bool = False
    cache_key: Optional[str] = None

    def stats(self) -> Dict[str, float]:
        s = self.program.stats()
        s["compile_s"] = self.compile_s
        s.update({f"phase_{k}_s": v for k, v in self.phase_s.items()})
        return s


# --------------------------------------------------------------------------
# Compiled-program cache
# --------------------------------------------------------------------------

_CACHE_LOCK = threading.Lock()
_PROGRAM_CACHE: "OrderedDict[Tuple, CompileResult]" = OrderedDict()
_PROGRAM_CACHE_MAX = 64


def program_cache_clear() -> None:
    with _CACHE_LOCK:
        _PROGRAM_CACHE.clear()


def program_cache_info() -> Dict[str, int]:
    with _CACHE_LOCK:
        return {"entries": len(_PROGRAM_CACHE), "max": _PROGRAM_CACHE_MAX}


def _cache_get(key: Tuple) -> Optional[CompileResult]:
    with _CACHE_LOCK:
        res = _PROGRAM_CACHE.get(key)
        if res is not None:
            _PROGRAM_CACHE.move_to_end(key)
        return res


def _cache_put(key: Tuple, res: CompileResult) -> None:
    with _CACHE_LOCK:
        _PROGRAM_CACHE[key] = res
        _PROGRAM_CACHE.move_to_end(key)
        while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.popitem(last=False)


def compile_graph(g: Graph, cfg: NPUConfig,
                  opts: Optional[CompilerOptions] = None,
                  cache: bool = True) -> CompileResult:
    opts = opts or CompilerOptions()
    t0 = time.monotonic()

    if opts.precision != "auto":
        got = graph_precision(g)
        if got != opts.precision:
            raise ValueError(
                f"CompilerOptions(precision={opts.precision!r}) but graph "
                f"{g.name!r} is annotated {got!r} — run "
                f"repro.quant.quantize_graph (or cast_graph) first")

    key = fp = None
    if cache:
        fp = g.fingerprint()
        key = (fp, cfg, opts.cache_key())
        hit = _cache_get(key)
        if hit is not None:
            # same shared (immutable) program/tiling/allocation objects;
            # fresh timing envelope for this call
            return replace(hit, compile_s=time.monotonic() - t0,
                           phase_s=dict(hit.phase_s, cache_hit=0.0),
                           cache_hit=True)

    phase: Dict[str, float] = {}
    t = time.monotonic()
    plan = select_formats(cfg, g, allowed=opts.formats)
    phase["formats"] = time.monotonic() - t

    sched_opt = SchedOptions(
        overlap=opts.overlap,
        partition=opts.partition,
        partition_steps=opts.partition_steps,
        cp_time_limit_s=(opts.cp_time_limit_s if opts.partition
                         else opts.monolithic_time_limit_s),
        cp_stall_s=opts.cp_stall_s,
        cp_stall_nodes=opts.cp_stall_nodes,
        parallel_cp=opts.parallel_cp,
        cp_engine=opts.cp_engine,
        dm_penalty=opts.dm_penalty,
    )
    # tile-budget ladder: a working set that over-subscribes the TCM at
    # schedule or allocation time is retried with finer tiles (the
    # paper's "partitioned into smaller sub-problems" escape hatch,
    # §III-B).  Within a rung, allocation failures first retry with pure
    # JIT placement (no CP re-timing) before descending.
    t = time.monotonic()
    last_err: Optional[Exception] = None
    prog = alloc = None
    for frac in (0.5, 0.25, 0.125, 0.0625, 0.03125):
        tiling = plan_tiling(cfg, g, plan, fusion=opts.fusion,
                             cp_time_limit_s=opts.cp_time_limit_s,
                             budget_frac=frac,
                             naive=opts.naive_tiling,
                             cp_stall_s=opts.cp_stall_s,
                             cp_stall_nodes=opts.cp_stall_nodes,
                             parallel_cp=opts.parallel_cp,
                             cp_engine=opts.cp_engine)
        for so in (sched_opt,
                   replace(sched_opt, cp_time_limit_s=0.0)):
            try:
                prog = schedule(cfg, g, plan, tiling, so)
                alloc = allocate(prog, cfg)
                last_err = None
                break
            except (RuntimeError, AllocationError) as e:
                last_err = e
                prog = alloc = None
                continue
        if last_err is None:
            break
    if last_err is not None:
        raise last_err
    phase["schedule_allocate"] = time.monotonic() - t

    res = CompileResult(prog, plan, tiling, alloc,
                        time.monotonic() - t0, phase,
                        cache_hit=False, cache_key=fp)
    if cache and key is not None:
        _cache_put(key, res)
    return res
