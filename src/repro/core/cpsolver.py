"""Self-contained 0-1 constraint-programming solver.

The paper formulates tiling/fusion (§IV-C), scheduling (§IV-B) and memory
allocation (§IV-D) as constraint programs and solves them with an external
CP solver.  No solver ships in this container, so this module implements a
real one: pseudo-boolean linear constraints over 0/1 variables, a linear
(+ pairwise-max) objective, constraint propagation, a caller-supplied warm
start as incumbent, and depth-first branch & bound with activity-based
variable ordering under a wall-clock deadline.

Design notes
------------
* All model variables are booleans.  The paper's integer quantities
  (``MemTh_t``, bank extents) are linearized by the model builders — see
  tiling.py / scheduling.py — so linear pseudo-boolean constraints are
  sufficient and keep propagation cheap.
* The scheduling objective Eq. (8) contains ``max(l_DM(t), l_C(t))``
  per tick; :class:`MaxTerm` supports exactly that shape.  Its lower bound
  under a partial assignment is ``max_k(lb(expr_k))`` which keeps B&B
  bounds admissible.
* ``solve`` always returns the best incumbent found; ``optimal`` is True
  only when the search space was exhausted within the deadline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

Terms = Sequence[Tuple[int, int]]  # (var_id, coef)


@dataclass
class MaxTerm:
    """Objective contribution ``max_k(const_k + sum coef*var)``."""

    exprs: List[Tuple[int, Terms]]  # (const, terms)

    def value(self, vals: Sequence[int]) -> int:
        return max(c + sum(co * vals[v] for v, co in t)
                   for c, t in self.exprs)

    def lower_bound(self, vals: Sequence[int], assigned: Sequence[bool]
                    ) -> int:
        lb = None
        for c, t in self.exprs:
            e = c
            for v, co in t:
                if assigned[v]:
                    e += co * vals[v]
                elif co < 0:
                    e += co
            lb = e if lb is None else max(lb, e)
        return lb or 0


@dataclass
class _Constraint:
    vars: List[int]
    coefs: List[int]
    rhs: int               # sum coefs*x <= rhs
    name: str = ""


@dataclass
class Solution:
    values: Dict[int, int]
    objective: float
    optimal: bool
    feasible: bool
    nodes: int
    wall_s: float

    def __getitem__(self, var: int) -> int:
        return self.values[var]


class CPModel:
    def __init__(self, name: str = "model"):
        self.name = name
        self.n_vars = 0
        self.var_names: List[str] = []
        self.cons: List[_Constraint] = []
        self.obj_terms: List[Tuple[int, int]] = []
        self.obj_const: int = 0
        self.max_terms: List[MaxTerm] = []
        self.fixed: Dict[int, int] = {}

    # ---- variables ----
    def bool(self, name: str = "") -> int:
        vid = self.n_vars
        self.n_vars += 1
        self.var_names.append(name or f"x{vid}")
        return vid

    def fix(self, var: int, val: int) -> None:
        self.fixed[var] = int(val)

    # ---- constraints (normalized to <=) ----
    def add(self, terms: Terms, sense: str, rhs: int, name: str = "") -> None:
        terms = [(v, c) for v, c in terms if c != 0]
        if sense == "<=":
            self.cons.append(_Constraint([v for v, _ in terms],
                                         [c for _, c in terms], rhs, name))
        elif sense == ">=":
            self.cons.append(_Constraint([v for v, _ in terms],
                                         [-c for _, c in terms], -rhs, name))
        elif sense == "==":
            self.add(terms, "<=", rhs, name)
            self.add(terms, ">=", rhs, name)
        else:
            raise ValueError(sense)

    def add_implies(self, a: int, b: int, name: str = "") -> None:
        """a -> b   ==   a - b <= 0."""
        self.add([(a, 1), (b, -1)], "<=", 0, name)

    def add_at_most_one(self, vars_: Iterable[int], name: str = "") -> None:
        self.add([(v, 1) for v in vars_], "<=", 1, name)

    def add_exactly_one(self, vars_: Iterable[int], name: str = "") -> None:
        self.add([(v, 1) for v in vars_], "==", 1, name)

    # ---- objective ----
    def minimize(self, terms: Terms = (), const: int = 0,
                 max_terms: Sequence[MaxTerm] = ()) -> None:
        self.obj_terms = list(terms)
        self.obj_const = const
        self.max_terms = list(max_terms)

    def objective_value(self, vals: Sequence[int]) -> int:
        o = self.obj_const + sum(c * vals[v] for v, c in self.obj_terms)
        for mt in self.max_terms:
            o += mt.value(vals)
        return o

    def check(self, vals: Sequence[int]) -> List[str]:
        """Return names of violated constraints (empty == feasible)."""
        bad = []
        for con in self.cons:
            s = sum(c * vals[v] for v, c in zip(con.vars, con.coefs))
            if s > con.rhs:
                bad.append(con.name or "<unnamed>")
        for v, val in self.fixed.items():
            if vals[v] != val:
                bad.append(f"fixed:{self.var_names[v]}")
        return bad


# --------------------------------------------------------------------------
# Solver
# --------------------------------------------------------------------------


class _SearchState:
    __slots__ = ("vals", "assigned", "minsum", "trail")

    def __init__(self, n_vars: int, cons: List[_Constraint]):
        self.vals = [0] * n_vars
        self.assigned = [False] * n_vars
        # minsum[c] = sum of min contribution of every var in constraint c
        self.minsum = [sum(min(0, co) for co in c.coefs) for c in cons]
        self.trail: List[Tuple[int, List[Tuple[int, int]]]] = []


def solve(model: CPModel, time_limit_s: float = 10.0,
          warm_start: Optional[Dict[int, int]] = None) -> Solution:
    t0 = time.monotonic()
    deadline = t0 + time_limit_s
    n = model.n_vars
    cons = model.cons

    # occurrence lists: var -> [(constraint index, coef)]
    occ: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for ci, c in enumerate(cons):
        for v, co in zip(c.vars, c.coefs):
            occ[v].append((ci, co))

    obj_coef = [0] * n
    for v, c in model.obj_terms:
        obj_coef[v] += c

    # ---- incumbent from warm start ----
    best_vals: Optional[List[int]] = None
    best_obj = float("inf")
    if warm_start is not None:
        ws = [0] * n
        for v, val in warm_start.items():
            ws[v] = int(val)
        for v, val in model.fixed.items():
            ws[v] = val
        if not model.check(ws):
            best_vals = ws
            best_obj = model.objective_value(ws)

    st = _SearchState(n, cons)
    nodes = 0

    def assign(v: int, val: int) -> bool:
        """Assign and update minsums.  Returns False on conflict."""
        changed: List[Tuple[int, int]] = []
        st.vals[v] = val
        st.assigned[v] = True
        ok = True
        for ci, co in occ[v]:
            old_min = min(0, co)
            new_min = co * val
            if new_min != old_min:
                st.minsum[ci] += new_min - old_min
                changed.append((ci, new_min - old_min))
            if st.minsum[ci] > cons[ci].rhs:
                ok = False
        st.trail.append((v, changed))
        return ok

    def undo() -> None:
        v, changed = st.trail.pop()
        st.assigned[v] = False
        st.vals[v] = 0
        for ci, delta in changed:
            st.minsum[ci] -= delta

    def propagate(level_mark: int) -> bool:
        """Unit-force vars whose assignment is implied.  Appends to trail;
        caller rewinds to level_mark on failure."""
        moved = True
        while moved:
            moved = False
            for ci, c in enumerate(cons):
                slack = c.rhs - st.minsum[ci]
                if slack < 0:
                    return False
                for v, co in zip(c.vars, c.coefs):
                    if st.assigned[v]:
                        continue
                    if co > 0 and co > slack:
                        if not assign(v, 0):
                            return False
                        moved = True
                    elif co < 0 and -co > slack:
                        if not assign(v, 1):
                            return False
                        moved = True
        return True

    def obj_lb() -> float:
        lb = model.obj_const
        for v in range(n):
            if st.assigned[v]:
                lb += obj_coef[v] * st.vals[v]
            elif obj_coef[v] < 0:
                lb += obj_coef[v]
        for mt in model.max_terms:
            lb += mt.lower_bound(st.vals, st.assigned)
        return lb

    # static branching order: objective-coefficient magnitude, then index
    order = sorted(range(n), key=lambda v: (-abs(obj_coef[v]), v))

    # apply fixed vars up front
    root_ok = True
    for v, val in model.fixed.items():
        if not assign(v, val):
            root_ok = False
    if root_ok:
        root_ok = propagate(0)

    def dfs(depth: int) -> None:
        nonlocal nodes, best_vals, best_obj
        if time.monotonic() > deadline:
            raise TimeoutError
        nodes += 1
        if obj_lb() >= best_obj:
            return
        # pick next unassigned var
        v = next((u for u in order if not st.assigned[u]), None)
        if v is None:
            obj = model.objective_value(st.vals)
            if obj < best_obj:
                best_obj = obj
                best_vals = list(st.vals)
            return
        # value order: cheaper objective contribution first
        first = 0 if obj_coef[v] >= 0 else 1
        for val in (first, 1 - first):
            mark = len(st.trail)
            ok = assign(v, val)
            if ok:
                ok = propagate(mark)
            if ok:
                dfs(depth + 1)
            while len(st.trail) > mark:
                undo()

    optimal = False
    if root_ok:
        try:
            dfs(0)
            optimal = True
        except (TimeoutError, RecursionError):
            optimal = False

    wall = time.monotonic() - t0
    if best_vals is None:
        return Solution({}, float("inf"), optimal, False, nodes, wall)
    return Solution({v: best_vals[v] for v in range(n)},
                    float(best_obj), optimal, True, nodes, wall)


def brute_force(model: CPModel) -> Solution:
    """Exhaustive reference solver for tests (<= ~20 vars)."""
    n = model.n_vars
    assert n <= 22, "brute_force is for tiny models"
    best = None
    best_obj = float("inf")
    for mask in range(1 << n):
        vals = [(mask >> i) & 1 for i in range(n)]
        if any(vals[v] != val for v, val in model.fixed.items()):
            continue
        if model.check(vals):
            continue
        o = model.objective_value(vals)
        if o < best_obj:
            best_obj = o
            best = vals
    if best is None:
        return Solution({}, float("inf"), True, False, 1 << n, 0.0)
    return Solution({v: best[v] for v in range(n)}, float(best_obj),
                    True, True, 1 << n, 0.0)
