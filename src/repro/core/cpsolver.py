"""Self-contained 0-1 constraint-programming solver.

The paper formulates tiling/fusion (§IV-C), scheduling (§IV-B) and memory
allocation (§IV-D) as constraint programs and solves them with an external
CP solver.  No solver ships in this container, so this module implements a
real one: pseudo-boolean linear constraints over 0/1 variables, a linear
(+ pairwise-max) objective, constraint propagation, a caller-supplied warm
start as incumbent, and depth-first branch & bound with activity-based
variable ordering under a wall-clock deadline.

Design notes
------------
* All model variables are booleans.  The paper's integer quantities
  (``MemTh_t``, bank extents) are linearized by the model builders — see
  tiling.py / scheduling.py — so linear pseudo-boolean constraints are
  sufficient and keep propagation cheap.
* The scheduling objective Eq. (8) contains ``max(l_DM(t), l_C(t))``
  per tick; :class:`MaxTerm` supports exactly that shape.  Its lower bound
  under a partial assignment is ``max_k(lb(expr_k))`` which keeps B&B
  bounds admissible.
* ``solve`` always returns the best incumbent found; ``optimal`` is True
  only when the search space was exhausted within the deadline.

Engines
-------
:func:`solve` is the incremental engine: per-variable constraint watch
lists keep a cached slack per constraint that is updated on
assignment/backtrack (no per-node full rescan), the objective lower bound
— including every :class:`MaxTerm` — is maintained incrementally so bound
checks are O(1), conflicts bump VSIDS-style variable activities (with
decay) that steer the branching order across geometric restarts, and the
incumbent drives objective-bound tightening (variables whose flip would
exceed the remaining gap are fixed).  :func:`solve_reference` preserves
the original full-rescan engine for regression tests and as the "seed
compiler" baseline in ``benchmarks/compile_bench.py``.  Both engines
explore admissible bounds only, so they agree on the optimum whenever
they prove optimality.

:func:`solve_many` solves a batch of *independent* models — the paper's
partitioned sub-problems (Table II) — concurrently on a process pool
(the solver is pure Python, so threads would serialize on the GIL),
falling back to in-process serial solving when the platform cannot fork.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

Terms = Sequence[Tuple[int, int]]  # (var_id, coef)


@dataclass
class MaxTerm:
    """Objective contribution ``max_k(const_k + sum coef*var)``."""

    exprs: List[Tuple[int, Terms]]  # (const, terms)

    def value(self, vals: Sequence[int]) -> int:
        return max(c + sum(co * vals[v] for v, co in t)
                   for c, t in self.exprs)

    def lower_bound(self, vals: Sequence[int], assigned: Sequence[bool]
                    ) -> int:
        lb = None
        for c, t in self.exprs:
            e = c
            for v, co in t:
                if assigned[v]:
                    e += co * vals[v]
                elif co < 0:
                    e += co
            lb = e if lb is None else max(lb, e)
        return lb or 0


@dataclass
class _Constraint:
    vars: List[int]
    coefs: List[int]
    rhs: int               # sum coefs*x <= rhs
    name: str = ""


@dataclass
class Solution:
    values: Dict[int, int]
    objective: float
    optimal: bool
    feasible: bool
    nodes: int
    wall_s: float

    def __getitem__(self, var: int) -> int:
        return self.values[var]


class CPModel:
    def __init__(self, name: str = "model"):
        self.name = name
        self.n_vars = 0
        self.var_names: List[str] = []
        self.cons: List[_Constraint] = []
        self.obj_terms: List[Tuple[int, int]] = []
        self.obj_const: int = 0
        self.max_terms: List[MaxTerm] = []
        self.fixed: Dict[int, int] = {}

    # ---- variables ----
    def bool(self, name: str = "") -> int:
        vid = self.n_vars
        self.n_vars += 1
        self.var_names.append(name or f"x{vid}")
        return vid

    def fix(self, var: int, val: int) -> None:
        self.fixed[var] = int(val)

    def fix_many(self, assignments: Dict[int, int]) -> None:
        """Bulk fixed assignment — how precondition/boundary state
        enters a model cheaply (e.g. the windowed fusion CPs' carry
        state): fixed vars are assigned and propagated once at the root
        and excluded from branching entirely."""
        for v, val in assignments.items():
            self.fixed[v] = int(val)

    # ---- constraints (normalized to <=) ----
    def add(self, terms: Terms, sense: str, rhs: int, name: str = "") -> None:
        terms = [(v, c) for v, c in terms if c != 0]
        if sense == "<=":
            self.cons.append(_Constraint([v for v, _ in terms],
                                         [c for _, c in terms], rhs, name))
        elif sense == ">=":
            self.cons.append(_Constraint([v for v, _ in terms],
                                         [-c for _, c in terms], -rhs, name))
        elif sense == "==":
            self.add(terms, "<=", rhs, name)
            self.add(terms, ">=", rhs, name)
        else:
            raise ValueError(sense)

    def add_implies(self, a: int, b: int, name: str = "") -> None:
        """a -> b   ==   a - b <= 0."""
        self.add([(a, 1), (b, -1)], "<=", 0, name)

    def add_at_most_one(self, vars_: Iterable[int], name: str = "") -> None:
        self.add([(v, 1) for v in vars_], "<=", 1, name)

    def add_exactly_one(self, vars_: Iterable[int], name: str = "") -> None:
        self.add([(v, 1) for v in vars_], "==", 1, name)

    # ---- objective ----
    def minimize(self, terms: Terms = (), const: int = 0,
                 max_terms: Sequence[MaxTerm] = ()) -> None:
        self.obj_terms = list(terms)
        self.obj_const = const
        self.max_terms = list(max_terms)

    def objective_value(self, vals: Sequence[int]) -> int:
        o = self.obj_const + sum(c * vals[v] for v, c in self.obj_terms)
        for mt in self.max_terms:
            o += mt.value(vals)
        return o

    def check(self, vals: Sequence[int]) -> List[str]:
        """Return names of violated constraints (empty == feasible)."""
        bad = []
        for con in self.cons:
            s = sum(c * vals[v] for v, c in zip(con.vars, con.coefs))
            if s > con.rhs:
                bad.append(con.name or "<unnamed>")
        for v, val in self.fixed.items():
            if vals[v] != val:
                bad.append(f"fixed:{self.var_names[v]}")
        return bad


# --------------------------------------------------------------------------
# Incremental solver
# --------------------------------------------------------------------------

_ACT_DECAY = 1.0 / 0.95
_ACT_RESCALE = 1e100
_TIME_CHECK_MASK = 63          # poll the clock every 64 expansions

#: default incumbent-stall cutoff (search nodes) used by the compiler's
#: windowed/partitioned CPs — the single source for the option defaults
#: in pipeline.CompilerOptions, scheduling.SchedOptions and plan_tiling.
DEFAULT_STALL_NODES = 16_000


def solve(model: CPModel, time_limit_s: float = 10.0,
          warm_start: Optional[Dict[int, int]] = None,
          stall_limit_s: Optional[float] = None,
          stall_limit_nodes: Optional[int] = None) -> Solution:
    """Branch & bound with incremental propagation.

    ``stall_limit_s`` / ``stall_limit_nodes``, when set, stop the search
    early once no better incumbent has been found for that long (wall
    seconds / search nodes) — the windowed scheduling CPs converge almost
    immediately from their warm starts and then spend the rest of the
    deadline proving optimality, which the anytime caller does not need.
    The node-based cutoff is deterministic: the same model explores the
    same tree regardless of machine load.  ``optimal`` is only True on
    full exhaustion.
    """
    t0 = time.monotonic()
    deadline = t0 + time_limit_s
    n = model.n_vars
    cons = model.cons
    n_cons = len(cons)

    cvars: List[List[Tuple[int, int]]] = [
        list(zip(c.vars, c.coefs)) for c in cons]
    occ: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for ci, pairs in enumerate(cvars):
        for v, co in pairs:
            occ[v].append((ci, co))

    obj_coef = [0] * n
    for v, c in model.obj_terms:
        obj_coef[v] += c

    # ---- incumbent from warm start ----
    best_vals: Optional[List[int]] = None
    best_obj = float("inf")
    if warm_start is not None:
        ws = [0] * n
        for v, val in warm_start.items():
            ws[v] = int(val)
        for v, val in model.fixed.items():
            ws[v] = val
        if not model.check(ws):
            best_vals = ws
            best_obj = model.objective_value(ws)

    # ---- incremental search state ----
    vals = [0] * n
    assigned = [False] * n
    # slack[ci] = rhs - (sum of min contribution of every var in ci);
    # assignments only ever *decrease* slack, backtracking restores it.
    slack = [c.rhs - sum(min(0, co) for co in c.coefs) for c in cons]

    # linear objective lower bound, maintained incrementally
    lin_lb = model.obj_const + sum(min(0, c) for c in obj_coef)

    # MaxTerm lower bounds, memoized per expression and maintained
    # incrementally: expr_lb[m][e] is exact for the current partial
    # assignment, mt_lb[m] = max_e expr_lb[m][e], total_mt = sum_m mt_lb.
    mts = model.max_terms
    expr_lb: List[List[int]] = []
    mt_lb: List[int] = []
    v2mt: Dict[int, List[Tuple[int, int, int]]] = {}
    for m, mt in enumerate(mts):
        lbs = []
        for e, (c, terms) in enumerate(mt.exprs):
            lbs.append(c + sum(min(0, co) for _, co in terms))
            for v, co in terms:
                if co:
                    v2mt.setdefault(v, []).append((m, e, co))
        expr_lb.append(lbs)
        mt_lb.append(max(lbs) if lbs else 0)
    total_mt = sum(mt_lb)

    trail: List[Tuple[int, List[Tuple[int, int]], int,
                      List[Tuple[int, int, int, int]]]] = []
    queue: List[int] = []
    queued = bytearray(n_cons)
    activity = [0.0] * n
    act_inc = 1.0
    conflict_ci = -1
    nodes = 0

    def assign(v: int, val: int) -> bool:
        """Assign and incrementally update slacks + objective bound.
        Returns False on constraint conflict."""
        nonlocal lin_lb, total_mt, conflict_ci
        vals[v] = val
        assigned[v] = True
        schanges: List[Tuple[int, int]] = []
        ok = True
        for ci, co in occ[v]:
            d = co * val - (co if co < 0 else 0)   # slack decrease, >= 0
            if d:
                s = slack[ci] - d
                slack[ci] = s
                schanges.append((ci, d))
                if s < 0:
                    ok = False
                    conflict_ci = ci
                elif not queued[ci]:
                    queued[ci] = 1
                    queue.append(ci)
        oc = obj_coef[v]
        dlin = oc * val - (oc if oc < 0 else 0)
        lin_lb += dlin
        mtch: List[Tuple[int, int, int, int]] = []
        for m, e, co in v2mt.get(v, ()):
            d = co * val - (co if co < 0 else 0)
            if d:
                old = mt_lb[m]
                lbs = expr_lb[m]
                lbs[e] += d
                if lbs[e] > old:
                    mt_lb[m] = lbs[e]
                    total_mt += lbs[e] - old
                mtch.append((m, e, d, old))
        trail.append((v, schanges, dlin, mtch))
        return ok

    def rewind(mark: int) -> None:
        nonlocal lin_lb, total_mt
        while len(trail) > mark:
            v, schanges, dlin, mtch = trail.pop()
            assigned[v] = False
            vals[v] = 0
            for ci, d in schanges:
                slack[ci] += d
            lin_lb -= dlin
            for m, e, d, old in reversed(mtch):
                expr_lb[m][e] -= d
                total_mt += old - mt_lb[m]
                mt_lb[m] = old

    def reset_queue() -> None:
        for ci in queue:
            queued[ci] = 0
        queue.clear()

    def run_queue() -> bool:
        """Drain the propagation queue, unit-forcing implied vars.  Only
        constraints whose slack shrank since last visit are re-examined."""
        while queue:
            ci = queue.pop()
            queued[ci] = 0
            s = slack[ci]
            if s < 0:
                return False
            for v, co in cvars[ci]:
                if assigned[v]:
                    continue
                if co > s:
                    if not assign(v, 0):
                        return False
                elif -co > s:
                    if not assign(v, 1):
                        return False
        return True

    # objective vars by |coef| (descending) for incumbent-driven
    # bound tightening
    obj_order_vars = sorted((v for v in range(n) if obj_coef[v]),
                            key=lambda v: -abs(obj_coef[v]))

    def node_fixpoint() -> bool:
        """Propagate + bound-check + tighten to fixpoint.  False means
        the node is pruned (conflict or objective bound)."""
        while True:
            if not run_queue():
                return False
            lb = lin_lb + total_mt
            if lb >= best_obj:
                return False
            gap = best_obj - lb
            forced = False
            for v in obj_order_vars:
                oc = obj_coef[v]
                if (oc if oc > 0 else -oc) < gap:
                    break
                if assigned[v]:
                    continue
                # flipping v to its expensive side alone would close the
                # remaining gap -> force the cheap side
                if not assign(v, 0 if oc > 0 else 1):
                    return False
                forced = True
            if not forced:
                return True

    def bump_conflict() -> None:
        nonlocal act_inc, activity
        if conflict_ci >= 0:
            for v, _ in cvars[conflict_ci]:
                activity[v] += act_inc
            act_inc *= _ACT_DECAY
            if act_inc > _ACT_RESCALE:
                activity = [a / _ACT_RESCALE for a in activity]
                act_inc = 1.0

    # branching order: activity (after restarts), then objective-
    # coefficient magnitude, then index.  Fixed vars (preconditions /
    # boundary state, see CPModel.fix_many) are assigned at the root and
    # never branched on.
    free = [v for v in range(n) if v not in model.fixed] \
        if model.fixed else list(range(n))
    order = sorted(free, key=lambda v: (-abs(obj_coef[v]), v))
    n_order = len(order)

    # ---- root: fixed vars + initial propagation over ALL constraints
    # (a constraint can be violated or unit-forcing before any
    # assignment, e.g. 3x <= -1 or 3x <= 2)
    root_ok = True
    for v, val in model.fixed.items():
        if assigned[v]:
            if vals[v] != val:
                root_ok = False
                break
            continue
        if not assign(v, val):
            root_ok = False
            break
    if root_ok:
        for ci in range(n_cons):
            if not queued[ci]:
                queued[ci] = 1
                queue.append(ci)
        root_ok = run_queue()     # plain propagation: root must not be
    reset_queue()                 # pruned by a warm-start bound

    optimal = False
    if root_ok:
        root_mark = len(trail)
        # iterative DFS (the fusion CPs reach thousands of variables —
        # deeper than Python's recursion limit)
        stack: List[List] = []      # [var, values-to-try, trail-mark, pos]
        cur_pos = 0
        conflicts = 0
        restart_at = 2048
        last_improve = t0
        improve_node = 0
        stalled = timed_out = False
        descend = True
        while True:
            if descend:
                i = cur_pos
                while i < n_order and assigned[order[i]]:
                    i += 1
                if i >= n_order:
                    obj = lin_lb + total_mt   # exact at full assignment
                    if obj < best_obj:
                        best_obj = obj
                        best_vals = list(vals)
                        last_improve = time.monotonic()
                        improve_node = nodes
                    descend = False
                    continue
                v = order[i]
                first = 0 if obj_coef[v] >= 0 else 1
                stack.append([v, [first, 1 - first], len(trail), i])
                descend = False
                continue
            if not stack:
                optimal = not (stalled or timed_out)
                break
            frame = stack[-1]
            if not frame[1]:
                rewind(frame[2])
                stack.pop()
                continue
            val = frame[1].pop(0)
            rewind(frame[2])
            reset_queue()
            nodes += 1
            if stall_limit_nodes is not None \
                    and nodes - improve_node > stall_limit_nodes:
                stalled = True
            if nodes & _TIME_CHECK_MASK == 0:
                now = time.monotonic()
                if now > deadline:
                    timed_out = True
                elif stall_limit_s is not None \
                        and now - last_improve > stall_limit_s:
                    stalled = True
            if stalled or timed_out:
                rewind(0)
                break
            ok = assign(frame[0], val)
            if ok:
                ok = node_fixpoint()
            if ok:
                cur_pos = frame[3] + 1
                descend = True
            else:
                conflicts += 1
                bump_conflict()
                if conflicts >= restart_at and stack:
                    # geometric restart with activity-reordered branching
                    restart_at *= 2
                    rewind(root_mark)
                    reset_queue()
                    stack.clear()
                    order = sorted(
                        free,
                        key=lambda v: (-activity[v], -abs(obj_coef[v]), v))
                    cur_pos = 0
                    descend = True

    wall = time.monotonic() - t0
    if best_vals is None:
        return Solution({}, float("inf"), optimal, False, nodes, wall)
    return Solution({v: best_vals[v] for v in range(n)},
                    float(best_obj), optimal, True, nodes, wall)


# --------------------------------------------------------------------------
# Reference (seed) solver — full constraint rescan per node.  Kept as the
# regression oracle and as the baseline engine timed by compile_bench.
# --------------------------------------------------------------------------


class _SearchState:
    __slots__ = ("vals", "assigned", "minsum", "trail")

    def __init__(self, n_vars: int, cons: List[_Constraint]):
        self.vals = [0] * n_vars
        self.assigned = [False] * n_vars
        # minsum[c] = sum of min contribution of every var in constraint c
        self.minsum = [sum(min(0, co) for co in c.coefs) for c in cons]
        self.trail: List[Tuple[int, List[Tuple[int, int]]]] = []


def solve_reference(model: CPModel, time_limit_s: float = 10.0,
                    warm_start: Optional[Dict[int, int]] = None,
                    stall_limit_s: Optional[float] = None,
                    stall_limit_nodes: Optional[int] = None) -> Solution:
    # stall limits are accepted (engine-interchangeable signature) but
    # ignored: the seed engine always runs to deadline or exhaustion
    t0 = time.monotonic()
    deadline = t0 + time_limit_s
    n = model.n_vars
    cons = model.cons

    # occurrence lists: var -> [(constraint index, coef)]
    occ: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for ci, c in enumerate(cons):
        for v, co in zip(c.vars, c.coefs):
            occ[v].append((ci, co))

    obj_coef = [0] * n
    for v, c in model.obj_terms:
        obj_coef[v] += c

    # ---- incumbent from warm start ----
    best_vals: Optional[List[int]] = None
    best_obj = float("inf")
    if warm_start is not None:
        ws = [0] * n
        for v, val in warm_start.items():
            ws[v] = int(val)
        for v, val in model.fixed.items():
            ws[v] = val
        if not model.check(ws):
            best_vals = ws
            best_obj = model.objective_value(ws)

    st = _SearchState(n, cons)
    nodes = 0

    def assign(v: int, val: int) -> bool:
        """Assign and update minsums.  Returns False on conflict."""
        changed: List[Tuple[int, int]] = []
        st.vals[v] = val
        st.assigned[v] = True
        ok = True
        for ci, co in occ[v]:
            old_min = min(0, co)
            new_min = co * val
            if new_min != old_min:
                st.minsum[ci] += new_min - old_min
                changed.append((ci, new_min - old_min))
            if st.minsum[ci] > cons[ci].rhs:
                ok = False
        st.trail.append((v, changed))
        return ok

    def undo() -> None:
        v, changed = st.trail.pop()
        st.assigned[v] = False
        st.vals[v] = 0
        for ci, delta in changed:
            st.minsum[ci] -= delta

    def propagate(level_mark: int) -> bool:
        """Unit-force vars whose assignment is implied.  Appends to trail;
        caller rewinds to level_mark on failure."""
        moved = True
        while moved:
            moved = False
            for ci, c in enumerate(cons):
                slack = c.rhs - st.minsum[ci]
                if slack < 0:
                    return False
                for v, co in zip(c.vars, c.coefs):
                    if st.assigned[v]:
                        continue
                    if co > 0 and co > slack:
                        if not assign(v, 0):
                            return False
                        moved = True
                    elif co < 0 and -co > slack:
                        if not assign(v, 1):
                            return False
                        moved = True
        return True

    def obj_lb() -> float:
        lb = model.obj_const
        for v in range(n):
            if st.assigned[v]:
                lb += obj_coef[v] * st.vals[v]
            elif obj_coef[v] < 0:
                lb += obj_coef[v]
        for mt in model.max_terms:
            lb += mt.lower_bound(st.vals, st.assigned)
        return lb

    # static branching order: objective-coefficient magnitude, then index
    order = sorted(range(n), key=lambda v: (-abs(obj_coef[v]), v))

    # apply fixed vars up front
    root_ok = True
    for v, val in model.fixed.items():
        if not assign(v, val):
            root_ok = False
    if root_ok:
        root_ok = propagate(0)

    def dfs(depth: int) -> None:
        nonlocal nodes, best_vals, best_obj
        if time.monotonic() > deadline:
            raise TimeoutError
        nodes += 1
        if obj_lb() >= best_obj:
            return
        # pick next unassigned var
        v = next((u for u in order if not st.assigned[u]), None)
        if v is None:
            obj = model.objective_value(st.vals)
            if obj < best_obj:
                best_obj = obj
                best_vals = list(st.vals)
            return
        # value order: cheaper objective contribution first
        first = 0 if obj_coef[v] >= 0 else 1
        for val in (first, 1 - first):
            mark = len(st.trail)
            ok = assign(v, val)
            if ok:
                ok = propagate(mark)
            if ok:
                dfs(depth + 1)
            while len(st.trail) > mark:
                undo()

    optimal = False
    if root_ok:
        try:
            dfs(0)
            optimal = True
        except (TimeoutError, RecursionError):
            optimal = False

    wall = time.monotonic() - t0
    if best_vals is None:
        return Solution({}, float("inf"), optimal, False, nodes, wall)
    return Solution({v: best_vals[v] for v in range(n)},
                    float(best_obj), optimal, True, nodes, wall)


ENGINES = {"incremental": solve, "reference": solve_reference}


# --------------------------------------------------------------------------
# Batch solving of independent sub-problems (Table II partitioning)
# --------------------------------------------------------------------------


@dataclass
class SolveTask:
    model: CPModel
    time_limit_s: float = 10.0
    warm_start: Optional[Dict[int, int]] = None
    stall_limit_s: Optional[float] = None
    stall_limit_nodes: Optional[int] = None
    engine: str = "incremental"


def _run_task(task: SolveTask) -> Solution:
    fn = ENGINES[task.engine]
    return fn(task.model, time_limit_s=task.time_limit_s,
              warm_start=task.warm_start,
              stall_limit_s=task.stall_limit_s,
              stall_limit_nodes=task.stall_limit_nodes)


def solve_many(tasks: Sequence[SolveTask], parallel: bool = True,
               max_workers: Optional[int] = None) -> List[Solution]:
    """Solve independent CP models, concurrently when possible.

    The partitioned scheduling/tiling sub-problems share no variables, so
    they can be dispatched to worker processes (fork start method: the
    models are inherited or pickled as plain data).  Any pool failure —
    no fork support, sandboxed semaphores, worker crash, a hung child —
    falls back to solving everything serially in-process, so callers
    never see an exception from the parallelism itself.

    Forking a multi-threaded process can deadlock the child (e.g. after
    jax spins up its runtime threads), and a deadlock is a hang, not an
    exception — so the pool is only used from single-threaded processes
    and every wait carries a deadline.
    """
    import threading

    tasks = list(tasks)
    if len(tasks) <= 1 or not parallel or threading.active_count() > 1:
        return [_run_task(t) for t in tasks]
    ex = None
    try:
        import concurrent.futures as cf
        import multiprocessing as mp
        ctx = mp.get_context("fork")
        workers = max_workers or min(len(tasks), os.cpu_count() or 1)
        ex = cf.ProcessPoolExecutor(max_workers=workers, mp_context=ctx)
        futs = [ex.submit(_run_task, t) for t in tasks]
        deadline = time.monotonic() + \
            sum(t.time_limit_s for t in tasks) + 60.0
        out = [f.result(timeout=max(1.0, deadline - time.monotonic()))
               for f in futs]
        ex.shutdown()
        return out
    except Exception:
        if ex is not None:          # don't join a possibly-hung worker
            ex.shutdown(wait=False, cancel_futures=True)
        return [_run_task(t) for t in tasks]


def brute_force(model: CPModel) -> Solution:
    """Exhaustive reference solver for tests (<= ~20 vars)."""
    n = model.n_vars
    assert n <= 22, "brute_force is for tiny models"
    best = None
    best_obj = float("inf")
    for mask in range(1 << n):
        vals = [(mask >> i) & 1 for i in range(n)]
        if any(vals[v] != val for v, val in model.fixed.items()):
            continue
        if model.check(vals):
            continue
        o = model.objective_value(vals)
        if o < best_obj:
            best_obj = o
            best = vals
    if best is None:
        return Solution({}, float("inf"), True, False, 1 << n, 0.0)
    return Solution({v: best[v] for v in range(n)}, float(best_obj),
                    True, True, 1 << n, 0.0)
