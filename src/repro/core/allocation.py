"""TCM memory allocation + V2P emission (paper §IV-D).

Given the timed job program, allocation reserves virtual space for every
resident tile, assigns physical banks, and emits the V2P remap updates so
the compute engines see contiguous data.  The paper's four properties map
onto this implementation as:

  a) *virtual-space contiguity* — tiles of a tensor get consecutive
     virtual slots (tensor base + tile index), recorded in the program
     meta for the executor;
  b) *physical preservation* — a tile's bank set never changes while it
     is resident (bank sets are only assigned on acquisition);
  c) *reuse optimization* — banks freed by tiles dying at a tick are
     preferentially recycled for that tick's outputs (output-over-input
     overwriting);
  d) *bank exclusivity* — banks are whole-tile granular, so two tensors
     never share a bank; asserted on every acquisition.

Because the V2P table makes physical banks interchangeable, a feasible
allocation exists whenever the scheduler respected the Eq. (7) capacity
constraint; the paper's CP formulation is needed on hardware with
*address-contiguous* physical constraints, which V2P removes.  The
allocator still verifies capacity tick-by-tick and can locally *re-time*
jobs (delay a prefetch, advance a push) to repair transient
over-subscription introduced by the scheduler's windowed re-timing; a
genuine overflow raises :class:`AllocationError`.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .npu import NPUConfig
from .program import DmaJob, NPUProgram, Tick, TileRef, V2PJob


class AllocationError(RuntimeError):
    pass


@dataclass
class Allocation:
    banks: Dict[Tuple[str, int], List[int]] = field(default_factory=dict)
    tiles: Dict[Tuple[str, int], "TileRef"] = field(default_factory=dict)
    peak_banks: int = 0
    v2p_updates: int = 0
    repair_spills: int = 0
    spill_events: List = field(default_factory=list)


def allocate(prog: NPUProgram, cfg: Optional[NPUConfig] = None
             ) -> Allocation:
    """Assign physical banks over the program's ticks; mutates `prog` by
    appending V2P jobs and possibly re-timing DMA jobs (fix-up)."""
    cfg = cfg or prog.cfg
    n_banks = cfg.tcm_banks
    free: List[int] = list(range(n_banks))
    held: Dict[Tuple[str, int], List[int]] = {}
    alloc = Allocation()
    dead_after = prog.meta.get("dead_after_tick", {})

    # Pre-scan (one pass per program): last tick each tile is used by a
    # compute or push job, the sorted compute-input use ticks per tile,
    # and the sorted ticks holding a scheduled push per tile.  The
    # force_spill/acquire fix-ups below consult these indexes instead of
    # rescanning prog.ticks[tick+1:] per repair — the rescan was
    # quadratic on programs with many repair spills.
    last_use: Dict[Tuple[str, int], int] = {}
    use_ticks: Dict[Tuple[str, int], List[int]] = {}
    push_locs: Dict[Tuple[str, int], List[int]] = {}
    for t in prog.ticks:
        if t.compute:
            for tl in t.compute.in_tiles:
                last_use[tl.key] = t.index
                use_ticks.setdefault(tl.key, []).append(t.index)
            for tl in t.compute.out_tiles:
                last_use[tl.key] = t.index
        for j in t.dma:
            if j.kind == "push":
                last_use.setdefault(j.tile.key, t.index)
                push_locs.setdefault(j.tile.key, []).append(t.index)

    def pop_push_loc(key: Tuple[str, int], after: int,
                     before: int) -> Optional[int]:
        """First tick in (after, before) holding a push of `key`; removed
        from the index (the caller moves the job)."""
        locs = push_locs.get(key)
        if not locs:
            return None
        i = bisect.bisect_right(locs, after)
        if i < len(locs) and locs[i] < before:
            return locs.pop(i)
        return None

    def move_push(key: Tuple[str, int], src: int, dst: Tick) -> bool:
        for j in prog.ticks[src].dma:
            if j.kind == "push" and j.tile.key == key:
                prog.ticks[src].dma.remove(j)
                dst.dma.append(j)
                return True
        return False  # pragma: no cover — index out of sync

    from .npu import dma_cost
    from .program import DmaJob

    protected: Set[Tuple[str, int]] = set()

    def force_spill(tick: Tick, want: int) -> None:
        """Last-resort repair: push a resident, not-currently-needed tile
        to DRAM now and schedule a re-fetch right before its next compute
        use.  Functionally exact (the executor round-trips the data);
        costs extra DDR traffic, which the latency accounting charges."""
        cands = sorted(
            ((key, banks) for key, banks in held.items()
             # synthetic staging tiles (l-copy halo buffers) have no DRAM
             # backing — they cannot round-trip through a push
             if key not in protected and not key[0].startswith("__")),
            key=lambda kv: -len(kv[1]))
        for key, banks in cands:
            if len(free) >= want:
                return
            tile = alloc.tiles.get(key)
            if tile is None:
                continue
            # next compute use of this tile (if any), via the use index
            next_use: Optional[int] = None
            us = use_ticks.get(key)
            if us:
                i = bisect.bisect_right(us, tick.index)
                if i < len(us):
                    next_use = us[i]
            # a scheduled push BEFORE the next use would now target a
            # non-resident tile — move it to this tick instead of adding
            # a duplicate
            horizon = next_use if next_use is not None \
                else len(prog.ticks)
            loc = pop_push_loc(key, tick.index, horizon)
            moved = loc is not None and move_push(key, loc, tick)
            if not moved:
                tick.dma.append(DmaJob("push", tile, tile.nbytes,
                                       dma_cost(cfg, tile.nbytes)))
            if next_use is not None:
                prog.ticks[next_use].dma.insert(0, DmaJob(
                    "fetch", tile, tile.nbytes,
                    dma_cost(cfg, tile.nbytes)))
            release(key)
            alloc.repair_spills += 1
            alloc.spill_events.append((tick.index, key, len(banks)))

    def acquire(tick: Tick, tl: TileRef) -> None:
        if tl.key in held:
            return
        if len(free) < tl.banks:
            # fix-up: advance pushes of tiles unused from here on
            for key in list(held):
                if len(free) >= tl.banks:
                    break
                if last_use.get(key, 10 ** 9) > tick.index:
                    continue  # needed later — cannot advance its push
                # tile resident but never used again: if a push job exists
                # in a later tick, advance it here and free the banks
                loc = pop_push_loc(key, tick.index, len(prog.ticks))
                if loc is not None and move_push(key, loc, tick):
                    release(key)
        if len(free) < tl.banks:
            force_spill(tick, tl.banks)
        if len(free) < tl.banks:
            raise AllocationError(
                f"tick {tick.index}: need {tl.banks} banks for {tl}, "
                f"only {len(free)} free")
        got = [free.pop() for _ in range(tl.banks)]
        held[tl.key] = got
        alloc.banks[tl.key] = got
        alloc.tiles[tl.key] = tl
        tick.v2p.append(V2PJob(tl, got, cfg.v2p_cycles))
        alloc.v2p_updates += 1
        alloc.peak_banks = max(alloc.peak_banks, n_banks - len(free))

    def release(key: Tuple[str, int]) -> None:
        banks = held.pop(key, None)
        if banks:
            free.extend(banks)

    for idx, tick in enumerate(prog.ticks):
        # 0. eviction pushes release first: the scheduler frees a pushed
        #    tile's banks within its tick, and evicted tiles are never
        #    inputs of the tick's compute (Eq. 3) — so their release is
        #    ordered before this tick's fetch acquisitions.
        compute_keys = set()
        if tick.compute:
            compute_keys = {tl.key for tl in tick.compute.in_tiles
                            + tick.compute.out_tiles}
        protected.clear()
        protected.update(compute_keys)
        protected.update(j.tile.key for j in tick.dma
                         if j.kind in ("fetch", "lfetch", "lcopy"))
        early_released = set()
        for j in tick.dma:
            if j.kind == "push" and j.tile.key not in compute_keys:
                release(j.tile.key)
                early_released.add(j.tile.key)
        # 1. fetches/l-copies acquire banks (written during this tick).
        #    A fetch that doesn't fit yet is DEFERRED to the next tick —
        #    legal until (and including) the tick of its first compute
        #    use, since the controller sequences DMA before the compute
        #    job within a tick.  This repairs residual drift between the
        #    scheduler's bank model and the physical ledger.
        for j in list(tick.dma):
            if j.kind in ("fetch", "lfetch", "lcopy"):
                if j.tile.key in held:
                    continue
                if len(free) < j.tile.banks \
                        and j.tile.key not in compute_keys \
                        and idx + 1 < len(prog.ticks):
                    tick.dma.remove(j)
                    prog.ticks[idx + 1].dma.append(j)
                    continue
                acquire(tick, j.tile)
        # 2. compute: inputs must be held; outputs acquire
        if tick.compute:
            for tl in tick.compute.in_tiles:
                if tl.key not in held:
                    raise AllocationError(
                        f"tick {tick.index}: input {tl} of "
                        f"{tick.compute.op_name} not resident")
            # bank exclusivity: inputs/outputs disjoint by construction —
            # verify no bank appears twice across held tiles
            for tl in tick.compute.out_tiles:
                acquire(tick, tl)
        # 3. remaining pushes release banks at end of tick
        for j in tick.dma:
            if j.kind == "push" and j.tile.key not in early_released:
                release(j.tile.key)
        # 4. dead tiles release
        for key in dead_after.get(tick.index, []):
            release(tuple(key))
        # invariant: a bank is held by at most one tile
        seen: Set[int] = set()
        for key, banks in held.items():
            for b in banks:
                if b in seen:
                    raise AllocationError(f"bank {b} double-held")
                seen.add(b)

    prog.meta["peak_banks"] = alloc.peak_banks
    prog.meta["v2p_updates"] = alloc.v2p_updates
    return alloc
