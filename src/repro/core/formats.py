"""Format selection — depth vs. line parallelism (paper §IV-A).

Every compute job runs on N lockstep engines in one of two *formats*:

  * **depth**: the outC dimension is split across engines; the ifmap is
    broadcast-shared.  No pre-compute copies are needed (the rotating
    word-level addressing over channel fragments handles the layout), but
    utilization collapses when outC < M x engines.
  * **line**: output lines (outH) are split across engines; parameters are
    broadcast-shared.  Works at any channel count, but when filterH > 1
    the per-engine input windows overlap, so halo rows must be duplicated
    across banks with TCM-to-TCM copies before compute.

The compiler picks a format per layer by estimating execution latency
including the format-switch/expansion overhead between consecutive layers
(the paper's own criterion).  The pairwise producer->consumer coupling
makes this a local-interaction energy; we minimize it with coordinate
descent (sweep to fixed point), which is exact on chains and in practice
optimal on the benchmark DAGs (verified against brute force on small
graphs in the tests).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

from .ir import Graph, Op
from .npu import NPUConfig, compute_job_cost, dma_cost, elem_bytes

FORMATS = ("depth", "line")

#: op kinds that have a spatial receptive field taller than one row (may
#: require halo expansion under line parallelism).
_SPATIAL = ("conv", "dwconv", "maxpool", "avgpool")


def halo_rows(op: Op) -> int:
    """Input rows that overlap between adjacent engine line-partitions."""
    if op.kind in _SPATIAL:
        k = op.attrs.get("k", (1, 1))
        kh = k[0] if isinstance(k, tuple) else k
        s = op.attrs.get("stride", 1)
        return max(0, kh - s)
    return 0


def lcopy_bytes(g: Graph, op: Op, out_rows: int) -> int:
    """TCM-to-TCM copy volume to expand inputs of `op` into line format
    for a tile covering `out_rows` output lines on `engines` partitions.
    (engines-1) internal boundaries each duplicate `halo` input rows."""
    h = halo_rows(op)
    if h == 0:
        return 0
    total = 0
    for t in g.act_inputs(op):
        if len(t.shape) != 3:
            continue
        _, w, c = t.shape
        total += math.ceil(h * w * c * elem_bytes(t.dtype))
    return total * 1  # one copy per internal engine boundary, amortized


def switch_bytes(g: Graph, producer_fmt: str, op: Op) -> int:
    """Layout-rearrangement volume when `op`'s input was produced in
    `producer_fmt` and `op` consumes in the other format.

    depth->depth : 0 (rotating fragment addressing, paper §IV-A)
    *->line      : halo expansion only (counted via lcopy_bytes)
    line->depth  : the line-fragmented ifmap must be re-fragmented by
                   channel — a full copy of the consumed activation.
    """
    if producer_fmt == "line":
        return sum(t.bytes for t in g.act_inputs(op) if len(t.shape) == 3)
    return 0


@dataclass
class FormatPlan:
    fmt: Dict[str, str]               # op name -> format
    cost_cycles: Dict[str, int]       # op name -> modeled cycles (inc. copies)

    def __getitem__(self, op_name: str) -> str:
        return self.fmt[op_name]


def _local_cost(cfg: NPUConfig, g: Graph, op: Op, fmt: str,
                producer_fmts: Dict[str, str]) -> int:
    out = g.tensors[op.output]
    H = out.shape[0] if len(out.shape) == 3 else 1
    c = compute_job_cost(cfg, g, op, H, fmt).cycles
    if fmt == "line":
        c += dma_cost(cfg, lcopy_bytes(g, op, H), kind="tcm")
    if fmt == "depth":
        # pay re-fragmentation for every line-format producer
        for t in g.act_inputs(op):
            p = t.producer
            if p is not None and producer_fmts.get(p) == "line":
                c += dma_cost(cfg, t.bytes, kind="tcm")
    return c


def select_formats(cfg: NPUConfig, g: Graph,
                   allowed: Tuple[str, ...] = FORMATS,
                   max_sweeps: int = 8) -> FormatPlan:
    """Coordinate-descent format assignment.

    `allowed` restricted to ("depth",) reproduces the baseline compiler
    (single-format, the eNPU-A reference behaviour in §V).
    """
    ops = g.topo_ops()
    fmt: Dict[str, str] = {}
    # init: per-op best ignoring neighbours
    for op in ops:
        best = min(allowed,
                   key=lambda f: _local_cost(cfg, g, op, f, {}))
        fmt[op.name] = best
    if len(allowed) > 1:
        for _ in range(max_sweeps):
            changed = False
            for op in ops:
                # own cost + downstream re-fragmentation induced on consumers
                def total(f: str) -> int:
                    trial = dict(fmt)
                    trial[op.name] = f
                    c = _local_cost(cfg, g, op, f, trial)
                    for out_name in op.outputs:
                        for cons in g.tensors[out_name].consumers:
                            cop = g.op(cons)
                            c += _local_cost(cfg, g, cop, trial[cop.name],
                                             trial)
                    return c
                best = min(allowed, key=total)
                if best != fmt[op.name]:
                    fmt[op.name] = best
                    changed = True
            if not changed:
                break
    costs = {op.name: _local_cost(cfg, g, op, fmt[op.name], fmt)
             for op in ops}
    return FormatPlan(fmt, costs)
