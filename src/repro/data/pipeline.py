"""Deterministic synthetic LM data pipeline with host-shard prefetch.

Production posture: each host process generates only its shard of the
global batch (``host_id``/``n_hosts``), double-buffered on a background
thread so step N+1's batch is ready before step N finishes (the data-side
DAE of the paper — input fetch hidden behind compute).  Determinism: the
token block for global step *s* is a pure function of (seed, s), so a
restarted/elastic job resumes bit-identically from any step.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The (deterministic) host-local batch of global step `step`.

    A Zipf-ish marginal over the vocab with a shifted-copy structure so
    the LM loss actually decreases (next token correlates with current)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab
    base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
    tokens = np.minimum(base - 1, V - 1).astype(np.int32)
    # inject learnable structure: 50% of positions repeat t-1 plus one
    mask = rng.random((B, S)) < 0.5
    shifted = np.roll(tokens, 1, axis=1)
    tokens = np.where(mask, np.minimum(shifted + 1, V - 1), tokens)
    return {"tokens": tokens, "labels": tokens.copy()}


class Pipeline:
    """Background-thread prefetching iterator over deterministic steps."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self._step
        while not self._stop.is_set():
            b = batch_for_step(self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, b = self._q.get()
        self._step = step + 1
        return b

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
