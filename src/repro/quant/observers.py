"""Range observers for post-training calibration.

An observer watches every value a tensor takes across the calibration
set and reduces it to the float range the quantizer maps onto the int
grid.  Two estimators (the ones every production PTQ stack ships):

  * **min-max** — the exact envelope; optimal for weights and for
    activations with hard range bounds (relu6), but a single outlier
    stretches the scale and wastes codes;
  * **percentile** — clips the top/bottom ``(100 - pct)/2`` percent per
    sample and takes the worst case over samples; robust to heavy-tailed
    activations (silu/gelu feature maps).

Observers also come in per-channel form (reduce over all axes except
``axis``) for conv/fc weights.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class MinMaxObserver:
    """Running min/max over all observed values (per-tensor)."""

    def __init__(self) -> None:
        self.lo = np.inf
        self.hi = -np.inf
        self.samples = 0

    def update(self, arr: np.ndarray) -> None:
        a = np.asarray(arr)
        if a.size == 0:
            return
        self.lo = min(self.lo, float(a.min()))
        self.hi = max(self.hi, float(a.max()))
        self.samples += 1

    def range(self) -> Tuple[float, float]:
        if self.samples == 0:
            return (0.0, 0.0)
        return (self.lo, self.hi)


class PercentileObserver:
    """Per-sample symmetric percentile clip, worst case across samples.

    ``pct=99.9`` keeps the [0.05, 99.95] percentile band of each sample
    and returns the widest such band seen — tighter than min-max under
    outliers, never tighter than the bulk of the distribution."""

    def __init__(self, pct: float = 99.9) -> None:
        if not 0.0 < pct <= 100.0:
            raise ValueError(f"pct must be in (0, 100], got {pct}")
        self.pct = pct
        self.lo = np.inf
        self.hi = -np.inf
        self.samples = 0

    def update(self, arr: np.ndarray) -> None:
        a = np.asarray(arr, dtype=np.float64).reshape(-1)
        if a.size == 0:
            return
        tail = (100.0 - self.pct) / 2.0
        lo, hi = np.percentile(a, [tail, 100.0 - tail])
        self.lo = min(self.lo, float(lo))
        self.hi = max(self.hi, float(hi))
        self.samples += 1

    def range(self) -> Tuple[float, float]:
        if self.samples == 0:
            return (0.0, 0.0)
        return (self.lo, self.hi)


class PerChannelMinMaxObserver:
    """Min/max per channel along ``axis`` (weights: axis 0 == outC)."""

    def __init__(self, axis: int = 0) -> None:
        self.axis = axis
        self.lo: Optional[np.ndarray] = None
        self.hi: Optional[np.ndarray] = None

    def update(self, arr: np.ndarray) -> None:
        a = np.asarray(arr, dtype=np.float64)
        if a.ndim == 0:
            a = a.reshape(1)
        moved = np.moveaxis(a, self.axis, 0).reshape(a.shape[self.axis], -1)
        lo = moved.min(axis=1)
        hi = moved.max(axis=1)
        self.lo = lo if self.lo is None else np.minimum(self.lo, lo)
        self.hi = hi if self.hi is None else np.maximum(self.hi, hi)

    def range(self) -> Tuple[np.ndarray, np.ndarray]:
        if self.lo is None:
            return (np.zeros(1), np.zeros(1))
        return (self.lo, self.hi)


def make_observer(method: str = "minmax", percentile: float = 99.9):
    if method == "minmax":
        return MinMaxObserver()
    if method == "percentile":
        return PercentileObserver(percentile)
    raise ValueError(f"unknown calibration method {method!r} "
                     "(expected 'minmax' or 'percentile')")
