"""Quantization arithmetic: affine quantize/dequantize + int4 packing.

The :class:`repro.core.ir.QParams` dataclass (scale, zero_point, bits,
per-channel axis) lives in the IR so graph fingerprints can include it;
this module supplies the arithmetic that gives it meaning:

  * ``quantize``/``dequantize`` — the affine map ``f = s * (q - z)`` with
    per-tensor or per-channel ``s``/``z`` broadcast along the channel
    axis;
  * ``qparams_from_range`` — scale/zero-point selection from an observed
    float range (symmetric for weights, asymmetric for activations —
    the standard TFLite/LiteRT PTQ convention the paper deploys);
  * ``pack_int4``/``unpack_int4`` — nibble packing for int4 weights: two
    signed 4-bit values per byte, low nibble first, flat row-major order
    (the storage format whose byte count ``Tensor.bytes`` charges).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.ir import QParams

#: epsilon floor so a constant tensor still gets an invertible scale.
_MIN_SCALE = 1e-12


def _broadcast(qp: QParams, ndim: int) -> Tuple[np.ndarray, np.ndarray]:
    """scale/zero_point shaped to broadcast against an ndim-D array.

    Per-channel params broadcast along ``qp.axis``; per-tensor params are
    scalars already."""
    s = np.asarray(qp.scale, dtype=np.float32)
    z = np.asarray(qp.zero_point, dtype=np.int32)
    if qp.axis is None or s.ndim == 0:
        return s, z
    shape = [1] * ndim
    shape[qp.axis] = s.shape[0]
    return s.reshape(shape), z.reshape(shape)


def quantize(x: np.ndarray, qp: QParams) -> np.ndarray:
    """float -> stored integer values (int8 for bits<=8, int32 for bias).

    int4 values are clamped to [-8, 7] but *stored* one-per-int8 — the
    packed byte stream is produced separately by :func:`pack_int4` (and
    is what the DMA byte accounting charges)."""
    x = np.asarray(x, dtype=np.float32)
    s, z = _broadcast(qp, x.ndim)
    if s.ndim == 0:
        # per-tensor hot path: python scalars keep the whole pipeline in
        # float32 (a 0-d int32 zero point would promote the add — and
        # every pass after it — to float64).  Bit-identical: round(x/s)
        # is integer-valued, the add is exact below 2^24, and anything
        # past 2^24 is far outside the clip range either way.
        q = x / float(s)
        np.round(q, out=q)
        q += int(z)
        np.clip(q, qp.qmin, qp.qmax, out=q)
        return q.astype(np.int32 if qp.bits > 8 else np.int8)
    q = np.round(x / s) + z
    q = np.clip(q, qp.qmin, qp.qmax)
    return q.astype(np.int32 if qp.bits > 8 else np.int8)


def dequantize(q: np.ndarray, qp: QParams) -> np.ndarray:
    s, z = _broadcast(qp, np.asarray(q).ndim)
    return ((np.asarray(q, dtype=np.int64) - z) * s).astype(np.float32)


def qparams_from_range(lo: float, hi: float, bits: int = 8,
                       symmetric: bool = False,
                       axis: Optional[int] = None) -> QParams:
    """Scale/zero-point from an observed float range (scalar form)."""
    return _qparams_from_ranges(np.asarray([lo]), np.asarray([hi]),
                                bits, symmetric, axis, scalar=True)


def qparams_per_channel(lo: np.ndarray, hi: np.ndarray, bits: int = 8,
                        symmetric: bool = True, axis: int = 0) -> QParams:
    """Per-channel qparams from per-channel ranges along ``axis``."""
    return _qparams_from_ranges(np.asarray(lo), np.asarray(hi),
                                bits, symmetric, axis, scalar=False)


def _qparams_from_ranges(lo: np.ndarray, hi: np.ndarray, bits: int,
                         symmetric: bool, axis: Optional[int],
                         scalar: bool) -> QParams:
    lo = np.minimum(np.asarray(lo, dtype=np.float64), 0.0)
    hi = np.maximum(np.asarray(hi, dtype=np.float64), 0.0)
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if symmetric:
        amax = np.maximum(np.abs(lo), np.abs(hi))
        scale = np.maximum(amax / qmax, _MIN_SCALE)
        zp = np.zeros_like(scale, dtype=np.int64)
    else:
        scale = np.maximum((hi - lo) / (qmax - qmin), _MIN_SCALE)
        zp = np.clip(np.round(qmin - lo / scale), qmin, qmax).astype(np.int64)
    if scalar:
        return QParams(np.float32(scale[0]), np.int64(zp[0]),
                       bits=bits, axis=None)
    return QParams(scale.astype(np.float32), zp, bits=bits, axis=axis)


# --------------------------------------------------------------------------
# int4 nibble packing
# --------------------------------------------------------------------------


def pack_int4(q: np.ndarray) -> np.ndarray:
    """Pack signed int4 values (each in [-8, 7], stored one-per-int8)
    into a flat uint8 stream: two values per byte, low nibble first.
    Odd-length inputs get a zero pad nibble."""
    flat = np.asarray(q).reshape(-1).astype(np.int16)
    if flat.size and (flat.min() < -8 or flat.max() > 7):
        raise ValueError("values out of int4 range [-8, 7]")
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, dtype=np.int16)])
    u = (flat & 0xF).astype(np.uint8)          # two's-complement nibbles
    return (u[0::2] | (u[1::2] << 4)).astype(np.uint8)


def unpack_int4(packed: np.ndarray, n: int,
                shape: Optional[Tuple[int, ...]] = None) -> np.ndarray:
    """Inverse of :func:`pack_int4`: first ``n`` signed int4 values,
    optionally reshaped."""
    p = np.asarray(packed, dtype=np.uint8).reshape(-1)
    lo = (p & 0xF).astype(np.int8)
    hi = (p >> 4).astype(np.int8)
    vals = np.empty(p.size * 2, dtype=np.int8)
    vals[0::2] = lo
    vals[1::2] = hi
    vals = np.where(vals >= 8, vals - 16, vals).astype(np.int8)[:n]
    return vals.reshape(shape) if shape is not None else vals
