"""Integer quantization subsystem (int8/int4 PTQ for the NPU compiler).

Workflow:

    g, b = vision.build("mobilenet_v2")
    calib = quant.calibrate(g, b._weights, samples)      # observe ranges
    qm = quant.quantize_graph(g, b._weights, calib)      # annotate IR
    res = compile_graph(qm.graph, cfg)                   # precision-aware
    execute(res.program, qm.graph, res.tiling, inp,
            qm.weights_f, semantics=quant.QuantSemantics(qm))

Modules:
    observers  — min-max / percentile / per-channel range observers
    qparams    — affine quantize/dequantize + int4 nibble packing
    ptq        — calibration driver, the PTQ graph pass, integer kernels,
                 quantized functional reference
    executor   — QuantSemantics: integer program-replay semantics
"""
from repro.core.ir import QParams, graph_precision

from .executor import QuantSemantics
from .observers import (MinMaxObserver, PerChannelMinMaxObserver,
                        PercentileObserver, make_observer)
from .ptq import (QuantizedModel, calibrate, cast_graph,
                  measure_quant_error, quantize_graph,
                  quantized_reference_execute, synthetic_calibration)
from .qparams import (dequantize, pack_int4, qparams_from_range,
                      qparams_per_channel, quantize, unpack_int4)

__all__ = [
    "QParams", "QuantizedModel", "QuantSemantics",
    "MinMaxObserver", "PercentileObserver", "PerChannelMinMaxObserver",
    "make_observer", "calibrate", "quantize_graph", "cast_graph",
    "measure_quant_error", "quantized_reference_execute",
    "synthetic_calibration",
    "graph_precision",
    "quantize", "dequantize", "qparams_from_range", "qparams_per_channel",
    "pack_int4", "unpack_int4",
]
